"""Distributed-decode benchmark: sharded vs local decode attention.

Runs in a subprocess with --xla_force_host_platform_device_count=8 (the
parent process has already locked jax to the visible device count), and
merges its rows into ``BENCH_kernels.json`` next to the kernel
micro-bench rows.

Per (B, T) cell:
  * local decode latency (``decode_attend_local`` on the full cache),
  * sharded decode latency (``dist.decode.sharded_flash_decode`` on a
    (1, 8) mesh — sequence-sharded cache, psum combine),
  * modeled per-token collective bytes from the compiled HLO
    (``hlo_analysis.collective_bytes``) — the headline number: the
    combine moves O(B*H*(Dh+2)) stat bytes instead of the O(B*T*KV*Dh)
    cache, independent of context length.

Plus one ``engine_decode`` row: a full one-token ``DecodeEngine`` step
(reduced arch, (1, 8) mesh, sequence-sharded cache, explicit mesh —
the production serve path) with its per-token collective bytes from
the engine's compiled decode step.

The ``mla_decode`` / ``mla_decode_paged`` rows pin the split-operand
MLA win in the staged_bytes column: ``mla_split`` stages r+rope
features/position (latent read once for scores AND values),
``mla_concat`` 2*(r+rope) (k_cat + zero-padded v_cat copies — on the
paged path, copies of the whole pool).  ``paged_decode_bucketed``
pins the block-table width bucketing: the table sliced to the
power-of-two bucket of the live page count stages the live-table
row's bytes instead of the fixed max_pages budget.

On a host-device CPU mesh the sharded latency is pure overhead
(interpret-mode kernels, emulated collectives); the latency columns
track the *trajectory*, the collective-bytes column is the modeled
production quantity.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json
import time

import jax
import jax.numpy as jnp

from repro.dist.decode import sharded_flash_decode
from repro.launch import hlo_analysis
from repro.models.attention import decode_attend_local


def timed(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


rows = []
mesh = jax.make_mesh((1, 8), ("data", "model"))
key = jax.random.PRNGKey(0)
H, KV, Dh = 8, 2, 64
for B, T in ((4, 2048), (4, 8192)):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    ck = jax.random.normal(ks[1], (B, T, KV, Dh))
    cv = jax.random.normal(ks[2], (B, T, KV, Dh))
    cur = jnp.int32(T)

    local = jax.jit(lambda q, k, v, c: decode_attend_local(
        q, k, v, jnp.arange(T), c))
    shard = jax.jit(lambda q, k, v, c: sharded_flash_decode(
        mesh, q, k, v, c))
    t_local = timed(local, q, ck, cv, cur)
    t_shard = timed(shard, q, ck, cv, cur)
    coll, kinds = hlo_analysis.collective_bytes(
        shard.lower(q, ck, cv, cur).compile().as_text())
    cache_bytes = 2 * B * T * KV * Dh * 4
    rows.append({
        "op": "dist_decode", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_shard, 1), "us_ref": round(t_local, 1),
        "flops": B * H * 2 * T * Dh * 2, "staged_bytes": cache_bytes,
        "arith_intensity": None,
        "note": (f"mesh (1,8) seq-sharded; collective {coll:.0f} B/token"
                 f" vs cache {cache_bytes} B ({kinds})"),
        "collective_bytes": coll,
    })

# ---- paged vs dense decode attention ---------------------------------
# same (B, T) cells at 50% occupancy — the continuous-batching regime.
# The dense path streams its full (B, T) budget every step (dead bytes
# included: masking skips math, not DMA); the paged path's block table
# names only the ceil(len/page_size) pages that hold live data, so the
# step stages half the bytes.  That table-width economy is exactly
# what the scheduler's per-request page allocation buys.
from repro.dist.decode import local_paged_decode_attend

PS_PAGE = 64
for B, T in ((4, 2048), (4, 8192)):
    T_live = T // 2
    J = T_live // PS_PAGE                       # live pages per slot
    n_pages = B * J
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, PS_PAGE, KV, Dh))
    vp = jax.random.normal(ks[2], (n_pages, PS_PAGE, KV, Dh))
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * J
             + jnp.arange(J, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)
    # dense comparison cache: same live contents, (B, T) budget
    ck = jnp.zeros((B, T, KV, Dh)).at[:, :T_live].set(
        kp.reshape(B, T_live, KV, Dh))
    cv = jnp.zeros((B, T, KV, Dh)).at[:, :T_live].set(
        vp.reshape(B, T_live, KV, Dh))

    local = jax.jit(lambda q, k, v, c: decode_attend_local(
        q, k, v, jnp.arange(T), c))
    paged = jax.jit(lambda q, kp, vp, tb, ln: local_paged_decode_attend(
        q, kp, vp, tb, ln))
    t_dense = timed(local, q, ck, cv, jnp.int32(T_live))
    t_paged = timed(paged, q, kp, vp, table, lens)
    live_bytes = 2 * B * T_live * KV * Dh * 4
    budget_bytes = 2 * B * T * KV * Dh * 4
    rows.append({
        "op": "paged_decode", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_paged, 1), "us_ref": round(t_dense, 1),
        "flops": B * H * 2 * T_live * Dh * 2,
        "staged_bytes": live_bytes, "arith_intensity": None,
        "note": (f"page_size {PS_PAGE}, 50% occupancy: paged stages "
                 f"{live_bytes} live B/token vs the dense budget's "
                 f"{budget_bytes} B/token (us_ref = dense)"),
        "collective_bytes": None,
    })

# ---- MLA decode: split-operand vs concatenated cache -----------------
# The concat view (mla_absorbed_mqa) rebuilds k_cat + zero-padded v_cat
# copies of the latent+rope cache every step, so it STAGES
# 2*(r+rope) features/position; the split-operand decode_partial_mla
# path reads the latent cache once (scores AND values) plus the rope
# cache — r+rope features/position, a 2x staged-cache-bytes win the
# staged_bytes columns pin (dense and paged).
from repro.dist.decode import (local_mla_decode_attend,
                               local_mla_paged_decode_attend,
                               local_paged_decode_attend)
from repro.models.mla import mla_concat_view

R_LAT, ROPE = 256, 32                   # deepseek-shaped ratio r:rope
scale_mla = 1.0 / ((R_LAT + ROPE) ** 0.5)
for B, T in ((4, 2048), (4, 8192)):
    ks = jax.random.split(key, 4)
    q_abs = jax.random.normal(ks[0], (B, H, R_LAT))
    q_rope = jax.random.normal(ks[1], (B, H, ROPE))
    ckv = jax.random.normal(ks[2], (B, T, R_LAT))
    krope = jax.random.normal(ks[3], (B, T, ROPE))
    cur = jnp.int32(T)

    split = jax.jit(lambda qa, qr, ck, kr, c: local_mla_decode_attend(
        qa, qr, ck, kr, c, scale=scale_mla))

    def concat_attend(qa, qr, ck, kr, c):
        q_cat, k_cat, v_cat, r = mla_concat_view(qa, qr, ck, kr,
                                                 scale_mla)
        return decode_attend_local(q_cat, k_cat, v_cat, jnp.arange(T),
                                   c)[..., :r]

    concat = jax.jit(concat_attend)
    t_split = timed(split, q_abs, q_rope, ckv, krope, cur)
    t_concat = timed(concat, q_abs, q_rope, ckv, krope, cur)
    split_bytes = B * T * (R_LAT + ROPE) * 4
    concat_bytes = 2 * B * T * (R_LAT + ROPE) * 4
    shape = f"{B}x{T}x{H}x{R_LAT}+{ROPE}"
    flops = B * H * 2 * T * (R_LAT + ROPE + R_LAT)
    rows.append({
        "op": "mla_decode", "shape": shape, "us": round(t_split, 1),
        "us_ref": round(t_concat, 1), "flops": flops,
        "staged_bytes": split_bytes, "arith_intensity": None,
        "note": (f"mla_split: latent+rope as separate operands, "
                 f"{split_bytes} staged cache B/token "
                 f"({concat_bytes / split_bytes:.1f}x fewer than "
                 "mla_concat; us_ref = concat)"),
        "collective_bytes": None,
    })
    rows.append({
        "op": "mla_decode", "shape": shape, "us": round(t_concat, 1),
        "us_ref": None, "flops": flops,
        "staged_bytes": concat_bytes, "arith_intensity": None,
        "note": (f"mla_concat: k_cat + zero-padded v_cat cache copies "
                 f"rebuilt per step, {concat_bytes} staged cache "
                 "B/token"),
        "collective_bytes": None,
    })

# paged MLA: the concat view copies the whole POOL per step
for B, T in ((4, 2048),):
    T_live = T // 2
    J = T_live // PS_PAGE
    n_pages = B * J
    ks = jax.random.split(key, 4)
    q_abs = jax.random.normal(ks[0], (B, H, R_LAT))
    q_rope = jax.random.normal(ks[1], (B, H, ROPE))
    ckv_pool = jax.random.normal(ks[2], (n_pages, PS_PAGE, R_LAT))
    krope_pool = jax.random.normal(ks[3], (n_pages, PS_PAGE, ROPE))
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * J
             + jnp.arange(J, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)

    psplit = jax.jit(lambda qa, qr, ck, kr, tb, ln:
                     local_mla_paged_decode_attend(
                         qa, qr, ck, kr, tb, ln, scale=scale_mla))

    def concat_paged_attend(qa, qr, ck, kr, tb, ln):
        # mla_concat_view materializes whole-POOL k_cat/v_cat copies —
        # exactly the cost the split row avoids
        q_cat, k_cat, v_cat, r = mla_concat_view(qa, qr, ck, kr,
                                                 scale_mla)
        return local_paged_decode_attend(q_cat, k_cat, v_cat, tb,
                                         ln)[..., :r]

    pconcat = jax.jit(concat_paged_attend)
    t_psplit = timed(psplit, q_abs, q_rope, ckv_pool, krope_pool,
                     table, lens)
    t_pconcat = timed(pconcat, q_abs, q_rope, ckv_pool, krope_pool,
                      table, lens)
    split_bytes = B * T_live * (R_LAT + ROPE) * 4
    # concat copies the whole pool (k_cat + v_cat) before attending
    concat_bytes = 2 * n_pages * PS_PAGE * (R_LAT + ROPE) * 4 \
        + split_bytes
    shape = f"{B}x{T}x{H}x{R_LAT}+{ROPE}"
    rows.append({
        "op": "mla_decode_paged", "shape": shape,
        "us": round(t_psplit, 1), "us_ref": round(t_pconcat, 1),
        "flops": B * H * 2 * T_live * (R_LAT + ROPE + R_LAT),
        "staged_bytes": split_bytes, "arith_intensity": None,
        "note": (f"mla_split paged: pools stay separate, {split_bytes} "
                 f"staged cache B/token "
                 f"({concat_bytes / split_bytes:.1f}x fewer than "
                 "mla_concat's pool-wide copies; us_ref = concat)"),
        "collective_bytes": None,
    })
    rows.append({
        "op": "mla_decode_paged", "shape": shape,
        "us": round(t_pconcat, 1), "us_ref": None,
        "flops": B * H * 2 * T_live * (R_LAT + ROPE + R_LAT),
        "staged_bytes": concat_bytes, "arith_intensity": None,
        "note": (f"mla_concat paged: whole-pool k_cat/v_cat copies per "
                 f"step, {concat_bytes} staged cache B/token"),
        "collective_bytes": None,
    })

# ---- bucketed block tables: stage only live table columns ------------
# Fixed-width tables hold max_pages columns per slot (the jit-stable
# engine budget) even when every live slot owns a handful — the
# dead-column analogue of the dense cache's dead bytes.  Bucketing
# slices the table to the power-of-two width covering the longest
# slot (engine.paged_cache.bucket_table_width), converging on the
# live-table paged_decode row above.
from repro.engine.paged_cache import bucket_table_width

for B, T in ((4, 2048),):
    T_live = T // 2
    J_live = T_live // PS_PAGE                  # live pages per slot
    J_max = T // PS_PAGE                        # engine-wide budget
    n_pages = B * J_max
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, PS_PAGE, KV, Dh))
    vp = jax.random.normal(ks[2], (n_pages, PS_PAGE, KV, Dh))
    table = jnp.zeros((B, J_max), jnp.int32).at[:, :J_live].set(
        jnp.arange(B, dtype=jnp.int32)[:, None] * J_live
        + jnp.arange(J_live, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)
    W = bucket_table_width(J_live, J_max)

    paged = jax.jit(lambda q, kp, vp, tb, ln: local_paged_decode_attend(
        q, kp, vp, tb, ln))
    t_fixed = timed(paged, q, kp, vp, table, lens)
    t_bucket = timed(paged, q, kp, vp, table[:, :W], lens)
    live_bytes = 2 * B * T_live * KV * Dh * 4
    bucket_bytes = 2 * B * W * PS_PAGE * KV * Dh * 4
    fixed_bytes = 2 * B * J_max * PS_PAGE * KV * Dh * 4
    rows.append({
        "op": "paged_decode_bucketed", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_bucket, 1), "us_ref": round(t_fixed, 1),
        "flops": B * H * 2 * T_live * Dh * 2,
        "staged_bytes": bucket_bytes, "arith_intensity": None,
        "note": (f"table bucketed {J_max}->{W} cols at 50% occupancy: "
                 f"{bucket_bytes} staged B/token vs fixed-width "
                 f"{fixed_bytes} (live-table floor {live_bytes}; "
                 "us_ref = fixed-width)"),
        "collective_bytes": None,
    })

# ---- quantized KV pages: int8 pools + fp32 per-page scale sidecars ---
# Same live contents and page geometry as the paged rows above, stored
# int8 with one fp32 scale per page (per KV head for GQA, per page for
# the MLA latents).  The decode step stages ~half the bf16 pools'
# bytes per token — the sidecar adds 4 B per (page, head) against a
# page's page_size*Dh int8 payload — and the q8 ops dequantize inside
# the staged block (scale hoisted out of the int8 dot).
from repro.kernels.quant import quantize_int8

for B, T in ((4, 2048), (4, 8192)):
    T_live = T // 2
    J = T_live // PS_PAGE
    n_pages = B * J
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, PS_PAGE, KV, Dh))
    vp = jax.random.normal(ks[2], (n_pages, PS_PAGE, KV, Dh))
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * J
             + jnp.arange(J, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)
    kq, ksc = quantize_int8(kp, axis=(1, 3))
    vq, vsc = quantize_int8(vp, axis=(1, 3))
    ksc, vsc = ksc.reshape(n_pages, KV), vsc.reshape(n_pages, KV)

    bf16 = jax.jit(lambda q, kp, vp, tb, ln: local_paged_decode_attend(
        q, kp, vp, tb, ln))
    q8 = jax.jit(lambda q, kp, vp, ks_, vs_, tb, ln:
                 local_paged_decode_attend(q, kp, vp, tb, ln,
                                           k_scale=ks_, v_scale=vs_))
    t_bf16 = timed(bf16, q, kp.astype(jnp.bfloat16),
                   vp.astype(jnp.bfloat16), table, lens)
    t_q8 = timed(q8, q, kq, vq, ksc, vsc, table, lens)
    bf16_bytes = 2 * B * T_live * KV * Dh * 2
    q8_bytes = 2 * B * T_live * KV * Dh + 2 * B * J * KV * 4
    rows.append({
        "op": "paged_decode_q8", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_q8, 1), "us_ref": round(t_bf16, 1),
        "flops": B * H * 2 * T_live * Dh * 2,
        "staged_bytes": q8_bytes, "arith_intensity": None,
        "note": (f"int8 pages + per-page-per-head fp32 scales: "
                 f"{q8_bytes} staged cache B/token, "
                 f"{bf16_bytes / q8_bytes:.2f}x fewer than bf16 "
                 f"pools' {bf16_bytes} (us_ref = bf16 pools)"),
        "collective_bytes": None,
    })

for B, T in ((4, 2048), (4, 8192)):
    T_live = T // 2
    J = T_live // PS_PAGE
    n_pages = B * J
    ks = jax.random.split(key, 4)
    q_abs = jax.random.normal(ks[0], (B, H, R_LAT))
    q_rope = jax.random.normal(ks[1], (B, H, ROPE))
    ckv_pool = jax.random.normal(ks[2], (n_pages, PS_PAGE, R_LAT))
    krope_pool = jax.random.normal(ks[3], (n_pages, PS_PAGE, ROPE))
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * J
             + jnp.arange(J, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)
    cq, csc = quantize_int8(ckv_pool, axis=(1, 2))
    rq, rsc = quantize_int8(krope_pool, axis=(1, 2))
    csc, rsc = csc.reshape(n_pages), rsc.reshape(n_pages)

    mbf16 = jax.jit(lambda qa, qr, ck, kr, tb, ln:
                    local_mla_paged_decode_attend(
                        qa, qr, ck, kr, tb, ln, scale=scale_mla))
    mq8 = jax.jit(lambda qa, qr, ck, kr, cs, rs, tb, ln:
                  local_mla_paged_decode_attend(
                      qa, qr, ck, kr, tb, ln, scale=scale_mla,
                      ckv_scale=cs, krope_scale=rs))
    t_mbf16 = timed(mbf16, q_abs, q_rope,
                    ckv_pool.astype(jnp.bfloat16),
                    krope_pool.astype(jnp.bfloat16), table, lens)
    t_mq8 = timed(mq8, q_abs, q_rope, cq, rq, csc, rsc, table, lens)
    bf16_bytes = B * T_live * (R_LAT + ROPE) * 2
    q8_bytes = B * T_live * (R_LAT + ROPE) + 2 * B * J * 4
    shape = f"{B}x{T}x{H}x{R_LAT}+{ROPE}"
    rows.append({
        "op": "mla_decode_paged_q8", "shape": shape,
        "us": round(t_mq8, 1), "us_ref": round(t_mbf16, 1),
        "flops": B * H * 2 * T_live * (R_LAT + ROPE + R_LAT),
        "staged_bytes": q8_bytes, "arith_intensity": None,
        "note": (f"int8 latent pages + per-page fp32 scales "
                 f"(split-operand): {q8_bytes} staged cache B/token, "
                 f"{bf16_bytes / q8_bytes:.2f}x fewer than bf16 "
                 f"pools' {bf16_bytes} (us_ref = bf16 pools)"),
        "collective_bytes": None,
    })

# ---- full engine step: the production serve path ---------------------
from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig

B, P, G = 2, 32, 32
cfg = reduced(get_config("qwen1.5-0.5b"))
eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                     mesh_shape=(1, 8),
                                     decode_shard="seq"))
toks = jax.random.randint(key, (B, P), 2, cfg.vocab)
logits, cache = eng.prefill({"tokens": toks})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
dbatch = {"token": tok, "cur_len": jnp.int32(P), "cache": cache}
t_eng = timed(eng.decode_fn, eng.params, dbatch)
coll, kinds = hlo_analysis.collective_bytes(
    eng.decode_fn.lower(eng.params, dbatch).compile().as_text())
rows.append({
    "op": "engine_decode", "shape": f"{cfg.name}:{B}x{P + G}",
    "us": round(t_eng, 1), "us_ref": None, "flops": None,
    "staged_bytes": None, "arith_intensity": None,
    "note": (f"DecodeEngine one-token step, mesh (1,8) seq-sharded, "
             f"explicit mesh; collective {coll:.0f} B/token ({kinds})"),
    "collective_bytes": coll,
})

# ---- paged engine step: pool seq-sharded over 8 devices --------------
peng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                      mesh_shape=(1, 8),
                                      decode_shard="seq", paged=True,
                                      page_size=8),
                    params=eng.params)
logits_p, pcache = peng.prefill({"tokens": toks})
ptable = peng.default_block_table()
lens = jnp.full((B,), P, jnp.int32)
pbatch = {"token": tok, "cur_len": lens, "block_table": ptable,
          "cache": pcache}
t_peng = timed(peng.decode_fn, peng.params, pbatch)
coll_p, kinds_p = hlo_analysis.collective_bytes(
    peng.decode_fn.lower(peng.params, pbatch).compile().as_text())
rows.append({
    "op": "engine_decode_paged", "shape": f"{cfg.name}:{B}x{P + G}",
    "us": round(t_peng, 1), "us_ref": round(t_eng, 1), "flops": None,
    "staged_bytes": None, "arith_intensity": None,
    "note": (f"paged DecodeEngine one-token step (page_size 8, pool "
             f"seq-sharded over 8 shards, block-table combine); "
             f"collective {coll_p:.0f} B/token ({kinds_p}); "
             "us_ref = dense engine step"),
    "collective_bytes": coll_p,
})
# ---- scheduler pick: one fused (3,B) transfer vs per-slot syncs ------
# The fault-tolerant scheduler computes every slot's token choice
# (greedy argmax, seeded categorical, isfinite guard) in one jitted
# call and crosses the device boundary as a single (3, B) int32 stack;
# the naive loop pays 3 separate device->host round-trips per slot.
import numpy as np
from repro.engine.scheduler import Scheduler

B_, V = 8, 4096
lg = jax.random.normal(key, (B_, V))
seeds = jnp.arange(B_, dtype=jnp.int32)
steps = jnp.full((B_,), 3, jnp.int32)
temps = jnp.full((B_,), 0.7, jnp.float32)
pick = jax.jit(Scheduler._pick)


def batched():
    return np.asarray(pick(lg, seeds, steps, temps))


def per_slot():
    out = []
    for b in range(B_):
        k = jax.random.fold_in(jax.random.PRNGKey(b), 3)
        out.append(int(jnp.argmax(lg[b])))
        out.append(int(jax.random.categorical(k, lg[b] / 0.7)))
        out.append(bool(jnp.all(jnp.isfinite(lg[b]))))
    return out


fused = batched()
loop = per_slot()
assert [int(x) for x in fused[0]] == loop[0::3]      # greedy agrees
assert [int(x) for x in fused[1]] == loop[1::3]      # sampled agrees
t_fused = timed(batched)
t_loop = timed(per_slot)
rows.append({
    "op": "sched_pick", "shape": f"{B_}x{V}",
    "us": round(t_fused, 1), "us_ref": round(t_loop, 1),
    "flops": None, "staged_bytes": 3 * B_ * 4,
    "arith_intensity": None,
    "note": (f"batched pick: 1 fused (3,{B_}) int32 transfer/step vs "
             f"{3 * B_} per-slot device syncs (us_ref = per-slot "
             "loop); sampled/greedy streams bit-identical"),
    "collective_bytes": None,
})

# ---- prefix cache: warm vs cold TTFT on a shared system prompt -------
# Production traffic reuses a handful of system prompts; the radix
# cache turns that reuse into resident pages, so admission prefills
# only the per-request suffix.  TTFT here = submit -> first token
# (admission prefill + scatter + argmax), measured on a 100%-shared
# system prompt: cold pays the full P-token prefill, warm only the
# suffix — prefill FLOPs drop with the positions (attention is
# super-linear, so the wall-clock win grows with the prompt).
import time as _time

from repro.engine import Request, Scheduler

PS_PC, SYS_PAGES, SUF = 8, 12, 8
SYS = SYS_PAGES * PS_PC                         # 96 shared tokens
P_PC = SYS + SUF                                # 104-token prompts
pceng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=P_PC + 4,
                                       paged=True, page_size=PS_PC,
                                       n_pages=64, prefix_cache=True))
rng_pc = np.random.default_rng(0)


def _pc_prompt(sys_toks):
    suf = rng_pc.integers(2, cfg.vocab, (SUF,)).astype(np.int32)
    return np.concatenate([sys_toks, suf])


def _ttft(sched, rid, prompt):
    sched.submit(Request(rid=rid, tokens=prompt, gen=1))
    t0 = _time.perf_counter()
    assert sched.admit() == 1
    jax.block_until_ready(sched.cache)
    return (_time.perf_counter() - t0) * 1e6


sched_pc = Scheduler(pceng)
# warm-up: compile both the full-prompt and the suffix prefill paths
warm_sys = rng_pc.integers(2, cfg.vocab, (SYS,)).astype(np.int32)
_ttft(sched_pc, "w0", _pc_prompt(warm_sys))
_ttft(sched_pc, "w1", _pc_prompt(warm_sys))
sched_pc.prefix.clear()

sys_toks = rng_pc.integers(2, cfg.vocab, (SYS,)).astype(np.int32)
t_cold = _ttft(sched_pc, "cold", _pc_prompt(sys_toks))
warm = sorted(_ttft(sched_pc, f"warm{i}", _pc_prompt(sys_toks))
              for i in range(5))
t_warm = warm[len(warm) // 2]
hit_rate = sched_pc.stats["prefix_hits"] / max(
    1, sched_pc.stats["prefix_hits"] + sched_pc.stats["prefix_misses"])
# per-position prefill cost: attention O(S*T) + MLP O(S) — report the
# dominant linear term as the FLOPs column (positions actually run)
D = cfg.d_model
flops_cold = P_PC * (12 * D * D + 2 * P_PC * D)
flops_warm = SUF * (12 * D * D + 2 * P_PC * D)
rows.append({
    "op": "prefix_cache_decode",
    "shape": f"{cfg.name}:{P_PC}p/{SYS}shared",
    "us": round(t_warm, 1), "us_ref": round(t_cold, 1),
    "flops": flops_warm, "staged_bytes": None, "arith_intensity": None,
    "note": (f"TTFT warm {t_warm:.0f}us vs cold {t_cold:.0f}us "
             f"({t_cold / t_warm:.1f}x) on a 100%-shared {SYS}-token "
             f"system prompt: suffix-only prefill runs {SUF} of "
             f"{P_PC} positions ({flops_cold / flops_warm:.1f}x fewer "
             f"prefill FLOPs); hit rate {hit_rate:.2f}, "
             f"{sched_pc.stats['prefix_hit_tokens']} tokens from cache "
             "(us_ref = cold full prefill)"),
    "collective_bytes": None,
})

# ---- mixed stream: chunked prefill pins decode ITL p99 ---------------
# A long prompt entering a busy batch is the classic ITL-tail killer:
# non-chunked admission runs the WHOLE prefill while every decoding
# slot waits, so the waiting slots' inter-token latency spikes by the
# full prefill wall time.  The token-budget mixed step slices the
# prompt into chunk_tokens-sized pieces that ride inside ordinary
# decode steps, collapsing the tail from "one full prefill" to "one
# chunk".  us = chunked ITL p99 during the prefill window, us_ref =
# the same window under whole-prompt admission — bench_diff's
# speedup-shrink guard watches the us_ref/us ratio.
LONG_P, CT, PS_MX = 128, 16, 8
mxeng = DecodeEngine(cfg, EngineConfig(
    batch=4, max_len=LONG_P + 64, paged=True, page_size=PS_MX,
    n_pages=48, chunked_prefill=True, chunk_tokens=CT))
rng_mx = np.random.default_rng(0)
shorts_mx = [rng_mx.integers(2, cfg.vocab, (16,)).astype(np.int32)
             for _ in range(3)]
long_mx = rng_mx.integers(2, cfg.vocab, (LONG_P,)).astype(np.int32)


# _stream_gaps: wall gaps between the 3 decoding slots' token
# emissions while the long prompt is admitted + prefilled.  Every
# executed step emits one token per decoding slot, so the step-to-step
# gaps ARE those slots' inter-token latencies — and the non-chunked
# run folds the whole admission prefill into the first gap.
def _stream_gaps(chunked):
    sched = Scheduler(mxeng, chunked_prefill=chunked)
    for i, toks in enumerate(shorts_mx):
        sched.submit(Request(rid=f"s{i}", tokens=toks, gen=56))
    sched.admit()
    for _ in range(4):                      # shorts into steady decode
        sched.step()
    sched.submit(Request(rid="long", tokens=long_mx, gen=4))
    marks = [_time.perf_counter()]
    sched.admit()                  # non-chunked: full prefill in here
    for _ in range(LONG_P // CT):  # chunked: the long's 8 chunk steps
        sched.step()
        jax.block_until_ready(sched.cache)
        marks.append(_time.perf_counter())
    sched.run()                             # drain; frees every page
    return np.diff(marks) * 1e6


_stream_gaps(True)                          # compile the mixed step
_stream_gaps(False)                         # compile the long prefill
itl_mix = float(np.percentile(_stream_gaps(True), 99))
itl_base = float(np.percentile(_stream_gaps(False), 99))
rows.append({
    "op": "mixed_stream",
    "shape": f"{cfg.name}:{LONG_P}p/ct{CT}",
    "us": round(itl_mix, 1), "us_ref": round(itl_base, 1),
    "flops": None, "staged_bytes": None, "arith_intensity": None,
    "note": (f"decode ITL p99 while a {LONG_P}-token prompt prefills: "
             f"chunked {itl_mix:.0f}us vs whole-prompt admission "
             f"{itl_base:.0f}us ({itl_base / itl_mix:.1f}x lower "
             f"tail; chunk_tokens={CT}, 3 slots decoding; us_ref = "
             "non-chunked batch-1 admission)"),
    "collective_bytes": None,
})

# ---- snapshot_restore: durability cost of crash-safe serving ---------
# Snapshot a mid-stream scheduler (paged KV + live slots + queues) to
# disk, then restore it into a fresh Scheduler on the same engine.
# us = restore wall µs, us_ref = synchronous save wall µs,
# staged_bytes = on-disk snapshot size.  The snapshot captures the
# scheduler's own step counter, so recovery resumes from that step
# with zero recomputation — the note carries save/restore ms and the
# steps-to-resume figure (remaining decode steps replayed: 0).
import shutil as _shutil
import tempfile as _tempfile

from repro.engine.snapshot import restore as _sn_restore
from repro.engine.snapshot import snapshot as _sn_snapshot

sched_sn = Scheduler(mxeng, chunked_prefill=True)
rng_sn = np.random.default_rng(1)
for i in range(3):
    sched_sn.submit(Request(
        rid=f"sn{i}",
        tokens=rng_sn.integers(2, cfg.vocab, (24,)).astype(np.int32),
        gen=16))
sched_sn.admit()
for _ in range(6):                       # mid-stream: slots decoding
    sched_sn.step()
jax.block_until_ready(sched_sn.cache)
step_at_snap = int(sched_sn.stats["steps"])

d_sn = _tempfile.mkdtemp()
try:
    t0 = _time.perf_counter()
    snap_step = _sn_snapshot(sched_sn, d_sn)
    save_us = (_time.perf_counter() - t0) * 1e6
    snap_dir = os.path.join(d_sn, f"step_{snap_step}")
    snap_bytes = sum(
        os.path.getsize(os.path.join(root_, f_))
        for root_, _, files_ in os.walk(snap_dir) for f_ in files_)
    t0 = _time.perf_counter()
    sched_rs = _sn_restore(d_sn, mxeng)
    restore_us = (_time.perf_counter() - t0) * 1e6
finally:
    _shutil.rmtree(d_sn, ignore_errors=True)
assert int(sched_rs.stats["steps"]) == step_at_snap
sched_sn.run()                           # drain both; free every page
sched_rs.run()
rows.append({
    "op": "snapshot_restore",
    "shape": f"{cfg.name}:b4/p{PS_MX}x48",
    "us": round(restore_us, 1), "us_ref": round(save_us, 1),
    "flops": None, "staged_bytes": int(snap_bytes),
    "arith_intensity": None,
    "note": (f"engine snapshot {snap_bytes / 1e6:.1f} MB on disk: "
             f"save {save_us / 1e3:.1f}ms / restore "
             f"{restore_us / 1e3:.1f}ms at step {step_at_snap}, "
             "steps-to-resume 0 (restored scheduler continues from "
             "the captured step; us_ref = synchronous save)"),
    "collective_bytes": None,
})

print("JSON:" + json.dumps(rows))
"""


def dist_decode_bench(json_path="BENCH_kernels.json"):
    """Appends dist_decode rows to the kernel-bench JSON artifact."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    pp = os.environ.get("PYTHONPATH")
    env = {**os.environ,
           "PYTHONPATH": src + (os.pathsep + pp if pp else "")}
    r = subprocess.run([sys.executable, "-c", _CHILD],
                       capture_output=True, text=True, timeout=900,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"dist_decode child failed:\n{r.stderr[-2000:]}")
    payload = [ln for ln in r.stdout.splitlines()
               if ln.startswith("JSON:")][-1]
    rows = json.loads(payload[len("JSON:"):])
    print("\n# dist_decode: op,shape,us,us_ref,"
          "collective_bytes_per_token")
    for row in rows:
        coll = row["collective_bytes"]
        print(f"{row['op']},{row['shape']},{row['us']},{row['us_ref']},"
              f"{'-' if coll is None else format(coll, '.0f')}")
    if json_path:
        existing = []
        if os.path.exists(json_path):
            try:
                with open(json_path) as f:
                    existing = json.load(f)
            except ValueError:
                existing = []
        existing = [r for r in existing
                    if r.get("op") not in ("dist_decode", "engine_decode",
                                           "paged_decode",
                                           "engine_decode_paged",
                                           "mla_decode",
                                           "mla_decode_paged",
                                           "paged_decode_bucketed",
                                           "paged_decode_q8",
                                           "mla_decode_paged_q8",
                                           "sched_pick",
                                           "prefix_cache_decode",
                                           "mixed_stream",
                                           "snapshot_restore")]
        existing.extend(rows)
        with open(json_path, "w") as f:
            json.dump(existing, f, indent=1)
        print(f"# merged {len(rows)} dist_decode rows -> {json_path}")
    return rows


if __name__ == "__main__":
    dist_decode_bench()
