"""Distributed-decode benchmark: sharded vs local decode attention.

Runs in a subprocess with --xla_force_host_platform_device_count=8 (the
parent process has already locked jax to the visible device count), and
merges its rows into ``BENCH_kernels.json`` next to the kernel
micro-bench rows.

Per (B, T) cell:
  * local decode latency (``decode_attend_local`` on the full cache),
  * sharded decode latency (``dist.decode.sharded_flash_decode`` on a
    (1, 8) mesh — sequence-sharded cache, psum combine),
  * modeled per-token collective bytes from the compiled HLO
    (``hlo_analysis.collective_bytes``) — the headline number: the
    combine moves O(B*H*(Dh+2)) stat bytes instead of the O(B*T*KV*Dh)
    cache, independent of context length.

Plus one ``engine_decode`` row: a full one-token ``DecodeEngine`` step
(reduced arch, (1, 8) mesh, sequence-sharded cache, explicit mesh —
the production serve path) with its per-token collective bytes from
the engine's compiled decode step.

On a host-device CPU mesh the sharded latency is pure overhead
(interpret-mode kernels, emulated collectives); the latency columns
track the *trajectory*, the collective-bytes column is the modeled
production quantity.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import json
import time

import jax
import jax.numpy as jnp

from repro.dist.decode import sharded_flash_decode
from repro.launch import hlo_analysis
from repro.models.attention import decode_attend_local


def timed(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


rows = []
mesh = jax.make_mesh((1, 8), ("data", "model"))
key = jax.random.PRNGKey(0)
H, KV, Dh = 8, 2, 64
for B, T in ((4, 2048), (4, 8192)):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    ck = jax.random.normal(ks[1], (B, T, KV, Dh))
    cv = jax.random.normal(ks[2], (B, T, KV, Dh))
    cur = jnp.int32(T)

    local = jax.jit(lambda q, k, v, c: decode_attend_local(
        q, k, v, jnp.arange(T), c))
    shard = jax.jit(lambda q, k, v, c: sharded_flash_decode(
        mesh, q, k, v, c))
    t_local = timed(local, q, ck, cv, cur)
    t_shard = timed(shard, q, ck, cv, cur)
    coll, kinds = hlo_analysis.collective_bytes(
        shard.lower(q, ck, cv, cur).compile().as_text())
    cache_bytes = 2 * B * T * KV * Dh * 4
    rows.append({
        "op": "dist_decode", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_shard, 1), "us_ref": round(t_local, 1),
        "flops": B * H * 2 * T * Dh * 2, "staged_bytes": cache_bytes,
        "arith_intensity": None,
        "note": (f"mesh (1,8) seq-sharded; collective {coll:.0f} B/token"
                 f" vs cache {cache_bytes} B ({kinds})"),
        "collective_bytes": coll,
    })

# ---- paged vs dense decode attention ---------------------------------
# same (B, T) cells at 50% occupancy — the continuous-batching regime.
# The dense path streams its full (B, T) budget every step (dead bytes
# included: masking skips math, not DMA); the paged path's block table
# names only the ceil(len/page_size) pages that hold live data, so the
# step stages half the bytes.  That table-width economy is exactly
# what the scheduler's per-request page allocation buys.
from repro.dist.decode import local_paged_decode_attend

PS_PAGE = 64
for B, T in ((4, 2048), (4, 8192)):
    T_live = T // 2
    J = T_live // PS_PAGE                       # live pages per slot
    n_pages = B * J
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    kp = jax.random.normal(ks[1], (n_pages, PS_PAGE, KV, Dh))
    vp = jax.random.normal(ks[2], (n_pages, PS_PAGE, KV, Dh))
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * J
             + jnp.arange(J, dtype=jnp.int32)[None, :])
    lens = jnp.full((B,), T_live, jnp.int32)
    # dense comparison cache: same live contents, (B, T) budget
    ck = jnp.zeros((B, T, KV, Dh)).at[:, :T_live].set(
        kp.reshape(B, T_live, KV, Dh))
    cv = jnp.zeros((B, T, KV, Dh)).at[:, :T_live].set(
        vp.reshape(B, T_live, KV, Dh))

    local = jax.jit(lambda q, k, v, c: decode_attend_local(
        q, k, v, jnp.arange(T), c))
    paged = jax.jit(lambda q, kp, vp, tb, ln: local_paged_decode_attend(
        q, kp, vp, tb, ln))
    t_dense = timed(local, q, ck, cv, jnp.int32(T_live))
    t_paged = timed(paged, q, kp, vp, table, lens)
    live_bytes = 2 * B * T_live * KV * Dh * 4
    budget_bytes = 2 * B * T * KV * Dh * 4
    rows.append({
        "op": "paged_decode", "shape": f"{B}x{T}x{H}x{KV}x{Dh}",
        "us": round(t_paged, 1), "us_ref": round(t_dense, 1),
        "flops": B * H * 2 * T_live * Dh * 2,
        "staged_bytes": live_bytes, "arith_intensity": None,
        "note": (f"page_size {PS_PAGE}, 50% occupancy: paged stages "
                 f"{live_bytes} live B/token vs the dense budget's "
                 f"{budget_bytes} B/token (us_ref = dense)"),
        "collective_bytes": None,
    })

# ---- full engine step: the production serve path ---------------------
from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig

B, P, G = 2, 32, 32
cfg = reduced(get_config("qwen1.5-0.5b"))
eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                     mesh_shape=(1, 8),
                                     decode_shard="seq"))
toks = jax.random.randint(key, (B, P), 2, cfg.vocab)
logits, cache = eng.prefill({"tokens": toks})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
dbatch = {"token": tok, "cur_len": jnp.int32(P), "cache": cache}
t_eng = timed(eng.decode_fn, eng.params, dbatch)
coll, kinds = hlo_analysis.collective_bytes(
    eng.decode_fn.lower(eng.params, dbatch).compile().as_text())
rows.append({
    "op": "engine_decode", "shape": f"{cfg.name}:{B}x{P + G}",
    "us": round(t_eng, 1), "us_ref": None, "flops": None,
    "staged_bytes": None, "arith_intensity": None,
    "note": (f"DecodeEngine one-token step, mesh (1,8) seq-sharded, "
             f"explicit mesh; collective {coll:.0f} B/token ({kinds})"),
    "collective_bytes": coll,
})

# ---- paged engine step: pool seq-sharded over 8 devices --------------
peng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                      mesh_shape=(1, 8),
                                      decode_shard="seq", paged=True,
                                      page_size=8),
                    params=eng.params)
logits_p, pcache = peng.prefill({"tokens": toks})
ptable = peng.default_block_table()
lens = jnp.full((B,), P, jnp.int32)
pbatch = {"token": tok, "cur_len": lens, "block_table": ptable,
          "cache": pcache}
t_peng = timed(peng.decode_fn, peng.params, pbatch)
coll_p, kinds_p = hlo_analysis.collective_bytes(
    peng.decode_fn.lower(peng.params, pbatch).compile().as_text())
rows.append({
    "op": "engine_decode_paged", "shape": f"{cfg.name}:{B}x{P + G}",
    "us": round(t_peng, 1), "us_ref": round(t_eng, 1), "flops": None,
    "staged_bytes": None, "arith_intensity": None,
    "note": (f"paged DecodeEngine one-token step (page_size 8, pool "
             f"seq-sharded over 8 shards, block-table combine); "
             f"collective {coll_p:.0f} B/token ({kinds_p}); "
             "us_ref = dense engine step"),
    "collective_bytes": coll_p,
})
print("JSON:" + json.dumps(rows))
"""


def dist_decode_bench(json_path="BENCH_kernels.json"):
    """Appends dist_decode rows to the kernel-bench JSON artifact."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    pp = os.environ.get("PYTHONPATH")
    env = {**os.environ,
           "PYTHONPATH": src + (os.pathsep + pp if pp else "")}
    r = subprocess.run([sys.executable, "-c", _CHILD],
                       capture_output=True, text=True, timeout=900,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"dist_decode child failed:\n{r.stderr[-2000:]}")
    payload = [ln for ln in r.stdout.splitlines()
               if ln.startswith("JSON:")][-1]
    rows = json.loads(payload[len("JSON:"):])
    print("\n# dist_decode: op,shape,us,us_ref,"
          "collective_bytes_per_token")
    for row in rows:
        coll = row["collective_bytes"]
        print(f"{row['op']},{row['shape']},{row['us']},{row['us_ref']},"
              f"{'-' if coll is None else format(coll, '.0f')}")
    if json_path:
        existing = []
        if os.path.exists(json_path):
            try:
                with open(json_path) as f:
                    existing = json.load(f)
            except ValueError:
                existing = []
        existing = [r for r in existing
                    if r.get("op") not in ("dist_decode", "engine_decode",
                                           "paged_decode",
                                           "engine_decode_paged")]
        existing.extend(rows)
        with open(json_path, "w") as f:
            json.dump(existing, f, indent=1)
        print(f"# merged {len(rows)} dist_decode rows -> {json_path}")
    return rows


if __name__ == "__main__":
    dist_decode_bench()
