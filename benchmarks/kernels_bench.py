"""Kernel micro-benchmarks: VWR Pallas kernels (interpret mode on CPU)
vs the XLA-compiled jnp reference.  On CPU the interesting output is
the arithmetic-intensity / staged-bytes table (the VWR width-ratio
knob) plus the fused-vs-unfused epilogue and zero-copy-GQA
comparisons; on a real TPU the same harness times Mosaic kernels.

Every row also lands in a machine-readable ``BENCH_kernels.json`` so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    # one warmup call (compile + autotune), then per-rep timed runs;
    # the median is robust to scheduler noise on shared CPU runners
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def _time_paired(fn_a, fn_b, *args, reps=60):
    """Interleave single reps of two variants so both sample the same
    noise environment; report each variant's p10 (µs)."""
    jax.block_until_ready(fn_a(*args))
    jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(reps):
        for fn, ts in ((fn_a, ta), (fn_b, tb)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return ta[reps // 10] * 1e6, tb[reps // 10] * 1e6


def _row(rows, op, shape, us, *, us_ref=None, flops=None, staged=None,
         note=""):
    ai = (flops / staged) if (flops and staged) else None
    rows.append({
        "op": op, "shape": "x".join(map(str, shape)), "us": round(us, 1),
        "us_ref": None if us_ref is None else round(us_ref, 1),
        "flops": flops, "staged_bytes": staged,
        "arith_intensity": None if ai is None else round(ai, 3),
        "note": note,
    })
    print(f"{op},{rows[-1]['shape']},{us:.0f},"
          f"{'' if us_ref is None else f'{us_ref:.0f}'},{flops},{staged},"
          f"{'' if ai is None else f'{ai:.2f}'},{note}")


def kernel_microbench(json_path="BENCH_kernels.json"):
    key = jax.random.PRNGKey(0)
    print("\n# kernel_microbench: op,shape,us_pallas,us_xla_ref,"
          "flops,staged_bytes,arith_intensity,note")
    rows = []

    # ---- matmul: the VWR block-size knob (bm, bk, bn) sets the
    # arithmetic intensity = flops / staged HBM bytes
    M = K = N = 256
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32)
    for bm, bk, bn in ((64, 64, 64), (128, 128, 128), (256, 256, 256)):
        t_p = _time(lambda a, b: ops.vwr_matmul(a, b, bm=bm, bk=bk,
                                                bn=bn), x, w)
        t_r = _time(ref.matmul_ref, x, w)
        flops = 2 * M * K * N
        n_blocks = (M // bm) * (N // bn) * (K // bk)
        staged = n_blocks * (bm * bk + bk * bn) * 4 + M * N * 4
        _row(rows, "vwr_matmul", (M, K, N), t_p, us_ref=t_r, flops=flops,
             staged=staged, note=f"b{bm}x{bk}x{bn}")

    # ---- fused epilogue vs the unfused two-pass path: the fused
    # kernel applies bias+act+residual on the fp32 accumulator in the
    # final-K store; the unfused path round-trips the (M, N) output
    # through HBM (plus the fp32 cast round-trip the pre-fusion models
    # layer paid) for a second elementwise pass.  Measured in bf16 —
    # the models' serving dtype — with paired interleaved reps so both
    # variants see the same scheduler noise; p10 of 60 reps is stable
    # on shared CPU runners where a median of 3 coin-flips.
    xb = x.astype(jnp.bfloat16)
    wb = w.astype(jnp.bfloat16)
    bias = jax.random.normal(key, (N,), jnp.float32).astype(jnp.bfloat16)
    resid = jax.random.normal(key, (M, N), jnp.float32).astype(jnp.bfloat16)
    bm = bk = bn = 256
    epilogue = jax.jit(lambda out, c, r: r + jax.nn.relu(
        (out + c).astype(jnp.float32)).astype(out.dtype))

    def unfused(a, b, c, r):
        return epilogue(ops.vwr_matmul(a, b, bm=bm, bk=bk, bn=bn), c, r)

    def fused(a, b, c, r):
        return ops.vwr_matmul(a, b, c, r, activation="relu",
                              bm=bm, bk=bk, bn=bn)

    t_un, t_fu = _time_paired(unfused, fused, xb, wb, bias, resid)
    flops = 2 * M * K * N
    staged_un = (bm * bk + bk * bn) * 2 + 3 * M * N * 2 + M * N * 2
    staged_fu = (bm * bk + bk * bn) * 2 + 2 * M * N * 2
    _row(rows, "matmul_bias_relu_res_unfused", (M, K, N), t_un,
         flops=flops, staged=staged_un, note="two-pass bf16")
    _row(rows, "matmul_bias_relu_res_fused", (M, K, N), t_fu,
         flops=flops, staged=staged_fu,
         note=f"fused epilogue bf16, {t_un / t_fu:.2f}x vs unfused")

    # ---- dual-matmul fused swiglu vs the three-pass composition (two
    # separate matmuls staging x twice + the g*h elementwise HBM pass)
    wg = jax.random.normal(key, (K, N), jnp.float32).astype(jnp.bfloat16)

    def swiglu_unfused(a, g_w, i_w):
        g = ops.vwr_matmul(a, g_w, activation="silu",
                           bm=bm, bk=bk, bn=bn)
        return g * ops.vwr_matmul(a, i_w, bm=bm, bk=bk, bn=bn)

    def swiglu_fused(a, g_w, i_w):
        return ops.vwr_swiglu(a, g_w, i_w, bm=bm, bk=bk, bn=bn)

    t_su, t_sf = _time_paired(swiglu_unfused, swiglu_fused, xb, wg, wb,
                              reps=30)
    f_s = 2 * 2 * M * K * N
    staged_su = 2 * (bm * bk + bk * bn) * 2 + 4 * M * N * 2
    staged_sf = (bm * bk + 2 * bk * bn) * 2 + M * N * 2
    _row(rows, "swiglu_unfused", (M, K, N), t_su, flops=f_s,
         staged=staged_su, note="two matmuls + g*h pass, bf16")
    _row(rows, "swiglu_dual_fused", (M, K, N), t_sf, flops=f_s,
         staged=staged_sf,
         note=f"shared-LHS dual matmul bf16, {t_su / t_sf:.2f}x")

    # ---- direct conv vs depthwise (the reuse cliff the paper targets)
    x4 = jax.random.normal(key, (1, 34, 34, 64), jnp.float32)
    wf = jax.random.normal(key, (3, 3, 64, 64), jnp.float32)
    wd = jax.random.normal(key, (3, 3, 64), jnp.float32)
    t_c = _time(lambda a, b: ops.vwr_conv2d(a, b, bh=8, bf=64), x4, wf)
    t_cr = _time(ref.conv2d_ref, x4, wf)
    f_c = 2 * 32 * 32 * 64 * 64 * 9
    _row(rows, "vwr_conv2d_3x3", x4.shape, t_c, us_ref=t_cr, flops=f_c,
         staged=x4.size * 4 + wf.size * 4)
    t_d = _time(lambda a, b: ops.vwr_depthwise(a, b, bh=8), x4, wd)
    t_dr = _time(ref.depthwise_ref, x4, wd)
    f_d = 2 * 32 * 32 * 64 * 9
    _row(rows, "vwr_depthwise_3x3", x4.shape, t_d, us_ref=t_dr, flops=f_d,
         staged=x4.size * 4 + wd.size * 4)

    # ---- conv fused bias+relu epilogue vs the two-pass composition
    # (the elementwise HBM round-trip the ProVet CNN demo used to pay)
    bias_c = jax.random.normal(key, (64,), jnp.float32)
    conv_epi = jax.jit(lambda out, c: jax.nn.relu(out + c))

    def conv_unfused(a, b, c):
        return conv_epi(ops.vwr_conv2d(a, b, bh=8, bf=64), c)

    def conv_fused(a, b, c):
        return ops.vwr_conv2d(a, b, c, activation="relu", bh=8, bf=64)

    t_cu, t_cf = _time_paired(conv_unfused, conv_fused, x4, wf, bias_c,
                              reps=30)
    out_elems = 32 * 32 * 64
    staged_cu = x4.size * 4 + wf.size * 4 + 3 * out_elems * 4
    staged_cf = x4.size * 4 + wf.size * 4 + out_elems * 4
    _row(rows, "conv_bias_relu_unfused", x4.shape, t_cu, flops=f_c,
         staged=staged_cu, note="two-pass")
    _row(rows, "conv_bias_relu_fused", x4.shape, t_cf, flops=f_c,
         staged=staged_cf,
         note=f"fused epilogue, {t_cu / t_cf:.2f}x vs unfused")

    # ---- attention block-size sweep (KV staging width = the VWR width)
    B, S, H, D = 4, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H, D), jnp.float32)
    f_a = B * H * 2 * S * S * D * 2
    for bkv in (64, 128, 256):
        t_a = _time(lambda a, b, c: ops.vwr_attention(
            a, b, c, causal=True, bq=64, bkv=bkv), q, k, v)
        staged = q.size * 4 + 2 * k.size * 4
        _row(rows, "vwr_attention", (B, S, H, D), t_a, flops=f_a,
             staged=staged, note=f"bq64 bkv{bkv}")

    # ---- zero-copy GQA: K/V stay at their native KV-head count; the
    # head-expanded layout (the old jnp.repeat path) stages G x more
    # K/V bytes for identical outputs
    KV = 1
    G = H // KV
    kg = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    vg = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    t_gqa = _time(lambda a, b, c: ops.vwr_attention(
        a, b, c, causal=True, bq=64, bkv=128), q, kg, vg)
    t_rep = _time(lambda a, b, c: ops.vwr_attention(
        jnp.asarray(a), jnp.repeat(b, G, axis=2), jnp.repeat(c, G, axis=2),
        causal=True, bq=64, bkv=128), q, kg, vg)
    staged_zero = 2 * kg.size * 4
    staged_rep = staged_zero * G
    _row(rows, "vwr_attention_gqa_repeat", (B, S, H, KV, D), t_rep,
         flops=f_a, staged=staged_rep, note=f"materialized G={G} copies")
    _row(rows, "vwr_attention_gqa_zerocopy", (B, S, H, KV, D), t_gqa,
         flops=f_a, staged=staged_zero,
         note=f"kv bytes {staged_rep / staged_zero:.0f}x lower")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows -> {json_path}")
    return rows
