"""Kernel micro-benchmarks: VWR Pallas kernels (interpret mode on CPU)
vs the XLA-compiled jnp reference.  On CPU the interesting output is
the arithmetic-intensity table (the VWR width-ratio knob), not wall
time; on a real TPU the same harness times Mosaic kernels."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_microbench():
    key = jax.random.PRNGKey(0)
    print("\n# kernel_microbench: name,us_pallas_interp,us_xla_ref,"
          "flops,staged_bytes,arith_intensity")
    rows = []

    # matmul: arithmetic intensity = flops / staged HBM bytes; the VWR
    # block-size knob (bm, bk, bn) sets it
    M = K = N = 256
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32)
    for bm, bk, bn in ((64, 64, 64), (128, 128, 128), (256, 256, 256)):
        t_p = _time(lambda a, b: ops.vwr_matmul(a, b, bm=bm, bk=bk,
                                                bn=bn), x, w)
        t_r = _time(ref.matmul_ref, x, w)
        flops = 2 * M * K * N
        n_blocks = (M // bm) * (N // bn) * (K // bk)
        staged = n_blocks * (bm * bk + bk * bn + bm * bn) * 4
        rows.append((f"vwr_matmul_b{bm}", t_p, t_r, flops, staged))
        print(f"vwr_matmul_b{bm}x{bk}x{bn},{t_p:.0f},{t_r:.0f},{flops},"
              f"{staged},{flops/staged:.2f}")

    # direct conv vs depthwise (the reuse cliff the paper targets)
    x = jax.random.normal(key, (1, 34, 34, 64), jnp.float32)
    wf = jax.random.normal(key, (3, 3, 64, 64), jnp.float32)
    wd = jax.random.normal(key, (3, 3, 64), jnp.float32)
    t_c = _time(lambda a, b: ops.vwr_conv2d(a, b, bh=8, bf=64), x, wf)
    t_cr = _time(ref.conv2d_ref, x, wf)
    f_c = 2 * 32 * 32 * 64 * 64 * 9
    print(f"vwr_conv2d_3x3,{t_c:.0f},{t_cr:.0f},{f_c},"
          f"{x.size*4 + wf.size*4},{f_c/(x.size*4+wf.size*4):.2f}")
    t_d = _time(lambda a, b: ops.vwr_depthwise(a, b, bh=8), x, wd)
    t_dr = _time(ref.depthwise_ref, x, wd)
    f_d = 2 * 32 * 32 * 64 * 9
    print(f"vwr_depthwise_3x3,{t_d:.0f},{t_dr:.0f},{f_d},"
          f"{x.size*4 + wd.size*4},{f_d/(x.size*4+wd.size*4):.2f}")

    # attention block-size sweep (KV staging width = the VWR width)
    q = jax.random.normal(key, (4, 256, 4, 64), jnp.float32)
    k = jax.random.normal(key, (4, 256, 4, 64), jnp.float32)
    v = jax.random.normal(key, (4, 256, 4, 64), jnp.float32)
    for bkv in (64, 128, 256):
        t_a = _time(lambda a, b, c: ops.vwr_attention(
            a, b, c, causal=True, bq=64, bkv=bkv), q, k, v)
        f_a = 4 * 4 * 2 * 256 * 256 * 64 * 2
        staged = (256 // bkv) * 0 + q.size * 4 + 2 * k.size * 4
        print(f"vwr_attention_bkv{bkv},{t_a:.0f},,{f_a},{staged},"
              f"{f_a/staged:.2f}")
    return rows
