"""One function per paper table/figure (§7 reproduction).

Each prints a CSV block and returns the rows; ours and the paper's
published values are side by side so deviations are visible, not
hidden.  Absolute units differ from the paper where its units are
unrecoverable (reads: our model counts 8-bit words; the paper's unit
is unstated) — the comparison object is the RATIO structure.
"""
from __future__ import annotations

import time

from repro.core import analysis as A


def fig9_utilization():
    """PE utilization per layer per architecture (Fig. 9)."""
    suite = A.run_suite()
    print("\n# fig9_utilization: layer," + ",".join(A.MODELS))
    rows = []
    for lname, res in suite.items():
        row = [res[a].utilization for a in A.MODELS]
        rows.append((lname, row))
        print(f"{lname}," + ",".join(f"{u:.4f}" for u in row))
    return rows


def fig10_cmr():
    """Compute-to-memory ratio per layer per architecture (Fig. 10),
    word-normalized (macs per global-buffer word read)."""
    suite = A.run_suite()
    print("\n# fig10_cmr: layer," + ",".join(A.MODELS))
    rows = []
    for lname, res in suite.items():
        row = [res[a].cmr for a in A.MODELS]
        rows.append((lname, row))
        print(f"{lname}," + ",".join(f"{c:.2f}" for c in row))
    return rows


def table3_improvements():
    """Provet improvement ratios vs each baseline (Table 3), ours and
    the paper's published numbers interleaved."""
    imp = A.improvement_table()
    archs = ["Eyeriss", "TPU", "ARA", "GPU"]
    print("\n# table3: layer," + ",".join(
        f"util_{a}_ours,util_{a}_paper,cmr_{a}_ours,cmr_{a}_paper"
        for a in archs))
    rows = []
    for lname, t in imp.items():
        pu = A.PAPER_TABLE3[lname]["utilization"]
        pc = A.PAPER_TABLE3[lname]["cmr"]
        vals = []
        for a in archs:
            vals += [t["utilization"][a], pu[a], t["cmr"][a], pc[a]]
        rows.append((lname, vals))
        print(f"{lname}," + ",".join(f"{v:.2f}" for v in vals))
    return rows


def table4_reads_latency():
    """Global-buffer reads + latency per layer (Table 4). Ours in
    Mwords / ms@200MHz; paper values echoed for reference."""
    suite = A.run_suite()
    print("\n# table4: layer,arch,reads_Mw_ours,lat_ms_ours,"
          "reads_paper,lat_paper")
    rows = []
    for lname, res in suite.items():
        paper = A.PAPER_TABLE4[lname][1]
        for a in A.MODELS:
            r = res[a]
            pr, pl = paper[a.replace("GPU", "GPU")] if a in paper else \
                paper.get(a, (float("nan"), float("nan")))
            rows.append((lname, a, r.reads_mwords, r.latency_ms, pr, pl))
            print(f"{lname},{a},{r.reads_mwords:.3f},{r.latency_ms:.3f},"
                  f"{pr},{pl}")
    return rows


def conv_isa_demo():
    """§6.1 mapping executed on the ISA interpreter (timing + counters
    — the cycle-level reproduction artifact)."""
    import numpy as np

    from repro.core import ref_ops, templates
    from repro.core.machine import PAPER_EXAMPLE

    rng = np.random.default_rng(0)
    img = rng.standard_normal((1, 16, 16)).astype(np.float32)
    w = rng.standard_normal((1, 1, 5, 5)).astype(np.float32)
    mp = templates.conv2d(PAPER_EXAMPLE, img, w)
    t0 = time.perf_counter()
    out, m = mp.run()
    dt = (time.perf_counter() - t0) * 1e6
    err = float(abs(out - ref_ops.conv2d_ref(img, w)).max())
    util = m.utilization(mp.meta["total_macs"])
    print("\n# conv_isa_demo: us_per_run,maxerr,cycles,sram_reads,"
          "sram_writes,cmr_instr,utilization,energy_nj")
    print(f"conv_6_1,{dt:.0f},{err:.2e},{m.c.cycles},{m.c.sram_reads},"
          f"{m.c.sram_writes},{m.cmr():.2f},{util:.3f},"
          f"{m.c.energy_fj/1e6:.2f}")
    return m.c.as_dict()
