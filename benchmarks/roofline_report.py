"""Roofline table from dry-run artifacts (deliverable g).

Reads artifacts/dryrun/<arch>.<shape>.single.json and prints per-cell:
three roofline terms, dominant bottleneck, MODEL_FLOPS/HLO ratio, and
the estimated per-chip HBM footprint (memory_analysis temp+args are
whole-module numbers on the CPU backend: divided by device count)."""
from __future__ import annotations

import json
import os

from repro.common.config import SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.configs import ARCHS, get_config
from repro.launch import roofline as RL


def load_roofline_rows(artifact_dir="artifacts/dryrun", mesh="single"):
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            rec = RL.load_cell(artifact_dir, arch, shape.name, mesh)
            if not ok:
                rows.append({"arch": arch, "shape": shape.name,
                             "status": "skipped", "reason": reason})
                continue
            if rec is None or rec.get("status") != "ok":
                rows.append({"arch": arch, "shape": shape.name,
                             "status": "missing"})
                continue
            chips = rec["n_devices"]
            # per-device HLO numbers -> global
            flops_g = rec.get("flops_accounted_global") or \
                rec["flops"] * chips
            bytes_analytic = RL.analytic_traffic(cfg, shape)
            coll_g = rec["collective_bytes"] * chips
            r = RL.Roofline(
                arch=arch, shape=shape.name, chips=chips,
                flops=flops_g, bytes_hbm=bytes_analytic,
                bytes_coll=coll_g,
                model_flops=RL.model_flops(cfg, shape)
                + RL.attention_flops(cfg, shape)).finalize()
            row = r.row()
            row["status"] = "ok"
            row["hbm_per_chip_gb"] = (
                rec.get("temp_size_in_bytes", 0)
                + rec.get("argument_size_in_bytes", 0)) / chips / 2**30
            row["flops_raw_scanned"] = rec["flops"]
            # HLO-derived byte bounds (per-device -> global); see
            # roofline.analytic_traffic for the bias discussion
            row["bytes_hlo_raw"] = rec["bytes_accessed"] * chips
            row["bytes_hlo_major"] = rec["major_bytes"] * chips
            row["compile_s"] = rec.get("compile_s")
            rows.append(row)
    return rows


def roofline_table(artifact_dir="artifacts/dryrun"):
    rows = load_roofline_rows(artifact_dir)
    print("\n# roofline: arch,shape,t_comp_s,t_mem_s,t_coll_s,dominant,"
          "useful_frac,roofline_frac,hbm_per_chip_gb")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']},{r['shape']},,,,{r['status']},,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['t_comp_s']:.4e},"
              f"{r['t_mem_s']:.4e},{r['t_coll_s']:.4e},{r['dominant']},"
              f"{r['useful_frac']:.3f},{r['roofline_frac']:.3f},"
              f"{r['hbm_per_chip_gb']:.2f}")
    return rows
