"""Benchmark driver: one function per paper table/figure + kernel
micro-benches + the roofline report (when dry-run artifacts exist).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks.dist_decode import dist_decode_bench
from benchmarks.kernels_bench import kernel_microbench
from benchmarks.paper_tables import (conv_isa_demo, fig9_utilization,
                                     fig10_cmr, table3_improvements,
                                     table4_reads_latency)
from benchmarks.roofline_report import roofline_table
from benchmarks.shuffler_cost import table1_shuffler_cost
from benchmarks.sram_energy import fig2b_sram_energy


def main() -> None:
    benches = [
        ("fig9_utilization", fig9_utilization),
        ("fig10_cmr", fig10_cmr),
        ("table3_improvements", table3_improvements),
        ("table4_reads_latency", table4_reads_latency),
        ("fig2b_sram_energy", fig2b_sram_energy),
        ("table1_shuffler_cost", table1_shuffler_cost),
        ("conv_isa_demo", conv_isa_demo),
        # perf trajectory across PRs: op, shape, us, staged bytes,
        # arithmetic intensity per kernel variant
        ("kernel_microbench",
         lambda: kernel_microbench(json_path="BENCH_kernels.json")),
        # sharded vs local decode latency + modeled collective bytes
        # (subprocess: needs its own 8-device host platform)
        ("dist_decode",
         lambda: dist_decode_bench(json_path="BENCH_kernels.json")),
        ("roofline_table_baseline", roofline_table),
        ("roofline_table_optimized",
         lambda: roofline_table("artifacts/dryrun_opt")
         if os.path.isdir("artifacts/dryrun_opt") else None),
    ]
    failures = []
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            fn()
            print(f"## {name}: {(time.perf_counter()-t0)*1e3:.0f} ms")
        except Exception:                                  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("BENCH FAILURES:", failures)
        raise SystemExit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
