"""Table 1 reproduction: Provet shuffler vs generic crossbar cost."""
from __future__ import annotations

from repro.core.machine import (PAPER_TABLE1_ENDPOINTS, PAPER_TABLE1_REACH,
                                crossbar_cost, shuffler_cost)

PAPER = {"shuffler": {"area_mm2": 0.13, "gates": 16e3, "wire_mm": 4.3},
         "crossbar": {"area_mm2": 0.88, "gates": 86e3, "wire_mm": 33.1}}


def table1_shuffler_cost():
    sh = shuffler_cost(PAPER_TABLE1_ENDPOINTS, PAPER_TABLE1_REACH)
    xb = crossbar_cost(PAPER_TABLE1_ENDPOINTS)
    print("\n# table1: design,gates_ours,gates_paper,area_ours,"
          "area_paper,wire_ours,wire_paper")
    print(f"shuffler,{sh['gates']:.0f},{PAPER['shuffler']['gates']:.0f},"
          f"{sh['area_mm2']:.3f},{PAPER['shuffler']['area_mm2']},"
          f"{sh['wire_mm']:.1f},{PAPER['shuffler']['wire_mm']}")
    print(f"crossbar,{xb['gates']:.0f},{PAPER['crossbar']['gates']:.0f},"
          f"{xb['area_mm2']:.3f},{PAPER['crossbar']['area_mm2']},"
          f"{xb['wire_mm']:.1f},{PAPER['crossbar']['wire_mm']}")
    print(f"ratio_gates,{xb['gates']/sh['gates']:.2f},5.38,,,,")
    print(f"ratio_area,{xb['area_mm2']/sh['area_mm2']:.2f},6.82,,,,")
    print(f"ratio_wire,{xb['wire_mm']/sh['wire_mm']:.2f},7.67,,,,")
    return {"shuffler": sh, "crossbar": xb}
