"""Fig. 2b reproduction: per-bit SRAM access energy vs aspect ratio at
fixed capacity (eq. 1-2 + CACTI-flavoured constants)."""
from __future__ import annotations

from repro.core.machine import aspect_ratio_sweep


def fig2b_sram_energy(capacity_kbits=(64, 256, 1024)):
    print("\n# fig2b_sram_energy: capacity_kbit,width_bits,depth,"
          "e_per_bit_fj,bw_bits_per_cycle")
    rows = []
    for cap in capacity_kbits:
        sweep = aspect_ratio_sweep(cap * 1024)
        for w in sorted(sweep):
            r = sweep[w]
            rows.append((cap, w, r["depth"], r["e_per_bit_fj"],
                         r["bw_bits_per_cycle"]))
            print(f"{cap},{w},{r['depth']},{r['e_per_bit_fj']:.3f},"
                  f"{r['bw_bits_per_cycle']}")
    # the paper's claim: monotone decrease of e/bit with width
    for cap in capacity_kbits:
        sweep = aspect_ratio_sweep(cap * 1024)
        es = [sweep[w]["e_per_bit_fj"] for w in sorted(sweep)]
        assert all(a > b for a, b in zip(es, es[1:]))
    return rows
