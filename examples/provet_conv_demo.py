"""The paper's §6.1 CONV mapping, end to end on the ISA machine:
layout -> instruction stream -> cycle/access counters -> §7 metrics,
plus the §6.2 folding/packing variants.

    PYTHONPATH=src python examples/provet_conv_demo.py
"""
import numpy as np

from repro.core import analysis, ref_ops, templates
from repro.core.machine import PAPER_EXAMPLE, ProvetConfig

rng = np.random.default_rng(0)

# --- the exact §6.1 example -------------------------------------------
img = rng.standard_normal((1, 16, 16)).astype(np.float32)
w = rng.standard_normal((1, 1, 5, 5)).astype(np.float32)
mp = templates.conv2d(PAPER_EXAMPLE, img, w)
out, m = mp.run()
print("§6.1 conv: 5x5 kernel, 16x16 image, 16-lane VFU, 64-op SRAM")
print(f"  maxerr vs numpy: {abs(out - ref_ops.conv2d_ref(img, w)).max():.2e}")
print(f"  instruction mix: {m.c.instr_mix}")
print(f"  cycles={m.c.cycles} sram R/W={m.c.sram_reads}/{m.c.sram_writes}"
      f" vwr R/W={m.c.vwr_reads}/{m.c.vwr_writes}")
print(f"  CMR (eq.4) = {m.cmr():.2f};"
      f" utilization (eq.3) = {m.utilization(mp.meta['total_macs']):.3f}")
print(f"  energy = {m.c.energy_fj/1e6:.2f} nJ")

# --- §6.2.1: image wider than the datapath ----------------------------
img = rng.standard_normal((1, 8, 40)).astype(np.float32)
w = rng.standard_normal((1, 1, 3, 3)).astype(np.float32)
parts = [(templates.conv2d(ProvetConfig(), s, w).run()[0], off)
         for s, off in templates.partition_image(img, 16, 3)]
full = templates.stitch_strips(parts, 38)
print(f"\n§6.2.1 partition: strips={len(parts)} "
      f"maxerr={abs(full - ref_ops.conv2d_ref(img, w)).max():.2e}")

# --- §6.2.2: two images packed into the lanes -------------------------
imgs = [rng.standard_normal((1, 8, 6)).astype(np.float32) for _ in range(2)]
packed, spans = templates.pack_width(imgs, 16, 3)
out, _ = templates.conv2d(ProvetConfig(), packed, w).run()
errs = [abs(out[:, :, o:o + wd - 2] - ref_ops.conv2d_ref(im, w)).max()
        for (o, wd), im in zip(spans, imgs)]
print(f"§6.2.2 packing: 2 images, maxerr={max(errs):.2e}")

# --- §7 analytical suite ----------------------------------------------
print("\n§7 suite (ours):  layer        Provet_util  Provet_CMR")
for lname, res in analysis.run_suite().items():
    p = res["Provet"]
    print(f"  {lname:<14} {p.utilization:10.3f} {p.cmr:10.1f}")

# --- TPU twin: the same conv with the fused bias+relu epilogue --------
# The Pallas version of the §6.1 dataflow (kernels/vwr_conv2d) now
# applies conv -> bias -> relu in the single output store — the CNN
# epilogue no longer pays a second elementwise HBM pass.
import jax
import jax.numpy as jnp

from repro.kernels import ops

jx = jnp.asarray(rng.standard_normal((1, 16, 16, 8)), jnp.float32)
jw = jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32)
jb = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
fused = ops.vwr_conv2d(jx, jw, jb, activation="relu")
two_pass = jax.nn.relu(ops.vwr_conv2d(jx, jw) + jb)
print(f"\nPallas fused conv epilogue: maxerr vs two-pass ="
      f" {float(jnp.abs(fused - two_pass).max()):.2e}"
      f" (one HBM round-trip instead of three)")
