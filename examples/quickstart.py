"""Quickstart: build an assigned architecture, run a forward/train step,
inspect the Provet reproduction artifacts.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import lm
from repro.optim import adamw
from repro.launch.steps import build_train_step

# ---- 1. an assigned architecture (reduced for CPU) -------------------
cfg = reduced(get_config("olmoe-1b-7b"))
print(f"arch: {cfg.name} family={cfg.family} "
      f"params={cfg.n_params()/1e6:.2f}M")

params = lm.init(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": tokens,
         "loss_mask": jnp.ones_like(tokens, jnp.float32)}

loss, metrics = jax.jit(lambda p, b: lm.train_loss(p, b, cfg))(params, batch)
print(f"loss={float(loss):.4f} moe_drop={float(metrics['drop_frac']):.3f}")

# ---- 2. one optimizer step -------------------------------------------
opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=100)
step = jax.jit(build_train_step(cfg, opt_cfg))
opt = adamw.init(opt_cfg, params)
params, opt, m = step(params, opt, batch)
print(f"after 1 step: loss={float(m['loss']):.4f} "
      f"gnorm={float(m['grad_norm']):.2f}")

# ---- 3. the paper's machine ------------------------------------------
import numpy as np
from repro.core import templates, ref_ops
from repro.core.machine import PAPER_EXAMPLE

img = np.random.default_rng(0).standard_normal((1, 16, 16)).astype("f4")
w = np.random.default_rng(1).standard_normal((1, 1, 5, 5)).astype("f4")
out, machine = templates.conv2d(PAPER_EXAMPLE, img, w).run()
err = abs(out - ref_ops.conv2d_ref(img, w)).max()
print(f"Provet ISA conv (§6.1): maxerr={err:.2e} "
      f"cycles={machine.c.cycles} CMR={machine.cmr():.2f}")

# ---- 4. a VWR Pallas kernel (interpret mode on CPU) ------------------
from repro.kernels import ops as kops
x = jax.random.normal(jax.random.PRNGKey(2), (128, 256))
wm = jax.random.normal(jax.random.PRNGKey(3), (256, 128))
y = kops.vwr_matmul(x, wm, bm=64, bk=128, bn=64)
print(f"vwr_matmul err={float(jnp.abs(y - x @ wm).max()):.2e}")
print("quickstart OK")
