"""Serving example: batch decode through the DecodeEngine.

The engine owns the mesh (explicit — no ``with mesh:`` context), the
sharded params, the decode-cache layouts, and the jitted
prefill/decode steps; generation is three calls.

    PYTHONPATH=src python examples/serve_batch.py            # batch decode
    PYTHONPATH=src python examples/serve_batch.py --stream   # continuous
                                                             # batching
    PYTHONPATH=src python examples/serve_batch.py --stream --inject
                                          # + chaos leg: injected NaN /
                                          # transient fault / pool
                                          # pressure; survivors must be
                                          # bit-identical
    PYTHONPATH=src python examples/serve_batch.py --stream --prefix-cache
                                          # + radix prefix cache leg:
                                          # shared system prompt, hit
                                          # rate > 0, streams identical
                                          # to the cache-off scheduler
                                          # (add --inject for the
                                          # chaos + no-leak pass)
    PYTHONPATH=src python examples/serve_batch.py --stream --chunked-prefill
                                          # + mixed-traffic leg: one
                                          # long prompt chunk-prefills
                                          # INSIDE the decode steps of
                                          # many short requests — no
                                          # decoding slot ever stalls
                                          # (add --inject for the
                                          # mid-chunk transient-fault
                                          # retry pass)
    PYTHONPATH=src python examples/serve_batch.py --stream --arrival-rate 0.7
                                          # seeded Poisson arrivals
                                          # (requests per decode step)
                                          # instead of the scripted
                                          # stagger
    PYTHONPATH=src python examples/serve_batch.py --stream \
        --crash-at 6 --snapshot-every 2   # + crash-recovery leg: the
                                          # journaled, snapshot-cadenced
                                          # stream is killed at step 6
                                          # (CrashFault), restored from
                                          # the latest snapshot + journal
                                          # replay, and must finish
                                          # bit-identical to the
                                          # crash-free run (composes
                                          # with --prefix-cache /
                                          # --chunked-prefill /
                                          # --kv-dtype)
    # any paged-family text arch (dense/vlm/moe — recurrent ssm/hybrid
    # state doesn't page, and the audio demo would need frontend_emb),
    # e.g. the deepseek-style MLA config (paged split-operand MLA
    # decode end to end):
    PYTHONPATH=src python examples/serve_batch.py --stream \
        --model deepseek-v3-671b
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig, Request, Scheduler


def _model_arg(default="qwen1.5-0.5b"):
    if "--model" in sys.argv:
        i = sys.argv.index("--model") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit("usage: serve_batch.py [--stream] [--model ARCH]")
        return sys.argv[i]
    return default


def _kv_dtype_arg():
    """--kv-dtype {bf16,int8}: page-pool storage for the demos."""
    if "--kv-dtype" in sys.argv:
        i = sys.argv.index("--kv-dtype") + 1
        if i >= len(sys.argv) or sys.argv[i] not in ("bf16", "int8"):
            sys.exit("usage: serve_batch.py [--kv-dtype {bf16,int8}]")
        return sys.argv[i]
    return "bf16"


def _int_arg(flag, default):
    """--flag N (crash step / snapshot cadence for the recovery leg)."""
    if flag in sys.argv:
        i = sys.argv.index(flag) + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit(f"usage: serve_batch.py [{flag} N]")
        return int(sys.argv[i])
    return default


def _arrival_rate_arg():
    """--arrival-rate R: seeded Poisson arrivals (requests per decode
    step) for the stream demo; None = the scripted stagger."""
    if "--arrival-rate" in sys.argv:
        i = sys.argv.index("--arrival-rate") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit("usage: serve_batch.py [--arrival-rate R]")
        return float(sys.argv[i])
    return None


def stream_demo():
    """Continuous batching on the paged engine: staggered request
    arrival and retirement over 2 slots and a shared page pool —
    request 2 is only admitted once a short request retires and frees
    its slot + pages, and the surviving request keeps decoding without
    being re-prefilled.  Decode steps run with bucketed block tables
    (the default), so short-table phases of the stream stage fewer
    pages."""
    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2,                            # slots, not requests
        max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla", kv_dtype=kv_dtype,
    ))
    sched = Scheduler(engine)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"req{i}", tokens=rng.integers(
                2, cfg.vocab, (p,)).astype(np.int32), gen=g)
            for i, (p, g) in enumerate([(24, 4), (16, 12), (8, 6)])]

    sched.submit(reqs[0])
    sched.submit(reqs[1])
    sched.admit()                           # both slots fill
    assert sched.n_active == 2
    while "req0" not in sched.finished:     # short request retires first
        sched.step()
    sched.submit(reqs[2])                   # late arrival...
    sched.admit()                           # ...takes the freed slot
    assert sched.n_active == 2
    out = sched.run()
    assert set(out) == {"req0", "req1", "req2"}
    assert all(len(out[r.rid]) == r.gen for r in reqs)
    # one prefill per request: survivors were never re-prefilled when
    # slots turned over around them
    assert sched.stats["prefills"] == 3
    widths = dict(sorted(sched.stats["table_widths"].items()))
    print(f"[stream] {cfg.name}: 3 staggered requests over 2 slots, "
          f"{sched.stats['steps']} steps, peak pages "
          f"{sched.stats['peak_pages']}/{engine.n_pages}, table-width "
          f"buckets {widths} (max_pages {engine.max_pages})")
    for r in reqs:
        print(f"    {r.rid}: {len(r.tokens)} prompt -> {out[r.rid]}")
    print("stream example OK")


def inject_demo():
    """Chaos leg: the same staggered stream, but with a NaN-poisoned
    slot, a transient decode exception, and artificial page-pool
    pressure injected (``engine.faults``).  The stream still completes:
    only the poisoned request ends FAILED (keeping its pre-fault token
    prefix), the transient fault heals through one bounded retry, and
    every surviving stream is bit-identical to the fault-free run."""
    from repro.engine import RequestStatus, faults

    cfg = reduced(get_config(_model_arg()))
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=_kv_dtype_arg(),
    ))
    rng = np.random.default_rng(0)
    specs = [(24, 4), (16, 12), (8, 6)]
    prompts = [rng.integers(2, cfg.vocab, (p,)).astype(np.int32)
               for p, _ in specs]

    def run(with_faults):
        sched = Scheduler(engine)
        release = None
        if with_faults:
            faults.inject(sched, decode_faults=[
                faults.NonFiniteLogits(step=1, slot=0),
                faults.TransientError(step=4)])
            release = faults.hold_pages(sched, 1)
        for i, (_, g) in enumerate(specs):
            sched.submit(Request(rid=f"req{i}", tokens=prompts[i],
                                 gen=g))
        out = sched.run()
        if release is not None:
            release()
        return sched, out

    _, clean = run(False)
    sched, out = run(True)
    assert set(out) == set(clean)
    # the poisoned slot held req0: it fails with its pre-fault prefix
    assert out["req0"].status is RequestStatus.FAILED
    assert "non-finite" in out["req0"].error
    assert np.array_equal(out["req0"],
                          np.asarray(clean["req0"])[:len(out["req0"])])
    # the transient fault healed through one bounded retry, and the
    # survivors' streams never diverged
    assert sched.stats["step_retries"] == 1
    for rid in ("req1", "req2"):
        assert out[rid].ok
        assert np.array_equal(out[rid], clean[rid])
    assert sched.allocator.free_pages == engine.n_pages
    print(f"[inject] {cfg.name}: req0 FAILED at the injected NaN "
          f"(kept {len(out['req0'])} pre-fault tokens), 1 step retry, "
          "survivors bit-identical to the fault-free stream")
    print("inject example OK")


def poisson_demo(rate):
    """Seeded Poisson arrivals: requests arrive as a Poisson process at
    ``rate`` requests per decode step (exponential inter-arrival gaps
    from a fixed-seed rng — same rate, same trace) instead of the
    scripted stagger.  The scheduler absorbs the burstiness: every
    request finishes with its full generation, and the table-width
    buckets show admission riding the arrival process."""
    cfg = reduced(get_config(_model_arg()))
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=_kv_dtype_arg(),
    ))
    sched = Scheduler(engine)
    rng = np.random.default_rng(0)
    n = 6
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    reqs = [Request(rid=f"req{i}",
                    tokens=rng.integers(
                        2, cfg.vocab,
                        (int(rng.integers(4, 20)),)).astype(np.int32),
                    gen=int(rng.integers(3, 9)))
            for i in range(n)]

    t, i = 0, 0
    while i < n or sched.n_active or sched.pending:
        while i < n and arrivals[i] <= t:
            sched.submit(reqs[i])
            i += 1
        sched.admit()
        if sched.n_active:
            sched.step()
        t += 1
        assert t < 10_000, "poisson stream failed to drain"
    out = sched.results()
    assert len(out) == n and all(out[r.rid].ok for r in reqs)
    assert all(len(out[r.rid]) == r.gen for r in reqs)
    itl = sched.itl_percentiles()
    print(f"[poisson] {cfg.name}: {n} requests, rate {rate:g}/step "
          f"(arrival steps {[round(float(a), 1) for a in arrivals]}), "
          f"{sched.stats['steps']} steps, "
          f"ITL p50/p99 {itl['p50'] * 1e3:.1f}/{itl['p99'] * 1e3:.1f} ms")
    print("poisson example OK")


def mixed_demo():
    """Mixed-traffic leg (chunked prefill): three short requests decode
    while a 40-token prompt arrives and chunk-prefills INSIDE their
    decode steps — the token-budget packer grants the in-flight prompt
    one ``chunk_tokens`` slice per unified step, so no decoding slot
    ever waits on the long prefill.  Asserted hard: during the entire
    prefill window every RUNNING slot emits a token on every step
    (zero stall steps), and the final streams are bit-identical to the
    non-chunked scheduler on the same engine.

    With ``--kv-dtype int8`` the long request's identity is relaxed:
    its chunks k>=1 read the already-quantized prefix where the
    non-chunked prefill saw full precision, so a near-tie argmax may
    flip (the short prompts fit in one chunk and stay exact)."""
    from repro.engine import RequestStatus

    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    engine = DecodeEngine(cfg, EngineConfig(
        batch=4, max_len=64, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla", kv_dtype=kv_dtype,
        chunked_prefill=True, chunk_tokens=8,
    ))
    rng = np.random.default_rng(3)
    shorts = [rng.integers(2, cfg.vocab, (6,)).astype(np.int32)
              for _ in range(3)]
    long_prompt = rng.integers(2, cfg.vocab, (40,)).astype(np.int32)

    def reqs():
        rs = [Request(rid=f"short{i}", tokens=t, gen=14)
              for i, t in enumerate(shorts)]
        rs.append(Request(rid="long", tokens=long_prompt, gen=4))
        return rs

    sched = Scheduler(engine)
    rs = reqs()
    for r in rs[:3]:
        sched.submit(r)
    sched.admit()
    while any(s is not None and s.req.status is RequestStatus.PREFILLING
              for s in sched.slots):
        sched.step()                        # drain the shorts' chunks
    sched.submit(rs[3])
    sched.admit()                           # long enters PREFILLING

    stall_steps, window = 0, 0
    while any(s is not None and s.req.status is RequestStatus.PREFILLING
              for s in sched.slots):
        before = {s.req.rid: len(s.out) for s in sched.slots
                  if s is not None
                  and s.req.status is RequestStatus.RUNNING}
        sched.step()
        window += 1
        after = {s.req.rid: len(s.out) for s in sched.slots
                 if s is not None
                 and s.req.status is RequestStatus.RUNNING}
        stall_steps += sum(1 for rid in before
                           if rid in after and after[rid] <= before[rid])
    # 40 tokens / 8-token chunks = 5 mixed steps, zero decode stalls
    assert window == 5 and stall_steps == 0, (window, stall_steps)
    out = sched.run()
    assert all(out[r.rid].ok and len(out[r.rid]) == r.gen for r in rs)

    base = Scheduler(engine, chunked_prefill=False)
    for r in reqs():
        base.submit(r)
    ref = base.run()
    for r in rs:
        if kv_dtype == "bf16" or r.rid != "long":
            assert np.array_equal(out[r.rid], ref[r.rid]), r.rid
    st = sched.stats
    itl = sched.itl_percentiles()
    ident = ("streams bit-identical to the non-chunked scheduler"
             if kv_dtype == "bf16" else
             "short streams bit-identical (the int8 long prompt's "
             "chunks re-read the quantized prefix: near-ties may flip)")
    print(f"[mixed] {cfg.name}: 40-token prompt prefilled in "
          f"{st['chunks']} chunks across {st['mixed_steps']} mixed "
          f"steps while 3 short requests decoded — {stall_steps} stall "
          f"steps, ITL p99 {itl['p99'] * 1e3:.1f} ms — {ident}")
    print("mixed example OK")


def chunk_chaos_demo():
    """Chaos over a chunking stream: a transient fault lands mid-way
    through the long prompt's chunk sequence (the shared decode/mixed
    call counter makes step index 5 a mixed step here).  The bounded
    retry redoes THAT CHUNK ONLY — the successful-chunk count matches
    the clean run, completed chunks are never re-prefilled, and every
    stream (long included, any kv dtype: both runs take the identical
    chunked path) is bit-identical to the fault-free chunked run."""
    from repro.engine import faults

    cfg = reduced(get_config(_model_arg()))
    engine = DecodeEngine(cfg, EngineConfig(
        batch=4, max_len=64, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=_kv_dtype_arg(),
        chunked_prefill=True, chunk_tokens=8,
    ))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab, (6,)).astype(np.int32)
               for _ in range(3)]
    prompts.append(rng.integers(2, cfg.vocab, (40,)).astype(np.int32))
    gens = [14, 14, 14, 4]

    def run(with_fault):
        sched = Scheduler(engine)
        proxy = None
        if with_fault:
            # steps 0-2 chunk the three short prompts; steps 3-7 are
            # the long prompt's five chunks -> step 5 is mid-sequence
            proxy = faults.inject(sched, decode_faults=[
                faults.TransientError(step=5)])
        for i, (p, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(rid=f"req{i}", tokens=p, gen=g))
        return sched, proxy, sched.run()

    _, _, clean_out = run(False)
    sched, proxy, out = run(True)
    assert sched.stats["step_retries"] == 1
    assert proxy.mixed_fn.injected == 1      # it hit a MIXED step
    clean_chunks = 3 + 5                     # 3 shorts + 40/8 chunks
    assert sched.stats["chunks"] == clean_chunks  # only 1 chunk redone
    for rid in out:
        assert out[rid].ok
        assert np.array_equal(out[rid], clean_out[rid]), rid
    assert sched.allocator.free_pages == engine.n_pages
    print(f"[chunk-chaos] {cfg.name}: transient fault on mixed step 5 "
          f"(chunk 3/5 of the long prompt) healed with 1 retry of that "
          f"chunk only ({sched.stats['chunks']} chunks total, same as "
          "clean); streams bit-identical, pool fully drained")
    print("chunk-chaos example OK")


def prefix_demo():
    """Prefix-cache leg: three requests, two sharing a 2-page system
    prompt, through the radix-cached scheduler.  Every token stream
    must be bit-identical to the cache-off scheduler (suffix-only
    prefill over aliased pages changes WHERE the prefix KV comes from,
    never the logits), with a nonzero hit rate and prompt tokens
    served from cache.

    With ``--kv-dtype int8`` bit-identity is asserted only for the
    cache-MISS requests: a hit's suffix prefill reads the prefix
    dequantized from the int8 pool where the cold prefill saw it in
    full precision, so a near-tie argmax can flip (decode itself reads
    the same quantized pages either way — the caveat is confined to
    the hit's prefill logits).

    With ``--inject`` a chaos pass rides on top: the same injected
    NaN / transient fault / page-pool pressure as ``inject_demo``, but
    with shared prefix pages live — the stream must still complete
    and, crucially, must not leak pages: after the trie is cleared the
    pool drains back to fully free (the shared-page double-free /
    leak regression check, end to end)."""
    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=kv_dtype, prefix_cache=True,
    ))
    rng = np.random.default_rng(0)
    sys_toks = rng.integers(2, cfg.vocab, (16,)).astype(np.int32)
    prompts = [np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (8,)).astype(np.int32)]),
               np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (4,)).astype(np.int32)]),
               rng.integers(2, cfg.vocab, (8,)).astype(np.int32)]
    gens = [6, 8, 5]

    def run(prefix_cache):
        sched = Scheduler(engine, prefix_cache=prefix_cache)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(rid=f"req{i}", tokens=p, gen=g))
        return sched, sched.run()

    _, clean = run(False)
    sched, out = run(True)
    # req1 is the cache hit (req0 inserts the system pages first);
    # req0/req2 prefill cold either way and must match exactly always
    hit_rids = {"req1"}
    for rid in out:
        assert out[rid].ok
        if kv_dtype == "bf16" or rid not in hit_rids:
            assert np.array_equal(out[rid], clean[rid]), rid
    st = sched.stats
    assert st["prefix_hits"] >= 1 and st["prefix_hit_tokens"] >= 16
    hit_rate = st["prefix_hits"] / (st["prefix_hits"]
                                    + st["prefix_misses"])
    assert hit_rate > 0
    ident = ("streams bit-identical to the cache-off scheduler"
             if kv_dtype == "bf16" else
             "miss streams bit-identical (int8 hits read the "
             "dequantized prefix: near-ties may flip)")
    print(f"[prefix] {cfg.name}: 3 requests (2 share a 16-token system "
          f"prompt): hit rate {hit_rate:.2f}, "
          f"{st['prefix_hit_tokens']} prompt tokens from cache, peak "
          f"shared pages {st['shared_pages']} — {ident}")

    if "--inject" in sys.argv:
        from repro.engine import faults
        chaos = Scheduler(engine)
        faults.inject(chaos, decode_faults=[
            faults.NonFiniteLogits(step=1, slot=0),
            faults.TransientError(step=4)])
        release = faults.hold_pages(chaos, 1)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            chaos.submit(Request(rid=f"req{i}", tokens=p, gen=g))
        cout = chaos.run()
        release()
        assert set(cout) == set(out)
        assert chaos.stats["step_retries"] >= 1
        # no leak under faults: only the trie still holds pages, and
        # clearing it drains the pool completely
        chaos.allocator.check()
        chaos.prefix.check()
        assert chaos.allocator.free_pages == \
            engine.n_pages - chaos.prefix.cached_pages
        chaos.prefix.clear()
        assert chaos.allocator.free_pages == engine.n_pages
        print(f"[prefix+inject] chaos stream completed "
              f"({sum(1 for v in cout.values() if v.ok)}/{len(cout)} "
              "ok) with shared pages live; pool fully drained after "
              "trie clear — no page leak")
    print("prefix example OK")


def crash_recovery_demo(crash_at, snapshot_every):
    """Crash-recovery leg: the stream runs journaled and
    snapshot-cadenced under ``serve_with_recovery``, and a
    ``CrashFault`` kills the first attempt at step ``crash_at`` —
    deterministic simulated process death.  The restart loop restores
    the latest complete snapshot (page pool, block tables, per-slot
    RNG state, allocator free-list ORDER, prefix trie), replays the
    write-ahead journal (finished results verbatim, unseen submits
    re-queued), and finishes the drain.  Asserted hard: every stream
    is bit-identical to the crash-free reference, no result is lost,
    and no page leaks (allocator partition checked post-recovery).
    Composes with --prefix-cache / --chunked-prefill / --kv-dtype."""
    import tempfile

    from repro.engine import faults
    from repro.runtime.resilience import (RestartPolicy,
                                          serve_with_recovery)

    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    prefix = "--prefix-cache" in sys.argv
    chunked = "--chunked-prefill" in sys.argv
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla", kv_dtype=kv_dtype,
        prefix_cache=prefix, chunked_prefill=chunked, chunk_tokens=8,
    ))
    rng = np.random.default_rng(0)
    sys_toks = rng.integers(2, cfg.vocab, (16,)).astype(np.int32)
    prompts = [np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (8,)).astype(np.int32)]),
               np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (4,)).astype(np.int32)]),
               rng.integers(2, cfg.vocab, (24,)).astype(np.int32)]
    gens = [6, 8, 5]

    def submit(sched):
        for i, (p, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(rid=f"req{i}", tokens=p, gen=g))

    ref = Scheduler(engine)
    submit(ref)
    want = ref.run()

    attempts = []

    def on_start(sched, fresh):
        attempts.append(fresh)
        if fresh:       # the crash hits only the pre-recovery process
            faults.inject(sched, decode_faults=[
                faults.CrashFault(step=crash_at)])

    with tempfile.TemporaryDirectory() as d:
        sched = serve_with_recovery(
            engine, d, submit, snapshot_every=snapshot_every,
            policy=RestartPolicy(max_restarts=3, backoff_s=0.0),
            on_start=on_start)
        saved = sched.snapshotter.saved

    assert attempts[0] is True and False in attempts[1:], \
        "the crash never fired (raise --crash-at past the drain?)"
    assert set(sched.finished) == set(want), "a result was lost"
    for rid, res in want.items():
        got = sched.finished[rid]
        assert got.status is res.status, rid
        assert np.array_equal(np.asarray(got), np.asarray(res)), rid
    sched.allocator.check()
    cached = sched.prefix.cached_pages if sched.prefix is not None else 0
    assert sched.allocator.free_pages == engine.n_pages - cached, \
        "page leaked across the crash"
    print(f"[crash] {cfg.name}: killed at step {crash_at}, "
          f"{len(attempts)} attempts, {saved} snapshots (cadence "
          f"{snapshot_every or 'journal-only'}); all "
          f"{len(want)} streams bit-identical to the crash-free run, "
          "no page leaked")
    print("crash-recovery example OK")


if "--stream" in sys.argv:
    _rate = _arrival_rate_arg()
    if _rate is not None:
        poisson_demo(_rate)
    else:
        stream_demo()
    if "--inject" in sys.argv:
        inject_demo()
    if "--prefix-cache" in sys.argv:
        prefix_demo()
    if "--chunked-prefill" in sys.argv:
        mixed_demo()
        if "--inject" in sys.argv:
            chunk_chaos_demo()
    if "--crash-at" in sys.argv:
        crash_recovery_demo(_int_arg("--crash-at", 6),
                            _int_arg("--snapshot-every", 2))
    sys.exit(0)

B, P, G = 4, 32, 16

cfg = reduced(get_config(_model_arg()))
engine = DecodeEngine(cfg, EngineConfig(
    batch=B, max_len=P + G,
    mesh_shape=(jax.device_count(), 1),   # (data, model)
    kernel_impl="xla",                    # or 'pallas' / 'auto'
))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
tokens, stats = engine.generate({"tokens": prompts}, gen=G)

print(f"[engine] {engine.cfg.name}: mesh {dict(engine.mesh.shape)}; "
      f"prefill {stats['prefill_tok_s']:.0f} tok/s, "
      f"decode {stats['decode_tok_s']:.0f} tok/s")
for b in range(2):
    print("   gen:", np.asarray(tokens[b]))
assert tokens.shape == (B, G)

# the same engine also exposes the raw step API (continuous batching &
# speculative decoding build on these):
logits, cache = engine.prefill({"tokens": prompts})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
logits2, cache = engine.decode_step(tok, P, cache)
assert logits2.shape[0] == B
print("engine example OK")
