"""Serving example: batch decode through the DecodeEngine.

The engine owns the mesh (explicit — no ``with mesh:`` context), the
sharded params, the decode-cache layouts, and the jitted
prefill/decode steps; generation is three calls.

    PYTHONPATH=src python examples/serve_batch.py            # batch decode
    PYTHONPATH=src python examples/serve_batch.py --stream   # continuous
                                                             # batching
    PYTHONPATH=src python examples/serve_batch.py --stream --inject
                                          # + chaos leg: injected NaN /
                                          # transient fault / pool
                                          # pressure; survivors must be
                                          # bit-identical
    PYTHONPATH=src python examples/serve_batch.py --stream --prefix-cache
                                          # + radix prefix cache leg:
                                          # shared system prompt, hit
                                          # rate > 0, streams identical
                                          # to the cache-off scheduler
                                          # (add --inject for the
                                          # chaos + no-leak pass)
    # any paged-family text arch (dense/vlm/moe — recurrent ssm/hybrid
    # state doesn't page, and the audio demo would need frontend_emb),
    # e.g. the deepseek-style MLA config (paged split-operand MLA
    # decode end to end):
    PYTHONPATH=src python examples/serve_batch.py --stream \
        --model deepseek-v3-671b
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig, Request, Scheduler


def _model_arg(default="qwen1.5-0.5b"):
    if "--model" in sys.argv:
        i = sys.argv.index("--model") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit("usage: serve_batch.py [--stream] [--model ARCH]")
        return sys.argv[i]
    return default


def _kv_dtype_arg():
    """--kv-dtype {bf16,int8}: page-pool storage for the demos."""
    if "--kv-dtype" in sys.argv:
        i = sys.argv.index("--kv-dtype") + 1
        if i >= len(sys.argv) or sys.argv[i] not in ("bf16", "int8"):
            sys.exit("usage: serve_batch.py [--kv-dtype {bf16,int8}]")
        return sys.argv[i]
    return "bf16"


def stream_demo():
    """Continuous batching on the paged engine: staggered request
    arrival and retirement over 2 slots and a shared page pool —
    request 2 is only admitted once a short request retires and frees
    its slot + pages, and the surviving request keeps decoding without
    being re-prefilled.  Decode steps run with bucketed block tables
    (the default), so short-table phases of the stream stage fewer
    pages."""
    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2,                            # slots, not requests
        max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla", kv_dtype=kv_dtype,
    ))
    sched = Scheduler(engine)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"req{i}", tokens=rng.integers(
                2, cfg.vocab, (p,)).astype(np.int32), gen=g)
            for i, (p, g) in enumerate([(24, 4), (16, 12), (8, 6)])]

    sched.submit(reqs[0])
    sched.submit(reqs[1])
    sched.admit()                           # both slots fill
    assert sched.n_active == 2
    while "req0" not in sched.finished:     # short request retires first
        sched.step()
    sched.submit(reqs[2])                   # late arrival...
    sched.admit()                           # ...takes the freed slot
    assert sched.n_active == 2
    out = sched.run()
    assert set(out) == {"req0", "req1", "req2"}
    assert all(len(out[r.rid]) == r.gen for r in reqs)
    # one prefill per request: survivors were never re-prefilled when
    # slots turned over around them
    assert sched.stats["prefills"] == 3
    widths = dict(sorted(sched.stats["table_widths"].items()))
    print(f"[stream] {cfg.name}: 3 staggered requests over 2 slots, "
          f"{sched.stats['steps']} steps, peak pages "
          f"{sched.stats['peak_pages']}/{engine.n_pages}, table-width "
          f"buckets {widths} (max_pages {engine.max_pages})")
    for r in reqs:
        print(f"    {r.rid}: {len(r.tokens)} prompt -> {out[r.rid]}")
    print("stream example OK")


def inject_demo():
    """Chaos leg: the same staggered stream, but with a NaN-poisoned
    slot, a transient decode exception, and artificial page-pool
    pressure injected (``engine.faults``).  The stream still completes:
    only the poisoned request ends FAILED (keeping its pre-fault token
    prefix), the transient fault heals through one bounded retry, and
    every surviving stream is bit-identical to the fault-free run."""
    from repro.engine import RequestStatus, faults

    cfg = reduced(get_config(_model_arg()))
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=_kv_dtype_arg(),
    ))
    rng = np.random.default_rng(0)
    specs = [(24, 4), (16, 12), (8, 6)]
    prompts = [rng.integers(2, cfg.vocab, (p,)).astype(np.int32)
               for p, _ in specs]

    def run(with_faults):
        sched = Scheduler(engine)
        release = None
        if with_faults:
            faults.inject(sched, decode_faults=[
                faults.NonFiniteLogits(step=1, slot=0),
                faults.TransientError(step=4)])
            release = faults.hold_pages(sched, 1)
        for i, (_, g) in enumerate(specs):
            sched.submit(Request(rid=f"req{i}", tokens=prompts[i],
                                 gen=g))
        out = sched.run()
        if release is not None:
            release()
        return sched, out

    _, clean = run(False)
    sched, out = run(True)
    assert set(out) == set(clean)
    # the poisoned slot held req0: it fails with its pre-fault prefix
    assert out["req0"].status is RequestStatus.FAILED
    assert "non-finite" in out["req0"].error
    assert np.array_equal(out["req0"],
                          np.asarray(clean["req0"])[:len(out["req0"])])
    # the transient fault healed through one bounded retry, and the
    # survivors' streams never diverged
    assert sched.stats["step_retries"] == 1
    for rid in ("req1", "req2"):
        assert out[rid].ok
        assert np.array_equal(out[rid], clean[rid])
    assert sched.allocator.free_pages == engine.n_pages
    print(f"[inject] {cfg.name}: req0 FAILED at the injected NaN "
          f"(kept {len(out['req0'])} pre-fault tokens), 1 step retry, "
          "survivors bit-identical to the fault-free stream")
    print("inject example OK")


def prefix_demo():
    """Prefix-cache leg: three requests, two sharing a 2-page system
    prompt, through the radix-cached scheduler.  Every token stream
    must be bit-identical to the cache-off scheduler (suffix-only
    prefill over aliased pages changes WHERE the prefix KV comes from,
    never the logits), with a nonzero hit rate and prompt tokens
    served from cache.

    With ``--kv-dtype int8`` bit-identity is asserted only for the
    cache-MISS requests: a hit's suffix prefill reads the prefix
    dequantized from the int8 pool where the cold prefill saw it in
    full precision, so a near-tie argmax can flip (decode itself reads
    the same quantized pages either way — the caveat is confined to
    the hit's prefill logits).

    With ``--inject`` a chaos pass rides on top: the same injected
    NaN / transient fault / page-pool pressure as ``inject_demo``, but
    with shared prefix pages live — the stream must still complete
    and, crucially, must not leak pages: after the trie is cleared the
    pool drains back to fully free (the shared-page double-free /
    leak regression check, end to end)."""
    cfg = reduced(get_config(_model_arg()))
    kv_dtype = _kv_dtype_arg()
    engine = DecodeEngine(cfg, EngineConfig(
        batch=2, max_len=48, paged=True, page_size=8,
        mesh_shape=(1, 1), kernel_impl="xla",
        kv_dtype=kv_dtype, prefix_cache=True,
    ))
    rng = np.random.default_rng(0)
    sys_toks = rng.integers(2, cfg.vocab, (16,)).astype(np.int32)
    prompts = [np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (8,)).astype(np.int32)]),
               np.concatenate([sys_toks, rng.integers(
                   2, cfg.vocab, (4,)).astype(np.int32)]),
               rng.integers(2, cfg.vocab, (8,)).astype(np.int32)]
    gens = [6, 8, 5]

    def run(prefix_cache):
        sched = Scheduler(engine, prefix_cache=prefix_cache)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(rid=f"req{i}", tokens=p, gen=g))
        return sched, sched.run()

    _, clean = run(False)
    sched, out = run(True)
    # req1 is the cache hit (req0 inserts the system pages first);
    # req0/req2 prefill cold either way and must match exactly always
    hit_rids = {"req1"}
    for rid in out:
        assert out[rid].ok
        if kv_dtype == "bf16" or rid not in hit_rids:
            assert np.array_equal(out[rid], clean[rid]), rid
    st = sched.stats
    assert st["prefix_hits"] >= 1 and st["prefix_hit_tokens"] >= 16
    hit_rate = st["prefix_hits"] / (st["prefix_hits"]
                                    + st["prefix_misses"])
    assert hit_rate > 0
    ident = ("streams bit-identical to the cache-off scheduler"
             if kv_dtype == "bf16" else
             "miss streams bit-identical (int8 hits read the "
             "dequantized prefix: near-ties may flip)")
    print(f"[prefix] {cfg.name}: 3 requests (2 share a 16-token system "
          f"prompt): hit rate {hit_rate:.2f}, "
          f"{st['prefix_hit_tokens']} prompt tokens from cache, peak "
          f"shared pages {st['shared_pages']} — {ident}")

    if "--inject" in sys.argv:
        from repro.engine import faults
        chaos = Scheduler(engine)
        faults.inject(chaos, decode_faults=[
            faults.NonFiniteLogits(step=1, slot=0),
            faults.TransientError(step=4)])
        release = faults.hold_pages(chaos, 1)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            chaos.submit(Request(rid=f"req{i}", tokens=p, gen=g))
        cout = chaos.run()
        release()
        assert set(cout) == set(out)
        assert chaos.stats["step_retries"] >= 1
        # no leak under faults: only the trie still holds pages, and
        # clearing it drains the pool completely
        chaos.allocator.check()
        chaos.prefix.check()
        assert chaos.allocator.free_pages == \
            engine.n_pages - chaos.prefix.cached_pages
        chaos.prefix.clear()
        assert chaos.allocator.free_pages == engine.n_pages
        print(f"[prefix+inject] chaos stream completed "
              f"({sum(1 for v in cout.values() if v.ok)}/{len(cout)} "
              "ok) with shared pages live; pool fully drained after "
              "trie clear — no page leak")
    print("prefix example OK")


if "--stream" in sys.argv:
    stream_demo()
    if "--inject" in sys.argv:
        inject_demo()
    if "--prefix-cache" in sys.argv:
        prefix_demo()
    sys.exit(0)

B, P, G = 4, 32, 16

cfg = reduced(get_config(_model_arg()))
engine = DecodeEngine(cfg, EngineConfig(
    batch=B, max_len=P + G,
    mesh_shape=(jax.device_count(), 1),   # (data, model)
    kernel_impl="xla",                    # or 'pallas' / 'auto'
))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
tokens, stats = engine.generate({"tokens": prompts}, gen=G)

print(f"[engine] {engine.cfg.name}: mesh {dict(engine.mesh.shape)}; "
      f"prefill {stats['prefill_tok_s']:.0f} tok/s, "
      f"decode {stats['decode_tok_s']:.0f} tok/s")
for b in range(2):
    print("   gen:", np.asarray(tokens[b]))
assert tokens.shape == (B, G)

# the same engine also exposes the raw step API (continuous batching &
# speculative decoding build on these):
logits, cache = engine.prefill({"tokens": prompts})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
logits2, cache = engine.decode_step(tok, P, cache)
assert logits2.shape[0] == B
print("engine example OK")
