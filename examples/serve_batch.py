"""Serving example (deliverable b): prefill a batch of prompts and
decode continuations with a KV cache.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--arch", "qwen1.5-0.5b", "--reduce", "smoke",
                "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    main(defaults + args)
