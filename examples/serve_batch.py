"""Serving example: batch decode through the DecodeEngine.

The engine owns the mesh (explicit — no ``with mesh:`` context), the
sharded params, the decode-cache layouts, and the jitted
prefill/decode steps; generation is three calls.

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig

B, P, G = 4, 32, 16

cfg = reduced(get_config("qwen1.5-0.5b"))
engine = DecodeEngine(cfg, EngineConfig(
    batch=B, max_len=P + G,
    mesh_shape=(jax.device_count(), 1),   # (data, model)
    kernel_impl="xla",                    # or 'pallas' / 'auto'
))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
tokens, stats = engine.generate({"tokens": prompts}, gen=G)

print(f"[engine] {engine.cfg.name}: mesh {dict(engine.mesh.shape)}; "
      f"prefill {stats['prefill_tok_s']:.0f} tok/s, "
      f"decode {stats['decode_tok_s']:.0f} tok/s")
for b in range(2):
    print("   gen:", np.asarray(tokens[b]))
assert tokens.shape == (B, G)

# the same engine also exposes the raw step API (continuous batching &
# speculative decoding build on these):
logits, cache = engine.prefill({"tokens": prompts})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
logits2, cache = engine.decode_step(tok, P, cache)
assert logits2.shape[0] == B
print("engine example OK")
