"""End-to-end driver (deliverable b): train a ~100M-param model for a
few hundred steps on CPU with checkpointing + restart resilience.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--arch", "tinyllama-1.1b", "--reduce", "width",
                "--steps", "200", "--batch", "8", "--seq", "256",
                "--ckpt", "/tmp/repro_100m_ckpt", "--ckpt-every", "50"]
    # user args win
    main(defaults + args)
