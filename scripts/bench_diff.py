"""Diff two BENCH_kernels.json artifacts and flag perf regressions.

    PYTHONPATH=src python scripts/bench_diff.py OLD.json NEW.json \
        [--threshold 0.10] [--fail]

Rows are matched by (op, shape, note, k) — the note disambiguates
variants sharing an op/shape cell (e.g. the ``mla_split`` vs
``mla_concat`` rows), with embedded measurements digit-stripped so a
re-run's jitter doesn't orphan the match, and ``k`` numbers rows whose
stripped key still collides (e.g. block-size sweeps whose notes differ
only in numbers), pairing them by emission order.  A matched row whose
``us`` grew by more than ``--threshold`` (default 10%) is flagged as a
regression, and so is a matched row whose ``staged_bytes`` column
(cache bytes staged per decode step — the quantized-KV benchmarks'
headline) grew by more than the same threshold.  Rows that carry a
within-run baseline in ``us_ref`` (e.g. the ``prefix_cache_decode``
row's warm-vs-cold TTFT, the ``mixed_stream`` row's chunked-vs-
monolithic-admission decode ITL p99, or the split-vs-concat MLA
rows) are
additionally checked on their SPEEDUP (``us_ref / us``): a speedup
that shrank by more than the threshold is flagged even when both
absolute latencies moved together — machine-load jitter cancels out
of the ratio, so this is the robust signal for headline wins like
"warm TTFT >= 2x cold".  ``--fail`` turns any kind of flag into a
nonzero exit for CI.  Unmatched rows (ops added/removed between the
two artifacts) are listed but never flagged.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Tuple


def _row_key(row: dict) -> Tuple[str, str, str]:
    """(op, shape, digit-stripped note): stable across re-runs whose
    notes embed measured values (collective bytes, ratios)."""
    note = re.sub(r"[\d.]+", "#", str(row.get("note") or ""))
    return (str(row.get("op")), str(row.get("shape")), note)


def _index(rows: List[dict]) -> Dict[Tuple[str, str, str, int], dict]:
    """Key every row; rows whose stripped key collides (block-size
    sweeps: notes differ only in numbers) get an occurrence index, so
    they pair by emission order instead of all-but-the-first being
    silently dropped."""
    out: Dict[Tuple[str, str, str, int], dict] = {}
    seen: Dict[Tuple[str, str, str], int] = {}
    for row in rows:
        base = _row_key(row)
        k = seen.get(base, 0)
        seen[base] = k + 1
        out[(*base, k)] = row
    return out


def diff(old_rows: List[dict], new_rows: List[dict],
         threshold: float = 0.10) -> dict:
    """Returns {'regressions': [...], 'improvements': [...],
    'byte_regressions': [...], 'speedup_regressions': [...],
    'only_old': [...], 'only_new': [...]} — latency entries carry the
    matched key and the old/new ``us``, byte entries the old/new
    ``staged_bytes``, speedup entries the old/new ``us_ref / us``."""
    old = _index(old_rows)
    new = _index(new_rows)
    regressions, improvements = [], []
    byte_regressions, speedup_regressions = [], []
    for key, n in new.items():
        o = old.get(key)
        if o is None:
            continue
        us_old, us_new = o.get("us"), n.get("us")
        if us_old and us_new:                 # None or 0: untimed row
            ratio = us_new / us_old
            entry = {"op": key[0], "shape": key[1],
                     "note": n.get("note"),
                     "us_old": us_old, "us_new": us_new,
                     "ratio": round(ratio, 3)}
            if ratio > 1.0 + threshold:
                regressions.append(entry)
            elif ratio < 1.0 - threshold:
                improvements.append(entry)
        ref_old, ref_new = o.get("us_ref"), n.get("us_ref")
        if us_old and us_new and ref_old and ref_new:
            # within-run baseline (TTFT cold, dense ref, ...): the
            # speedup us_ref/us cancels machine-load jitter; shrinking
            # means the headline win itself eroded
            sp_old, sp_new = ref_old / us_old, ref_new / us_new
            if sp_new < sp_old * (1.0 - threshold):
                speedup_regressions.append(
                    {"op": key[0], "shape": key[1],
                     "note": n.get("note"),
                     "speedup_old": round(sp_old, 3),
                     "speedup_new": round(sp_new, 3),
                     "ratio": round(sp_new / sp_old, 3)})
        b_old, b_new = o.get("staged_bytes"), n.get("staged_bytes")
        if b_old and b_new:
            bratio = b_new / b_old
            if bratio > 1.0 + threshold:
                byte_regressions.append(
                    {"op": key[0], "shape": key[1],
                     "note": n.get("note"),
                     "staged_bytes_old": b_old,
                     "staged_bytes_new": b_new,
                     "ratio": round(bratio, 3)})
    regressions.sort(key=lambda e: -e["ratio"])
    improvements.sort(key=lambda e: e["ratio"])
    byte_regressions.sort(key=lambda e: -e["ratio"])
    speedup_regressions.sort(key=lambda e: e["ratio"])
    return {
        "regressions": regressions,
        "improvements": improvements,
        "byte_regressions": byte_regressions,
        "speedup_regressions": speedup_regressions,
        "only_old": sorted(k[:2] for k in old.keys() - new.keys()),
        "only_new": sorted(k[:2] for k in new.keys() - old.keys()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff two BENCH_kernels.json files; flag >threshold "
                    "latency regressions on matching op/shape/note rows.")
    ap.add_argument("old", help="baseline BENCH_kernels.json")
    ap.add_argument("new", help="candidate BENCH_kernels.json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative us growth that counts as a "
                         "regression (default 0.10 = 10%%)")
    ap.add_argument("--fail", action="store_true",
                    help="exit 1 when regressions are flagged (CI)")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old_rows = json.load(f)
    with open(args.new) as f:
        new_rows = json.load(f)
    result = diff(old_rows, new_rows, args.threshold)

    for entry in result["regressions"]:
        print(f"REGRESSION {entry['op']},{entry['shape']}: "
              f"{entry['us_old']} -> {entry['us_new']} us "
              f"({entry['ratio']}x)  [{entry['note']}]")
    for entry in result["byte_regressions"]:
        print(f"BYTES-REGRESSION {entry['op']},{entry['shape']}: "
              f"{entry['staged_bytes_old']} -> "
              f"{entry['staged_bytes_new']} staged bytes "
              f"({entry['ratio']}x)  [{entry['note']}]")
    for entry in result["speedup_regressions"]:
        print(f"SPEEDUP-REGRESSION {entry['op']},{entry['shape']}: "
              f"us_ref/us {entry['speedup_old']} -> "
              f"{entry['speedup_new']} ({entry['ratio']}x)  "
              f"[{entry['note']}]")
    for entry in result["improvements"]:
        print(f"improved   {entry['op']},{entry['shape']}: "
              f"{entry['us_old']} -> {entry['us_new']} us "
              f"({entry['ratio']}x)")
    for op, shape in result["only_old"]:
        print(f"removed    {op},{shape}")
    for op, shape in result["only_new"]:
        print(f"added      {op},{shape}")
    n_reg = (len(result["regressions"]) + len(result["byte_regressions"])
             + len(result["speedup_regressions"]))
    print(f"# {n_reg} regression(s) "
          f"({len(result['regressions'])} latency, "
          f"{len(result['byte_regressions'])} staged-bytes, "
          f"{len(result['speedup_regressions'])} speedup), "
          f"{len(result['improvements'])} improvement(s) "
          f"at threshold {args.threshold:.0%}")
    return 1 if (n_reg and args.fail) else 0


if __name__ == "__main__":
    sys.exit(main())
