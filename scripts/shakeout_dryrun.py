"""Dev shakeout: dry-run machinery on 8 host devices, reduced configs."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import jax

from repro.common.config import ShapeSpec
from repro.configs import ARCHS, reduced
from repro.launch import dryrun

mesh = jax.make_mesh((2, 4), ("data", "model"))

SHAPES = [
    ShapeSpec("train_4k", 64, 4, "train"),       # tiny stand-ins
    ShapeSpec("prefill_32k", 128, 4, "prefill"),
    ShapeSpec("decode_32k", 128, 8, "decode"),
]

fails = []
for arch, cfg in ARCHS.items():
    rcfg = reduced(cfg).replace(dtype="bfloat16")
    for shape in SHAPES:
        try:
            dryrun.run_cell(arch, shape.name, "local",
                            out_dir="/tmp/shakeout", cfg=rcfg,
                            mesh=mesh, shape=shape)
        except Exception as e:
            import traceback; traceback.print_exc()
            fails.append((arch, shape.name, str(e)[:120]))
print("FAILS:", fails if fails else "none")
