"""Dev smoke: tiny config per family -> train_loss + decode_step on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.common.config import (MLAConfig, Mamba2Config, ModelConfig,
                                 MoEConfig, XLSTMConfig)
from repro.models import lm


def tiny(family, **kw):
    base = dict(
        name=f"tiny-{family}", family=family, n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
        dtype="float32", remat="none", scan_layers=True,
        attn_block_q=32, attn_block_kv=32,
    )
    base.update(kw)
    return ModelConfig(**base)


CASES = [
    tiny("dense"),
    tiny("dense", qkv_bias=True, tie_embeddings=True),
    tiny("moe", moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                              first_k_dense=1, d_ff_dense=128,
                              n_shared=1, score_fn="sigmoid",
                              norm_topk=True, routed_scale=1.5)),
    tiny("moe", moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
         mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                       nope_head_dim=16, v_head_dim=16)),
    tiny("hybrid", n_layers=8,
         mamba2=Mamba2Config(d_state=8, d_conv=4, expand=2, head_dim=16,
                             chunk=16, attn_every=3)),
    tiny("ssm", n_layers=4, n_kv_heads=4,
         xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, chunk=16)),
    tiny("vlm", frontend="vision", frontend_tokens=8, frontend_dim=48),
    tiny("audio", enc_layers=2, norm="layernorm", act="relu",
         frontend="audio", frontend_tokens=16, frontend_dim=48),
]

B, S = 2, 32
key = jax.random.PRNGKey(0)

for cfg in CASES:
    params = lm.init(cfg, key)
    nparams = sum(x.size for x in jax.tree.leaves(params))
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        batch["tokens"] = tokens[:, : S - cfg.frontend_tokens]
        batch["labels"] = batch["tokens"]
        batch["loss_mask"] = jnp.ones_like(batch["tokens"], jnp.float32)
        batch["frontend_emb"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        batch["frontend_emb"] = jax.random.normal(
            key, (B, 16, cfg.frontend_dim))

    loss, metrics = jax.jit(lambda p, b: lm.train_loss(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss), (cfg.name, loss)

    # grad check
    g = jax.jit(jax.grad(lambda p, b: lm.train_loss(p, b, cfg)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree.leaves(g)))
    assert jnp.isfinite(gnorm), cfg.name

    # decode
    cache = lm.init_cache(cfg, B, 16, enc_len=16)
    dbatch = {"token": tokens[:, 0], "cur_len": jnp.int32(3), "cache": cache}
    logits, new_cache = jax.jit(
        lambda p, b: lm.decode_step(p, b, cfg))(params, dbatch)
    assert logits.shape == (B, cfg.vocab), (cfg.name, logits.shape)
    assert jnp.all(jnp.isfinite(logits)), cfg.name

    # prefill
    pl, pcaches = jax.jit(lambda p, b: lm.prefill(p, b, cfg))(params, batch)
    assert pl.shape == (B, cfg.vocab)
    print(f"OK {cfg.name:16s} params={nparams:8d} loss={float(loss):7.4f} "
          f"gnorm={float(gnorm):9.4f} dec_logit_mean={float(logits.mean()):+.4f}")

print("ALL FAMILIES OK")
