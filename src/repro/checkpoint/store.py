"""Sharded, atomic, keep-k checkpointing with elastic re-shard.

Layout:  <dir>/step_<n>/
            index.json            tree structure, shapes, dtypes
            <leaf_id>.s<k>.npy    shard k of leaf (per addressable shard)
            _COMPLETE             commit marker (atomicity)

Properties:
  * atomic: written into step_<n>.tmp, fsynced, renamed; readers only
    trust directories with _COMPLETE;
  * multi-host-aware: each process writes only its addressable shards
    (process 0 writes index + marker after a barrier in real clusters;
    single-process here, structure identical);
  * elastic restore: `restore` takes TARGET shardings that may differ
    from the save-time mesh — each device reads exactly the saved
    shards overlapping its slice (save mesh != load mesh works);
  * keep-k GC + async save (thread executor, joined before the next
    save so at most one inflight).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    # jax.tree.flatten_with_path only exists on newer jax; the
    # tree_util spelling works on every version we support.
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._inflight: Optional[Future] = None

    # ------------------------------------------------------------ save
    def save(self, step: int, tree, async_: bool = False):
        # snapshot to host memory first (donated buffers may be reused);
        # flatten BEFORE converting (the shard records are dicts and
        # would otherwise be traversed as pytrees)
        host = {k: self._to_host_shards(v)
                for k, v in _leaf_paths(tree).items()}
        self.wait()     # join (and clear) the previous async write —
        # a failure re-raises HERE once, not again at teardown
        if async_:
            self._inflight = self._pool.submit(self._write, step, host)
        else:
            self._write(step, host)

    @staticmethod
    def _to_host_shards(leaf):
        if isinstance(leaf, jax.Array):
            shards = []
            for s in leaf.addressable_shards:
                idx = s.index
                spans = [(sl.start or 0,
                          sl.stop if sl.stop is not None else dim)
                         for sl, dim in zip(idx, leaf.shape)]
                shards.append((spans, np.asarray(s.data)))
            # deduplicate replicated shards (same index spans)
            seen, uniq = set(), []
            for spans, arr in shards:
                key = tuple(spans)
                if key not in seen:
                    seen.add(key)
                    uniq.append((spans, arr))
            return {"shape": list(leaf.shape),
                    "dtype": str(leaf.dtype), "shards": uniq}
        arr = np.asarray(leaf)
        return {"shape": list(arr.shape), "dtype": str(arr.dtype),
                "shards": [([(0, d) for d in arr.shape], arr)]}

    def _write(self, step: int, host_tree):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {}
        for key, rec in host_tree.items():
            safe = key.replace("/", "__")
            index[key] = {"shape": rec["shape"], "dtype": rec["dtype"],
                          "shards": []}
            for i, (spans, arr) in enumerate(rec["shards"]):
                fname = f"{safe}.s{i}.npy"
                np.save(os.path.join(tmp, fname), arr)
                index[key]["shards"].append({"spans": spans,
                                             "file": fname})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------- restore
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            # tolerate stray entries (step_backup, step_old_3, ...):
            # one unparsable name must not kill restore discovery
            tail = name[len("step_"):]
            if not tail.isdigit():
                continue
            if os.path.exists(os.path.join(self.dir, name,
                                           "_COMPLETE")):
                out.append(int(tail))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """target_tree: pytree of ShapeDtypeStructs (or arrays) giving
        the wanted structure; shardings: matching tree of Shardings for
        elastic re-shard (None -> single-device arrays)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)

        leaf_keys = list(_leaf_paths(target_tree).keys())
        flat_t, treedef = jax.tree.flatten(target_tree)
        flat_s = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat_t))
        out = []
        for key, tgt, shd in zip(leaf_keys, flat_t, flat_s):
            rec = index[key]
            shape = tuple(rec["shape"])
            dtype = np.dtype(rec["dtype"])
            assert shape == tuple(tgt.shape), (key, shape, tgt.shape)

            files = [(s["spans"], os.path.join(d, s["file"]))
                     for s in rec["shards"]]

            def read_slice(global_idx, files=files, shape=shape,
                           dtype=dtype):
                want = [(sl.start or 0,
                         sl.stop if sl.stop is not None else dim)
                        for sl, dim in zip(global_idx, shape)]
                buf = np.zeros([b - a for a, b in want], dtype)
                for spans, path in files:
                    inter = [(max(a, c), min(b, dd))
                             for (a, b), (c, dd) in zip(want, spans)]
                    if any(a >= b for a, b in inter):
                        continue
                    arr = np.load(path, mmap_mode="r")
                    src = tuple(slice(a - c, b - c)
                                for (a, b), (c, _) in zip(inter, spans))
                    dst = tuple(slice(a - wa, b - wa)
                                for (a, b), (wa, _) in zip(inter, want))
                    buf[dst] = arr[src]
                return buf

            if shd is None:
                full = read_slice(tuple(slice(0, s) for s in shape))
                out.append(jax.numpy.asarray(full.astype(dtype)))
            else:
                arr = jax.make_array_from_callback(
                    shape, shd, lambda idx, rs=read_slice: rs(idx))
                out.append(arr.astype(tgt.dtype))
        return jax.tree.unflatten(treedef, out)

    def wait(self):
        """Join the in-flight async save, re-raising its exception —
        without this, a failed background write would surface only on
        the NEXT ``save()`` (or never, at the end of a run).  Call it
        at run end and from snapshot-cadence teardown; idempotent."""
        if self._inflight is not None:
            try:
                self._inflight.result()
            finally:
                self._inflight = None
