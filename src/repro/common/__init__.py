from repro.common.config import (  # noqa: F401
    MLAConfig,
    Mamba2Config,
    ModelConfig,
    MoEConfig,
    XLSTMConfig,
)
from repro.common.module import ParamDef, abstract_params, init_params, param_pspecs  # noqa: F401
