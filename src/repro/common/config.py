"""Model / runtime configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the exact
published numbers live in ``repro.configs.<id>``.  Runtime knobs (remat,
microbatching, attention implementation) live here too so that a config
fully determines the lowered program.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # shared (always-on) experts
    d_expert: int = 0              # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    first_k_dense: int = 0         # leading dense layers (DeepSeek-V3: 3)
    d_ff_dense: int = 0            # FFN width of the dense layers (0 = d_ff)
    score_fn: str = "softmax"      # softmax | sigmoid (DeepSeek-V3)
    norm_topk: bool = False        # renormalize top-k gates (DeepSeek-V3: True)
    routed_scale: float = 1.0      # routed-expert output scale (V3: 2.5)
    # 'gather' = capacity dispatch, position-in-expert via one-hot cumsum
    # 'sort'   = same, position via stable argsort (beyond-paper opt)
    dispatch: str = "gather"
    # 'gspmd'   = let GSPMD reshard around the expert einsum (baseline)
    # 'full_ep' = constrain dispatched tokens to the expert owners
    #             (E sharded over data x model): tokens move (all-to-
    #             all-sized), weights never do (EXPERIMENTS.md §Perf H2)
    ep: str = "gspmd"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class Mamba2Config:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    # hybrid (Zamba2): a shared full-attention block every `attn_every`
    # Mamba blocks (0 = pure SSM stack)
    attn_every: int = 0


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 6           # 1-in-N layers are sLSTM, rest mLSTM
    proj_factor: float = 2.0       # mLSTM up-projection
    conv1d_kernel: int = 4
    chunk: int = 256               # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    qkv_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu | relu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    z_loss_coef: float = 1e-4      # output z-loss
    lb_coef: float = 0.01          # MoE load-balance coefficient
    router_z_coef: float = 1e-3    # MoE router z-loss coefficient

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba2: Optional[Mamba2Config] = None
    xlstm: Optional[XLSTMConfig] = None

    # encoder-decoder (audio family): n_layers counts DECODER layers.
    enc_layers: int = 0
    # modality frontend stub: number of precomputed embedding tokens the
    # frontend contributes ('input_specs' provides them directly).
    frontend: Optional[str] = None          # 'vision' | 'audio' | None
    frontend_tokens: int = 0
    frontend_dim: int = 0                   # raw embedding dim (pre-proj)

    # ---- runtime knobs (affect lowering, not semantics) ----
    # 'fsdp_tp' = TP over 'model' + param dim over data axes (default)
    # 'ddp'     = both mesh axes are data; params ZeRO-sharded over all
    #             (right choice for sub-1B archs on a 256-chip mesh)
    sharding_strategy: str = "fsdp_tp"
    # kernel-dispatch backend (repro.kernels.dispatch registry):
    # 'xla'    = einsum/blockwise reference formulations (default; the
    #            path GSPMD shards and the dry-run lowers)
    # 'pallas' = VWR Pallas kernels with fused epilogues + zero-copy
    #            GQA + autotuned block sizes (single-device / Mosaic;
    #            see repro.kernels.ops).  FORWARD-ONLY: the kernels
    #            define no VJP yet, so this path serves prefill /
    #            decode / eval; lm.train_loss rejects it.
    # 'auto'   = per-op, per-shape measured choice through the
    #            autotuner cache (dispatch registry 'dispatch:<op>'
    #            entries); lm.train_loss pins it back to 'xla'.
    kernel_impl: str = "xla"
    # decode attention distribution:
    # 'none' = the cache is shard-local (GSPMD may still head-shard it)
    # 'seq'  = cache sequence-sharded over 'model'; decode attention
    #          runs distributed FlashDecoding (dist.decode) — per-shard
    #          online-softmax partials, a (B, H)-sized psum combine.
    #          Needs the mesh passed explicitly through
    #          lm.decode_step/steps.build_decode (engine.DecodeEngine
    #          does); the ambient-mesh fallback is deprecated.  Falls
    #          back to 'none' without a mesh.
    decode_shard: str = "none"
    dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    attn_impl: str = "auto"        # auto | tp_heads | seq_par
    attn_block_q: int = 512        # blockwise-attention q tile
    attn_block_kv: int = 1024      # blockwise-attention kv tile
    n_microbatches: int = 1        # grad-accumulation microbatches
    logits_chunk: int = 0          # 0 = whole-seq loss; else chunk seq
    max_seq: int = 32768
    # accounting mode: scan-free / dense formulations so that XLA
    # cost_analysis FLOP/byte counts are exact (see DESIGN.md §8 — XLA
    # counts while-loop bodies once).  Accounting programs are lowered,
    # never executed, so their transient sizes don't matter.
    accounting: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------- derived ----------
    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding tables are padded to a multiple of 128
        so the vocab dim shards over 'model' (Megatron-style padding;
        granite/internvl2/seamless have odd vocab sizes).  Logits at
        padded positions are masked to -inf."""
        return -(-self.vocab // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def n_params(self) -> int:
        """Total parameter count (analytic, matches the param tree)."""
        from repro.models import lm  # local import: avoid cycle

        import jax

        tree = lm.abstract_init(self)
        return sum(int(x.size) for x in jax.tree.leaves(tree))

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        n_moe_layers = self.n_layers - m.first_k_dense
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return self.n_params() - inactive


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is a pure full-attention arch (skip per brief)"
        )
    return True, ""
