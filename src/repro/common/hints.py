"""Sharding hint helpers.

Every helper takes the mesh as an **explicit** argument; the
ambient-mesh lookup (the ``with mesh:`` context) survives only as a
deprecated fallback for callers that predate the explicit-mesh API
(``DecodeEngine`` / ``lm.decode_step(..., mesh=...)`` thread the mesh
through instead).  The ``with_sharding_constraint`` wrappers no-op when
the resolved mesh lacks the referenced axes (single-device tests).
"""
from __future__ import annotations

import math
import warnings

import jax
from jax.sharding import PartitionSpec as PS  # noqa: F401


def ambient_mesh():
    """The physical mesh of the enclosing ``with mesh:`` context, or
    None outside one.  The single place that touches the private
    jax._src thread-resources API.

    DEPRECATED as an implicit dependency: new code should thread the
    mesh explicitly (see ``resolve_mesh``); this lookup remains only so
    pre-engine call sites keep working."""
    try:
        from jax._src import mesh as mesh_lib
        cur = mesh_lib.thread_resources.env.physical_mesh
        return None if cur.empty else cur
    except Exception:                                  # noqa: BLE001
        return None


_AMBIENT_WARNED = False


def resolve_mesh(mesh, context: str = ""):
    """Explicit mesh when given; else the deprecated ambient fallback
    (one DeprecationWarning per process when it actually resolves)."""
    if mesh is not None:
        return mesh
    cur = ambient_mesh()
    if cur is not None:
        global _AMBIENT_WARNED
        if not _AMBIENT_WARNED:
            _AMBIENT_WARNED = True
            warnings.warn(
                f"{context or 'repro.common.hints'}: falling back to the "
                "ambient `with mesh:` context is deprecated — pass the "
                "mesh explicitly (lm.decode_step/lm.prefill/dist.decode "
                "take mesh=; engine.DecodeEngine owns one).",
                DeprecationWarning, stacklevel=3)
    return cur


def _constrain(x, spec, cur):
    """A bare PartitionSpec only resolves inside a ``with mesh:``
    context; on the explicit-mesh path (no ambient context, by design)
    with_sharding_constraint raises 'requires a non-empty mesh' —
    which the callers' no-op guards would silently swallow.  Binding
    the resolved mesh into a NamedSharding works in both worlds."""
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(cur, spec))


def shard_hint(x, spec, mesh=None):
    """with_sharding_constraint iff the resolved mesh has every axis the
    spec references."""
    try:
        cur = mesh if mesh is not None else ambient_mesh()
        names = set(cur.axis_names) if cur is not None else set()
        need = {a for e in spec for a in
                ((e,) if isinstance(e, str) else (e or ()))}
        if need and need.issubset(names):
            return _constrain(x, spec, cur)
    except Exception:                                  # noqa: BLE001
        pass
    return x


def shard_batch(x, ndim=None, extra=None, mesh=None):
    """Constrain dim 0 to the data axes present in the resolved mesh
    (('pod','data') on the multi-pod mesh, ('data',) single-pod) and
    leave other dims free.  No-op without a mesh."""
    try:
        cur = mesh if mesh is not None else ambient_mesh()
        if cur is None:
            return x
        dp = tuple(a for a in ("pod", "data") if a in cur.axis_names)
        if not dp or x.shape[0] % math.prod(cur.shape[a] for a in dp):
            return x
        n = ndim or x.ndim
        spec = PS(dp if len(dp) > 1 else dp[0], *([None] * (n - 1)))
        return _constrain(x, spec, cur)
    except Exception:                                  # noqa: BLE001
        return x
