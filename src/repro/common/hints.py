"""Sharding hint helpers: ambient-mesh lookup plus
with_sharding_constraint wrappers that no-op when no mesh with the
referenced axes is active (single-device tests)."""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as PS  # noqa: F401


def ambient_mesh():
    """The physical mesh of the enclosing ``with mesh:`` context, or
    None outside one.  The single place that touches the private
    jax._src thread-resources API."""
    try:
        from jax._src import mesh as mesh_lib
        cur = mesh_lib.thread_resources.env.physical_mesh
        return None if cur.empty else cur
    except Exception:                                  # noqa: BLE001
        return None


def shard_hint(x, spec):
    """with_sharding_constraint iff the active mesh has every axis the
    spec references."""
    try:
        cur = ambient_mesh()
        names = set(cur.axis_names) if cur is not None else set()
        need = {a for e in spec for a in
                ((e,) if isinstance(e, str) else (e or ()))}
        if need and need.issubset(names):
            return jax.lax.with_sharding_constraint(x, spec)
    except Exception:                                  # noqa: BLE001
        pass
    return x


def shard_batch(x, ndim=None, extra=None):
    """Constrain dim 0 to the data axes present in the active mesh
    (('pod','data') on the multi-pod mesh, ('data',) single-pod) and
    leave other dims free.  No-op without a mesh."""
    try:
        cur = ambient_mesh()
        if cur is None:
            return x
        dp = tuple(a for a in ("pod", "data") if a in cur.axis_names)
        if not dp or x.shape[0] % math.prod(cur.shape[a] for a in dp):
            return x
        n = ndim or x.ndim
        spec = PS(dp if len(dp) > 1 else dp[0], *([None] * (n - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:                                  # noqa: BLE001
        return x
