"""Sharding hint helper: with_sharding_constraint iff a mesh with the
referenced axes is active (no-op in single-device tests)."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as PS  # noqa: F401


def shard_hint(x, spec):
    try:
        from jax._src import mesh as mesh_lib
        cur = mesh_lib.thread_resources.env.physical_mesh
        names = set(cur.axis_names) if not cur.empty else set()
        need = {a for e in spec for a in
                ((e,) if isinstance(e, str) else (e or ()))}
        if need and need.issubset(names):
            return jax.lax.with_sharding_constraint(x, spec)
    except Exception:                                  # noqa: BLE001
        pass
    return x


def shard_batch(x, ndim=None, extra=None):
    """Constrain dim 0 to the data axes present in the active mesh
    (('pod','data') on the multi-pod mesh, ('data',) single-pod) and
    leave other dims free.  No-op without a mesh."""
    try:
        from jax._src import mesh as mesh_lib
        cur = mesh_lib.thread_resources.env.physical_mesh
        if cur.empty:
            return x
        dp = tuple(a for a in ("pod", "data") if a in cur.axis_names)
        if not dp or x.shape[0] % __import__("math").prod(
                cur.shape[a] for a in dp):
            return x
        n = ndim or x.ndim
        spec = PS(dp if len(dp) > 1 else dp[0], *([None] * (n - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:                                  # noqa: BLE001
        return x
