"""Minimal parameter-definition system (no flax available / needed).

A module is a function pair:
  ``spec(cfg, ...) -> dict[name -> ParamDef | nested dict]``
  ``apply(params, inputs, ...) -> outputs``

``ParamDef`` carries shape, dtype, *logical axes* and an init function.
Logical axes are resolved to mesh ``PartitionSpec`` via a rules table, the
same idea as flax.linen.partitioning but ~100 lines.  This keeps the
multi-pod dry-run allocation-free: ``abstract_params`` gives
ShapeDtypeStructs, ``param_pspecs`` gives in_shardings, and only real
training materializes arrays.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


class ParamDef:
    __slots__ = ("shape", "dtype", "axes", "init")

    def __init__(self, shape, dtype, axes, init: Optional[Callable] = None):
        assert len(axes) == len(shape), (shape, axes)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.axes = tuple(axes)
        self.init = init if init is not None else fan_in_init

    def __repr__(self):
        return f"ParamDef({self.shape}, {self.dtype}, {self.axes})"


# ---------------- initializers ----------------

def fan_in_init(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def const_init(v):
    def f(key, shape, dtype):
        return jnp.full(shape, v, dtype)
    return f


# ---------------- tree utilities ----------------

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(spec: Dict[str, Any], key) -> Dict[str, Any]:
    """Materialize a spec tree into real arrays (deterministic in key)."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [d.init(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec: Dict[str, Any]):
    """ShapeDtypeStructs standing in for params — no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), spec, is_leaf=_is_def
    )


def param_pspecs(spec: Dict[str, Any], rules: Dict[str, Any]):
    """Resolve logical axes -> PartitionSpec using a rules dict.

    rules maps logical axis name -> mesh axis (str | tuple | None).
    Unknown axes default to None (replicated).
    """
    def resolve(d: ParamDef):
        out = []
        used = set()
        for ax, size in zip(d.axes, d.shape):
            mesh_ax = rules.get(ax)
            flat = (mesh_ax if isinstance(mesh_ax, tuple)
                    else ((mesh_ax,) if mesh_ax is not None else ()))
            # each mesh axis may appear at most once per spec
            if mesh_ax is None or any(a in used for a in flat):
                out.append(None)
                continue
            used.update(flat)
            out.append(mesh_ax)
        return PS(*out)

    return jax.tree.map(resolve, spec, is_leaf=_is_def)


def stack_specs(spec: Dict[str, Any], n: int, axis_name: str = "layers"):
    """Stack a per-layer spec n times along a leading axis (for scan)."""
    def stack(d: ParamDef):
        return ParamDef((n, *d.shape), d.dtype, (axis_name, *d.axes), d.init)

    return jax.tree.map(stack, spec, is_leaf=_is_def)


def count_params(spec: Dict[str, Any]) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=_is_def)
    return sum(int(jnp.prod(jnp.array(d.shape))) for d in leaves)
