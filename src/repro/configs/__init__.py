"""Assigned-architecture registry: ``get_config(name)`` / ``--arch``.

Each module exports CONFIG (the exact published numbers) and the
registry adds ``reduced(cfg)`` — a same-family shrink used by the CPU
smoke tests (tiny layers/width/experts, fp32).  The full configs are
only ever lowered (dry-run), never materialized on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.common.config import (MLAConfig, Mamba2Config, ModelConfig,
                                 MoEConfig, XLSTMConfig)

from repro.configs import (deepseek_coder_33b, deepseek_v3_671b,
                           granite_3_8b, internvl2_2b, olmoe_1b_7b,
                           provet_cnn, qwen1_5_0_5b, seamless_m4t_large_v2,
                           tinyllama_1_1b, xlstm_350m, zamba2_1_2b)

ARCHS = {
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "granite-3-8b": granite_3_8b.CONFIG,
    "tinyllama-1.1b": tinyllama_1_1b.CONFIG,
    "deepseek-coder-33b": deepseek_coder_33b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "xlstm-350m": xlstm_350m.CONFIG,
    "internvl2-2b": internvl2_2b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family shrink for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)) or 4,
        d_head=16, d_ff=(128 if cfg.d_ff else 0), vocab=512,
        dtype="float32", remat="none", attn_block_q=32, attn_block_kv=32,
        logits_chunk=0, n_microbatches=1,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=32,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            d_ff_dense=128 if cfg.moe.d_ff_dense else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16)
    if cfg.mamba2 is not None:
        kw["mamba2"] = dataclasses.replace(
            cfg.mamba2, d_state=8, head_dim=16, chunk=16,
            attn_every=2)
        kw["n_layers"] = 5                      # 2 groups of 2 + tail 1
        kw["n_kv_heads"] = 4
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_every=2,
                                          chunk=16)
        kw["n_layers"] = 4
        kw["n_kv_heads"] = 4
    if cfg.frontend:
        kw["frontend"] = cfg.frontend
        kw["frontend_tokens"] = 8
        kw["frontend_dim"] = 32
    if cfg.enc_layers:
        kw["enc_layers"] = 2
    return cfg.replace(**kw)
