"""DeepSeek-Coder-33B [arXiv:2401.14196]: llama-arch, deep+wide."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19200, vocab=32256, act="swiglu", rope_theta=100000.0,
)
