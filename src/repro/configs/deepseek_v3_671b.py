"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA + 1 shared + 256 routed
top-8 (sigmoid scoring, selection bias, gates renormalized, scale 2.5),
first 3 layers dense (d_ff 18432).

MTP (multi-token prediction) head omitted: the training objective here
is next-token CE; noted in DESIGN.md §8.
"""
from repro.common.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=2048, vocab=129280, act="swiglu", rope_theta=10000.0,
    logits_chunk=1024,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_expert=2048,
                  first_k_dense=3, d_ff_dense=18432,
                  score_fn="sigmoid", norm_topk=True, routed_scale=2.5,
                  capacity_factor=1.25),
)
