"""Granite-3 8B [hf:ibm-granite]: dense GQA."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12800, vocab=49155, act="swiglu", rope_theta=10000.0,
)
