"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B backbone; the
InternViT frontend is a STUB per the brief — input_specs() provides 256
precomputed patch embeddings (InternVL's 256 tokens/tile after pixel
shuffle) of dim 1024, projected into the LM stream."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92553, act="swiglu", rope_theta=1e6,
    frontend="vision", frontend_tokens=256, frontend_dim=1024,
)
