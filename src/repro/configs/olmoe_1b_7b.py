"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts, top-8, no shared."""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1024, vocab=50304, act="swiglu", rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=8, n_shared=0, d_expert=1024,
                  score_fn="softmax", norm_topk=False),
)
