"""The paper's own application: CNN layers on the Provet machine.

Not an LM config — this exposes the §6/§7 artifacts (ISA machine,
templates, analysis suite) under the same registry so examples and
benchmarks can reach them uniformly."""
from repro.core.analysis import LAYERS, PROVET_FULL, run_suite  # noqa: F401
from repro.core.machine import PAPER_EXAMPLE, ProvetConfig  # noqa: F401

CONFIG = None  # not a ModelConfig; see module docstring
