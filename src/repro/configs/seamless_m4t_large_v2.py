"""SeamlessM4T-large-v2 [arXiv:2308.11596]: encoder-decoder backbone
(24 enc + 24 dec, the text/unit decoder stack); the speech frontend is
a STUB per the brief — input_specs() provides precomputed frame
embeddings (dim 1024) as the encoder input sequence.  LayerNorm + ReLU
FFNs (NLLB-style)."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=8192, vocab=256206, norm="layernorm", act="relu",
    rope_theta=10000.0, logits_chunk=1024,
    frontend="audio", frontend_dim=1024,
)
