"""xLSTM-350M [arXiv:2405.04517]: mLSTM blocks with 1-in-6 sLSTM
(xLSTM[m:s] mix), block-internal expansion (proj factor 2) — d_ff=0
per the assignment: blocks carry their own FFN-equivalent."""
from repro.common.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_head=256,
    d_ff=0, vocab=50304,
    xlstm=XLSTMConfig(slstm_every=6, proj_factor=2.0, conv1d_kernel=4,
                      chunk=256),
)
