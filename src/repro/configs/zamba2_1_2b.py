"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 blocks + one SHARED
attention+MLP block invoked every 6 blocks (7 invocations, one weight
set — the Zamba2 shared-block design; the concat-embedding input to the
shared block is simplified to the current residual stream, DESIGN.md §8).
"""
from repro.common.config import Mamba2Config, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000, act="swiglu", rope_theta=10000.0,
    mamba2=Mamba2Config(d_state=64, d_conv=4, expand=2, head_dim=64,
                        n_groups=1, chunk=256, attn_every=6),
)
