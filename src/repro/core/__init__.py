from repro.core import analysis, isa, machine, ref_ops, templates  # noqa: F401
