"""§7 analytical models: Provet vs Eyeriss / TPU / ARA / GPU.

Provet's numbers come from a *closed-form count of the conv template*
(core/templates.py): the same loop structure counted arithmetically.
Property tests assert the closed form matches the ISA interpreter's
counters at small sizes (`issue='scalar'`); the §7 tables then use
`issue='pipelined'`, which models the paper's distributed loop-buffer
control (§4.4, §4.3.6: "Different VFUs can execute different
instructions simultaneously") — VWR reads, shuffles, SRAM transactions
and VFU ops each belong to a different component, so steady-state
throughput is the *max* over per-component counts, not the sum.

Baseline architectures use documented first-order dataflow models
(GEMM fold model for systolic arrays, lane/VRF model for the vector
machine, SM-occupancy/stall model for the GPU).  The paper generated
these with the ZigZag DSE and vendor profiling, which we do not have;
our models are calibrated to the paper's order of magnitude and
reproduce the paper's *relative* claims:

  * utilization roughly comparable across Provet/ARA/TPU/Eyeriss on
    ResNet/AlexNet; GPU utilization (vs its own peak) far lower;
  * systolic arrays collapse on MobileNet depthwise layers (low reuse,
    fold waste) while Provet/ARA (1D, linear bandwidth) hold;
  * CMR: Provet >= ARA > GPU >= SAs, gap exploding on depthwise.

Deviations are logged in DESIGN.md §8; absolute numbers are printed
next to the paper's values by benchmarks/paper_tables.py.

Units: reads in mega-words (8-bit operands); latency in ms @ 200 MHz
(Table 4's normalization).  GPU utilization/latency use real device
scale (6912 cores) because the paper measures the A100 against its own
peak while normalizing latency — reproducing its seeming paradox of
"lowest utilization, yet low latency".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List

from repro.core.machine import ProvetConfig


# ======================================================================
# layer suite (Table 3/4 rows)
# ======================================================================

@dataclass(frozen=True)
class ConvLayer:
    name: str
    H_in: int
    W_in: int
    C_in: int
    C_out: int
    K: int
    stride: int = 1
    depthwise: bool = False

    @property
    def H_out(self):
        return (self.H_in - self.K) // self.stride + 1

    @property
    def W_out(self):
        return (self.W_in - self.K) // self.stride + 1

    @property
    def macs(self):
        per_out = self.K * self.K * (1 if self.depthwise else self.C_in)
        return self.H_out * self.W_out * self.C_out * per_out

    @property
    def in_words(self):
        return self.H_in * self.W_in * self.C_in

    @property
    def w_words(self):
        kk = self.K * self.K
        return kk * self.C_out * (1 if self.depthwise else self.C_in)

    @property
    def out_words(self):
        return self.H_out * self.W_out * self.C_out

    @property
    def reduction(self):
        """GEMM K-dim: im2col reduction length."""
        return self.K * self.K * (1 if self.depthwise else self.C_in)


# Dims chosen so MACs match the paper's MOPS column where it is
# internally consistent (= 2*MACs for RN_112/56, AN_*); where it is not
# (RN_28/14/7, MN_112/56 do not reproduce from the published network
# definitions) we keep the published network layer and report the
# discrepancy in the benchmark output.
LAYERS: List[ConvLayer] = [
    ConvLayer("RN_112x112", 224 + 6, 224 + 6, 3, 64, 7, 2),
    ConvLayer("RN_56x56", 56 + 2, 56 + 2, 64, 64, 3),
    ConvLayer("RN_28x28", 28 + 2, 28 + 2, 128, 128, 3),
    ConvLayer("RN_14x14", 14 + 2, 14 + 2, 256, 256, 3),
    ConvLayer("RN_7x7", 7 + 2, 7 + 2, 512, 512, 3),
    ConvLayer("AN_55x55", 224 + 3, 224 + 3, 3, 96, 11, 4),
    ConvLayer("AN_27x27", 27 + 4, 27 + 4, 96, 256, 5),
    ConvLayer("AN_13x13", 13 + 2, 13 + 2, 256, 384, 3),
    ConvLayer("MN_112x112", 112 + 2, 112 + 2, 32, 32, 3, 1, True),
    ConvLayer("MN_56x56", 56 + 2, 56 + 2, 128, 128, 3, 1, True),
    ConvLayer("MN_7x7", 7 + 2, 7 + 2, 1024, 1024, 3, 1, True),
]

LAYERS_BY_NAME = {l.name: l for l in LAYERS}


@dataclass
class Result:
    arch: str
    layer: str
    macs: int
    cycles: float
    utilization: float
    reads_mwords: float
    cmr: float

    @property
    def latency_ms(self):
        return self.cycles / 200e6 * 1e3       # 200 MHz


# ======================================================================
# Provet — closed-form count of the conv2d template
# ======================================================================

# production-scale Provet: 64 VFUs x 64 8-bit lanes (4096 lanes); each
# VFU's SRAM/VWR region is N=8 slices wide (the paper's 8x width ratio,
# §4.3.1) -> SRAM rows of 64*64*8 = 32768 operands.
PROVET_FULL = ProvetConfig(sram_width=32768, sram_depth=32, vfu_width=64,
                           n_vfus=64, vfu_shuffle_range=16,
                           tile_shuffle_range=8)


def template_conv_counts(cfg: ProvetConfig, layer: ConvLayer) -> Dict[str, float]:
    """Closed-form counts that mirror templates.conv2d EXACTLY
    (single-VFU, scalar issue, §6.1 accumulator-shift dataflow).
    Property-tested against the ISA interpreter's counters."""
    assert cfg.n_vfus == 1
    V, S, W = cfg.vfu_width, cfg.n_slices, cfg.sram_width
    K = layer.K
    C_in = 1 if layer.depthwise else layer.C_in
    C_out = layer.C_out
    H_in, H_out = layer.H_in, layer.H_out
    rng = cfg.vfu_shuffle_range
    assert layer.stride == 1 and layer.W_in <= V

    n_conv = C_out            # depthwise: per-channel convs, C_in=1 each
    vmv = mac = C_in * K * K
    perm = 1 + C_in * ((K - 1) * K + K * math.ceil((K - 1) / rng))
    rmv = wlb = 1

    # image RLBs per (co, k): transitions of (c*H_in + k + j)//S over the
    # (c, j) visit order, VWR dirtied by staging each output row, plus
    # the staging RLB itself; kernel RLBs from monotone tap order.
    rlb_img_total = 0
    for k in range(H_out):
        seq = [(c * H_in + k + j) // S for c in range(C_in)
               for j in range(K)]
        trans = 1 + sum(1 for a, b in zip(seq, seq[1:]) if a != b)
        rlb_img_total += trans
    rlb_img_total *= n_conv

    # kernel RLBs: simulate the load tracker over the (co, k) visit
    # order — taps of one co may straddle SRAM-row boundaries, in which
    # case every output row re-walks that co's row sequence
    taps_per_co = C_in * K * K
    rlb_ker_total = 0
    prev_row = None
    for co in range(n_conv):
        start = co * taps_per_co
        rows_seq = list(dict.fromkeys(
            (start + t) // W for t in range(taps_per_co)))
        for _k in range(H_out):
            for r in rows_seq:
                if r != prev_row:
                    rlb_ker_total += 1
                    prev_row = r

    instrs = (n_conv * H_out * (vmv + mac + perm + rmv + wlb + 1)  # +stagRLB
              + rlb_img_total + rlb_ker_total)
    sram_reads = rlb_img_total + rlb_ker_total + n_conv * H_out
    sram_writes = n_conv * H_out
    return {
        "cycles": float(instrs),
        "sram_reads": float(sram_reads),
        "sram_writes": float(sram_writes),
        "compute_instrs": float(n_conv * H_out * mac),
        "mem_instrs": float(sram_reads + sram_writes),
    }


def provet_conv_counts(cfg: ProvetConfig, layer: ConvLayer,
                       issue: str = "pipelined") -> Dict[str, float]:
    """Production mapping counts (the §7 configuration).

    Mapping decisions (§5.2/§6, plus two scheduling refinements the
    paper's control structure §4.4 enables — both recorded in DESIGN.md):
      * work item = (output-row group x strip x output channel); VFU v
        keeps output channel v mod C_out for the whole layer, so its
        kernel stays resident in its VWR-B region (loaded ~once);
      * image rows are stored channel-interleaved (HWC rows), so the
        C_in*K composite rows a wave needs are contiguous: a wave costs
        ceil(C_in*K/N) broadcast transactions (dense; the tile shuffler
        fans one region out to all VFUs) or ceil(K/N) wide transactions
        (depthwise: per-VFU-distinct channels share one wide row);
      * image-shift variant of §6.1: the *image* register is shifted
        one lane per tap (ping-pong through the VFU shuffler) instead
        of the accumulator, which breaks the mac->shift->mac dependency
        so the shuffler and VFU streams pipeline (issue='pipelined');
      * stride>1: rows are phase-split/repacked by the shuffler at load
        (hidden under the mac stream); out-dense lanes.
    """
    V, N = cfg.vfu_width, cfg.slices_per_vfu
    K, s = layer.K, layer.stride
    C_in = 1 if layer.depthwise else layer.C_in
    C_out = layer.C_out
    rng = cfg.vfu_shuffle_range

    if layer.W_in <= V and s == 1:
        pack = max(1, V // layer.W_in)
        n_strips = 1
    else:
        pack = 1
        out_per_strip = max(1, V - math.ceil((K - 1) / s))
        n_strips = math.ceil(layer.W_out / out_per_strip)

    row_groups = math.ceil(layer.H_out / pack)
    waves = math.ceil(row_groups * n_strips * C_out / cfg.n_vfus)

    mac = C_in * K * K                       # VFUX stream (VWR-A port)
    vmv = C_in * K * K                       # broadcast stream (VWR-B port)
    perm = C_in * K * K + (s - 1) * math.ceil(C_in * K / N)  # shuffler
    if layer.depthwise:
        rlb_img = math.ceil(K * pack / N)
    else:
        rlb_img = math.ceil(C_in * K * pack / N)
    taps_per_vfu = C_in * K * K
    ker_thrash = ker_rows = math.ceil(taps_per_vfu / (N * V))
    rlb_ker = 0 if ker_rows == 1 else ker_rows      # resident if it fits
    rlb = rlb_img + rlb_ker + 1              # +1 staging RMW read
    wlb = 1

    if issue == "scalar":
        cycles = waves * (vmv + mac + perm + rlb + wlb + 1)
    else:
        cycles = waves * max(mac, vmv, perm, rlb + wlb + 1)

    sram_reads = waves * rlb + math.ceil(taps_per_vfu / (N * V)) *         (0 if rlb_ker else 1) * math.ceil(C_out / cfg.n_vfus)
    sram_writes = waves * wlb
    compute_instrs = waves * mac
    return {
        "cycles": float(cycles),
        "waves": waves,
        "sram_reads": float(sram_reads),
        "sram_writes": float(sram_writes),
        "compute_instrs": float(compute_instrs),
        "mem_instrs": float(sram_reads + sram_writes),
        "pack": pack,
        "n_strips": n_strips,
    }


def provet_model(layer: ConvLayer, cfg: ProvetConfig = PROVET_FULL,
                 issue: str = "pipelined") -> Result:
    c = provet_conv_counts(cfg, layer, issue=issue)
    lanes = cfg.n_vfus * cfg.vfu_width
    util = layer.macs / (lanes * c["cycles"])
    # words actually consumed per transaction: one per-VFU region (N*V
    # operands) for broadcast reads, one full row for distinct reads
    reads_words = c["sram_reads"] * cfg.slices_per_vfu * cfg.vfu_width
    # CMR in word-normalized units (macs per word read from the global
    # SRAM) so it is comparable across architectures; the paper's
    # instruction-count CMR (eq. 4) is c[compute]/c[mem] and is what the
    # ISA machine reports — both are printed by the benchmark.
    cmr = layer.macs / max(reads_words, 1)
    r = Result("Provet", layer.name, layer.macs, c["cycles"], util,
               reads_words / 1e6, cmr)
    r.cmr_instr = c["compute_instrs"] / c["mem_instrs"]  # type: ignore
    return r


# ======================================================================
# systolic arrays (GEMM fold model)
# ======================================================================

def sa_model(layer: ConvLayer, name: str, rows: int, cols: int,
             bw_words: float, input_reuse: float = 1.0) -> Result:
    """Weight-stationary GEMM fold model with in-array psum
    accumulation (psums live in dedicated accumulators, not the global
    buffer — as in the TPU).  conv as GEMM: M = out pixels,
    Kd = C_in*K^2 (im2col), N = C_out.  Depthwise degenerates to
    per-channel GEMMs with Kd = K^2, N = 1: fold waste idles the array
    (§3.4)."""
    M = layer.H_out * layer.W_out
    if layer.depthwise:
        Kd, N, reps = layer.K ** 2, 1, layer.C_out
    else:
        Kd, N, reps = layer.reduction, layer.C_out, 1

    folds_r = math.ceil(Kd / rows)
    folds_c = math.ceil(N / cols)
    per_rep = folds_r * folds_c * (M + rows + cols)      # fill/drain
    cycles_compute = reps * per_rep

    # global-buffer reads: weights once; im2col'd inputs once per
    # column fold, divided by the dataflow's input-reuse factor
    reads = reps * (Kd * N + M * Kd * folds_c / input_reuse)
    cycles = max(cycles_compute, reads / bw_words)
    util = layer.macs / (rows * cols * cycles)
    return Result(name, layer.name, layer.macs, cycles, util, reads / 1e6,
                  layer.macs / reads)


def eyeriss_model(layer: ConvLayer) -> Result:
    # 12x14 row-stationary: conv rows stay in PEs, input rows reused
    # across the K kernel rows inside the array (input_reuse ~ K); the
    # small global buffer spills partial sums once per 8-deep
    # accumulation pass (not free like the TPU's accumulators)
    r = sa_model(layer, "Eyeriss", 12, 14, bw_words=16.0,
                 input_reuse=layer.K)
    M = layer.H_out * layer.W_out
    Kd = layer.K ** 2 if layer.depthwise else layer.reduction
    N = 1 if layer.depthwise else layer.C_out
    reps = layer.C_out if layer.depthwise else 1
    spills = max(0, math.ceil(Kd / (12 * 8)) - 1)
    extra = reps * spills * M * N
    reads = r.reads_mwords * 1e6 + extra
    r.reads_mwords = reads / 1e6
    r.cmr = layer.macs / reads
    r.cycles = max(r.cycles, reads / 16.0)
    r.utilization = layer.macs / (12 * 14 * r.cycles)
    return r


def tpu_model(layer: ConvLayer) -> Result:
    return sa_model(layer, "TPU", 64, 64, bw_words=64.0)


# ======================================================================
# vector processor (ARA-like, 1D)
# ======================================================================

def ara_model(layer: ConvLayer) -> Result:
    """64 8-bit lanes behind a conventional vector register file.

    1D organization: bandwidth scales with the lanes, so low-reuse
    layers do not starve (the property it shares with Provet).  The VRF
    (32 vregs) holds kernel taps + a few rows: inputs are re-fetched
    once per output channel *pair* (vreg double-use), weights stream
    once.  Memory instructions move one vreg (64 words) per issue —
    1/8 of Provet's wide transaction, which is exactly the VWR-ratio
    advantage the paper claims (§5.3.2)."""
    lanes = 64
    eff = 0.85                                     # strip-mine fringe
    cycles_compute = layer.macs / (lanes * eff)
    reps = 1 if layer.depthwise else max(1, layer.C_out // 8)
    reads = layer.w_words + layer.in_words * reps
    cycles = max(cycles_compute, reads / lanes)
    util = layer.macs / (lanes * cycles)
    return Result("ARA", layer.name, layer.macs, cycles, util, reads / 1e6,
                  layer.macs / reads)


# ======================================================================
# GPU (Ampere-like, batch 1)
# ======================================================================

def gpu_model(layer: ConvLayer) -> Result:
    """Batch-1 implicit-GEMM on an A100-class device (6912 cores).

    Utilization is measured against the device's own peak, after
    removing control stalls per the paper's methodology (75.6% of
    stalls are control; only memory stalls count).  Batch 1 removes
    the GPU's main reuse lever, so the memory-stall fraction is large;
    L2 catches about half of the inter-tile re-reads."""
    cores = 6912
    tile_n, tile_k = 16, 32
    M = layer.H_out * layer.W_out
    if layer.depthwise:
        Kd, N, reps = layer.K ** 2, 1, layer.C_out
        occupancy = min(1.0, (Kd * M) / (tile_n * tile_k * 8))
    else:
        Kd, N, reps = layer.reduction, layer.C_out, 1
        occupancy = min(1.0, N / tile_n) * min(1.0, Kd / tile_k)

    mem_stall_free = 0.075                        # batch-1 derate
    util = max(occupancy * mem_stall_free, 1e-4)
    cycles = layer.macs / (cores * util)
    # batch-1 cuDNN path: im2col materialization (write+read M*Kd) and
    # per-N-tile re-reads with little L2 help
    reads = reps * (2 * M * Kd + M * Kd * math.ceil(N / tile_n)
                    + Kd * N * math.ceil(M / 128))
    return Result("GPU", layer.name, layer.macs, cycles, util, reads / 1e6,
                  layer.macs / reads)


# ======================================================================
# suite driver
# ======================================================================

MODELS = {
    "Eyeriss": eyeriss_model,
    "TPU": tpu_model,
    "ARA": ara_model,
    "GPU": gpu_model,
    "Provet": provet_model,
}


def run_suite() -> Dict[str, Dict[str, Result]]:
    """{layer: {arch: Result}} for all §7 layers and architectures."""
    out: Dict[str, Dict[str, Result]] = {}
    for layer in LAYERS:
        out[layer.name] = {a: f(layer) for a, f in MODELS.items()}
    return out


def improvement_table(suite=None) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Table 3: Provet improvement ratios (utilization and CMR)."""
    suite = suite or run_suite()
    table = {}
    for lname, res in suite.items():
        p = res["Provet"]
        table[lname] = {
            "utilization": {a: p.utilization / max(r.utilization, 1e-9)
                            for a, r in res.items() if a != "Provet"},
            "cmr": {a: p.cmr / max(r.cmr, 1e-9)
                    for a, r in res.items() if a != "Provet"},
        }
    return table


# paper's published Table 3 (for side-by-side reporting in benchmarks)
PAPER_TABLE3 = {
    "RN_112x112": {"utilization": {"Eyeriss": 1.70, "TPU": 1.08, "ARA": 1.01, "GPU": 15.97},
                   "cmr": {"Eyeriss": 4.09, "TPU": 3.00, "ARA": 1.36, "GPU": 1.25}},
    "RN_56x56": {"utilization": {"Eyeriss": 1.37, "TPU": 1.03, "ARA": 1.04, "GPU": 9.71},
                 "cmr": {"Eyeriss": 3.63, "TPU": 3.00, "ARA": 1.24, "GPU": 1.21}},
    "RN_28x28": {"utilization": {"Eyeriss": 1.03, "TPU": 0.98, "ARA": 1.11, "GPU": 15.42},
                 "cmr": {"Eyeriss": 4.14, "TPU": 3.03, "ARA": 1.28, "GPU": 1.11}},
    "RN_14x14": {"utilization": {"Eyeriss": 1.19, "TPU": 1.10, "ARA": 1.20, "GPU": 19.12},
                 "cmr": {"Eyeriss": 4.00, "TPU": 3.29, "ARA": 1.31, "GPU": 1.26}},
    "RN_7x7": {"utilization": {"Eyeriss": 1.18, "TPU": 2.50, "ARA": 1.18, "GPU": 17.67},
               "cmr": {"Eyeriss": 3.60, "TPU": 3.33, "ARA": 1.53, "GPU": 1.61}},
    "AN_55x55": {"utilization": {"Eyeriss": 1.32, "TPU": 1.06, "ARA": 1.01, "GPU": 13.04},
                 "cmr": {"Eyeriss": 3.95, "TPU": 3.48, "ARA": 1.50, "GPU": 1.16}},
    "AN_27x27": {"utilization": {"Eyeriss": 1.05, "TPU": 1.31, "ARA": 1.12, "GPU": 15.65},
                 "cmr": {"Eyeriss": 4.24, "TPU": 3.07, "ARA": 1.41, "GPU": 1.20}},
    "AN_13x13": {"utilization": {"Eyeriss": 0.94, "TPU": 1.09, "ARA": 1.05, "GPU": 16.05},
                 "cmr": {"Eyeriss": 4.09, "TPU": 3.00, "ARA": 1.48, "GPU": 1.00}},
    "MN_112x112": {"utilization": {"Eyeriss": 3.18, "TPU": 2.00, "ARA": 1.08, "GPU": 12.15},
                   "cmr": {"Eyeriss": 25.00, "TPU": 15.00, "ARA": 3.13, "GPU": 2.14}},
    "MN_56x56": {"utilization": {"Eyeriss": 5.00, "TPU": 3.75, "ARA": 1.06, "GPU": 8.05},
                 "cmr": {"Eyeriss": 19.50, "TPU": 15.60, "ARA": 2.69, "GPU": 3.00}},
    "MN_7x7": {"utilization": {"Eyeriss": 9.43, "TPU": 3.67, "ARA": 1.10, "GPU": 5.04},
               "cmr": {"Eyeriss": 24.67, "TPU": 18.50, "ARA": 2.96, "GPU": 3.08}},
}

# paper's Table 4 (reads in M, latency in ms) for side-by-side reporting
PAPER_TABLE4 = {
    # layer: (MOPS, {arch: (reads, latency)})
    "RN_112x112": (236.0, {"Eyeriss": (22.434, 9.231), "TPU": (33.891, 0.320),
                           "ARA": (15.125, 5.657), "GPU": (90.287, 1.757),
                           "Provet": (6.611, 0.193)}),
    "RN_56x56": (231.2, {"Eyeriss": (22.093, 9.035), "TPU": (33.058, 0.315),
                         "ARA": (14.820, 5.516), "GPU": (88.416, 1.713),
                         "Provet": (6.454, 0.189)}),
    "RN_28x28": (115.6, {"Eyeriss": (11.025, 4.492), "TPU": (16.587, 0.156),
                         "ARA": (7.398, 2.777), "GPU": (44.302, 0.856),
                         "Provet": (3.223, 0.095)}),
    "RN_14x14": (115.6, {"Eyeriss": (11.072, 4.536), "TPU": (16.493, 0.157),
                         "ARA": (7.414, 2.785), "GPU": (44.258, 0.861),
                         "Provet": (3.222, 0.095)}),
    "RN_7x7": (115.6, {"Eyeriss": (11.067, 4.551), "TPU": (16.609, 0.157),
                       "ARA": (7.344, 2.752), "GPU": (44.230, 0.859),
                       "Provet": (3.189, 0.095)}),
    "AN_55x55": (210.8, {"Eyeriss": (20.156, 8.257), "TPU": (30.189, 0.286),
                         "ARA": (13.456, 5.029), "GPU": (80.055, 1.550),
                         "Provet": (5.834, 0.171)}),
    "AN_27x27": (895.8, {"Eyeriss": (85.803, 34.885), "TPU": (127.607, 1.223),
                         "ARA": (57.337, 21.333), "GPU": (342.714, 6.639),
                         "Provet": (24.942, 0.729)}),
    "AN_13x13": (299.0, {"Eyeriss": (28.512, 11.630), "TPU": (42.560, 0.406),
                         "ARA": (19.174, 7.107), "GPU": (114.604, 2.211),
                         "Provet": (8.363, 0.244)}),
    "MN_112x112": (0.7, {"Eyeriss": (0.131, 1.125), "TPU": (0.191, 0.435),
                         "ARA": (0.088, 0.954), "GPU": (0.512, 3.059),
                         "Provet": (0.038, 0.339)}),
    "MN_56x56": (1.8, {"Eyeriss": (0.340, 0.768), "TPU": (0.515, 0.510),
                       "ARA": (0.231, 1.071), "GPU": (1.374, 3.651),
                       "Provet": (0.101, 0.403)}),
    "MN_7x7": (0.5, {"Eyeriss": (0.090, 0.689), "TPU": (0.131, 0.218),
                     "ARA": (0.057, 0.887), "GPU": (0.343, 2.089),
                     "Provet": (0.025, 0.230)}),
}
