"""Provet machine description + SRAM/VWR energy model (paper §4.1, §4.3).

``ProvetConfig`` pins the architectural parameters of Fig. 4:
ultra-wide shallow SRAM, N very-wide registers (VWRs) with asymmetric
ports, a coarse tile shuffler (SRAM<->VWR), per-VFU fine shufflers, and
R1-R4 local registers per VFU.

The energy model implements eq. (1)-(2):

    E_word  = W * D * BL + W * WL          (energize W bitlines, 1 wordline)
    E_bit   = D * BL + WL                  (width-normalized)

so for fixed capacity C = W*D the per-bit energy D*BL + WL = (C/W)*BL + WL
falls monotonically with width — the ultra-wide-and-shallow thesis
(Fig. 2b).  Constants are CACTI-calibrated orders of magnitude (28 nm);
absolute joules are not the claim, the W/D scaling law is.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class ProvetConfig:
    """All widths in operands (one operand = ``operand_bits`` wide)."""
    sram_width: int = 64          # operands per SRAM row (ultra-wide)
    sram_depth: int = 32          # rows (shallow: 1-32 per §4.3.1)
    vfu_width: int = 16           # operands per VFU (SIMD lanes)
    n_vfus: int = 1
    n_vwr: int = 2                # §4.3.4: usually 2 for concurrent R/W
    operand_bits: int = 8
    tile_shuffle_range: int = 8   # blocks (block = vfu_width operands)
    vfu_shuffle_range: int = 16   # operands (<= vfu_width per §4.3.7)
    n_local_regs: int = 4         # R1..R4

    def __post_init__(self):
        assert self.sram_width % self.vfu_width == 0
        assert self.vfu_shuffle_range <= self.vfu_width

    @property
    def width_ratio(self) -> int:
        """N = per-VFU SRAM region / VFU width — the asymmetric-port
        ratio.  One wide VWR fill is consumed by N narrow reads, the
        paper's architectural >=N x SRAM-access reduction (§4.1)."""
        return self.sram_width // (self.n_vfus * self.vfu_width)

    @property
    def n_slices(self) -> int:
        """Total vfu-width slices in one SRAM row."""
        return self.sram_width // self.vfu_width

    @property
    def slices_per_vfu(self) -> int:
        return self.sram_width // (self.n_vfus * self.vfu_width)

    @property
    def total_lanes(self) -> int:
        return self.n_vfus * self.vfu_width

    @property
    def sram_width_bits(self) -> int:
        return self.sram_width * self.operand_bits

    @property
    def vfu_width_bits(self) -> int:
        return self.vfu_width * self.operand_bits


# paper's running example (§6.1): 16-lane VFU, 64-operand SRAM, 1 VFU
PAPER_EXAMPLE = ProvetConfig(sram_width=64, sram_depth=32, vfu_width=16,
                             n_vfus=1, n_vwr=2)

# §4.3 "real" scale: 4096-bit SRAM rows, 512-bit VFU, 8x ratio
PAPER_FULL = ProvetConfig(sram_width=512, sram_depth=32, vfu_width=64,
                          n_vfus=8, n_vwr=2)


# ======================================================================
# SRAM energy model (eq. 1-2, Fig. 2a/2b)
# ======================================================================

# CACTI-flavoured 28nm constants (fJ): energy per unit cell-pitch of
# bitline/wordline, plus fixed per-access periphery.
BL_FJ_PER_CELL = 1.1      # bitline energy per row traversed, per bit
WL_FJ_PER_CELL = 0.18     # wordline energy per column traversed, per bit
PERIPH_FJ_PER_BIT = 0.35  # sense amp / drivers, per accessed bit
VWR_FJ_PER_BIT = 0.08     # flip-flop read (no decode, no multiplexing)
SHUFFLE_FJ_PER_BIT_STEP = 0.02   # wire energy ~ shuffle distance (§5.2)
MAC_FJ_8B = 25.0          # 8-bit MAC energy (for context ratios)


def sram_word_energy_fj(width_bits: int, depth: int) -> float:
    """Eq. (1): energy to access one full word of `width_bits`."""
    return (width_bits * depth * BL_FJ_PER_CELL
            + width_bits * WL_FJ_PER_CELL
            + width_bits * PERIPH_FJ_PER_BIT)


def sram_bit_energy_fj(width_bits: int, depth: int) -> float:
    """Eq. (2): width-normalized per-bit access energy."""
    return depth * BL_FJ_PER_CELL + WL_FJ_PER_CELL + PERIPH_FJ_PER_BIT


def aspect_ratio_sweep(capacity_bits: int, widths=None) -> Dict[int, Dict]:
    """Fig. 2b: per-bit energy + bandwidth across aspect ratios at fixed
    capacity.  Returns {width_bits: {e_per_bit_fj, depth, bw_bits_per_cyc}}."""
    if widths is None:
        widths = [128, 256, 512, 1024, 2048, 4096, 8192]
    out = {}
    for w in widths:
        d = max(1, capacity_bits // w)
        out[w] = {
            "depth": d,
            "e_per_bit_fj": sram_bit_energy_fj(w, d),
            "bw_bits_per_cycle": w,
        }
    return out


def vwr_access_energy_fj(bits: int) -> float:
    """Single-row register file: no address decode, no output mux."""
    return bits * VWR_FJ_PER_BIT


def shuffle_energy_fj(bits: int, distance_steps: int) -> float:
    """§5.2: wire length (energy) scales with shuffle distance, NOT with
    total width."""
    return bits * SHUFFLE_FJ_PER_BIT_STEP * max(1, abs(distance_steps))


# ======================================================================
# shuffler vs crossbar cost model (Table 1)
# ======================================================================

# A generic W-endpoint crossbar needs ~W^2 crosspoints; the Provet
# shuffler needs W * (2*range + 1) mux inputs.  Gate/area/wire constants
# calibrated so Table 1 reproduces at the inferred paper configuration
# of 128 endpoints with reach ~11 (the paper does not state the dims;
# these are the unique (n, r) solving its gate counts):
#   shuffler 128*(2*11+1)*5.25 = 15.5k gates (paper 16k)
#   crossbar 128^2*5.25        = 86k gates   (paper 86k)
PAPER_TABLE1_ENDPOINTS = 128
PAPER_TABLE1_REACH = 11
GATES_PER_MUX_INPUT = 5.25
MM2_PER_GATE = 8.1e-6
WIRE_MM_PER_ENDPOINT_STEP = 0.00305
CROSSBAR_SPAN_FRAC = 0.66


def shuffler_cost(n_endpoints: int, reach: int) -> Dict[str, float]:
    mux_inputs = n_endpoints * (2 * reach + 1)
    gates = mux_inputs * GATES_PER_MUX_INPUT
    return {
        "gates": gates,
        "area_mm2": gates * MM2_PER_GATE,
        "wire_mm": n_endpoints * reach * WIRE_MM_PER_ENDPOINT_STEP,
    }


def crossbar_cost(n_endpoints: int) -> Dict[str, float]:
    mux_inputs = n_endpoints * n_endpoints
    gates = mux_inputs * GATES_PER_MUX_INPUT
    return {
        "gates": gates,
        "area_mm2": gates * MM2_PER_GATE,
        # average routed span ~ 0.66*W per endpoint (post-layout
        # detours; calibrated to Table 1's 33.1 mm)
        "wire_mm": n_endpoints * (CROSSBAR_SPAN_FRAC * n_endpoints)
        * WIRE_MM_PER_ENDPOINT_STEP,
    }
