"""Pure-NumPy oracles for the Provet ISA templates."""
from __future__ import annotations

import numpy as np


def conv2d_ref(img: np.ndarray, w: np.ndarray) -> np.ndarray:
    """img: (C_in,H,W); w: (C_out,C_in,K,K) -> (C_out,H-K+1,W-K+1).

    Cross-correlation (CNN convention), stride 1, valid padding."""
    C_in, H, W = img.shape
    C_out, _, K, _ = w.shape
    H_out, W_out = H - K + 1, W - K + 1
    out = np.zeros((C_out, H_out, W_out), np.float64)
    for j in range(K):
        for i in range(K):
            patch = img[:, j: j + H_out, i: i + W_out]       # (C_in,Ho,Wo)
            out += np.einsum("oc,chw->ohw", w[:, :, j, i], patch)
    return out.astype(np.float32)


def depthwise_ref(img: np.ndarray, w: np.ndarray) -> np.ndarray:
    """img: (C,H,W); w: (C,K,K)."""
    C, H, W = img.shape
    K = w.shape[-1]
    outs = [conv2d_ref(img[c: c + 1], w[c][None, None])[0] for c in range(C)]
    return np.stack(outs)


def maxpool_ref(img: np.ndarray, K: int) -> np.ndarray:
    H, W = img.shape
    return img.reshape(H // K, K, W // K, K).max(axis=(1, 3))
