"""§6.3 template/macro library: DNN layer -> (layout, ISA program).

Each template returns a ``Mapping`` holding the SRAM image to preload,
the instruction program, and an extractor that reads the result back out
of the machine state — the paper's statement that a template packages
*both* the instruction schedule and the memory layout.

The CONV dataflow is §6.1 exactly: broadcast one kernel tap -> multiply a
whole image row -> multiply-accumulate into R4 -> shift R4 one lane ->
repeat over taps; shift back after each kernel row.  (The paper's
pseudo-code shifts after every tap and then steps back by -(K-1); the
algebra only closes if the shift happens *between* taps — i.e. K-1
shifts — which is what we implement; recorded in DESIGN.md §8.)

§6.2 size mismatches:
  * image wider than the datapath  -> ``partition_image`` (halo duplication)
  * image narrower than the lanes  -> ``pack_width`` (multiple images or
    channels side by side in one VWR row)

These programs are bit-exact (tests/test_isa_conv.py asserts equality
with the NumPy oracle) and their counters cross-validate the closed-form
cost model in core/analysis.py at small sizes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.isa import (BRAN, CALC, GLMV, NOP, PERM, RLB, RMV, VFUX,
                            VMV, WLB, Instr, ProvetMachine)
from repro.core.machine import ProvetConfig


@dataclass
class Mapping:
    cfg: ProvetConfig
    sram_image: np.ndarray                    # preloaded SRAM contents
    program: List[Instr]
    extract: Callable[[ProvetMachine], np.ndarray]
    meta: Dict = field(default_factory=dict)

    def run(self, dtype=np.float32) -> Tuple[np.ndarray, ProvetMachine]:
        m = ProvetMachine(self.cfg, dtype=dtype)
        m.sram[: self.sram_image.shape[0]] = self.sram_image
        m.run(self.program)
        return self.extract(m), m


def _shift_program(src, dst, step, rng) -> List[Instr]:
    """Split a lane shift larger than the shuffler range into steps
    (§4.3.7: beyond max range by using multiple steps, more cycles)."""
    out: List[Instr] = []
    remaining = step
    cur_src = src
    while remaining != 0:
        s = max(-rng, min(rng, remaining))
        out.append(PERM(src=cur_src, dst=dst, shift=s))
        cur_src = dst
        remaining -= s
    return out


# ======================================================================
# CONV2D (§6.1) — multi-channel, stride 1, 'valid'
# ======================================================================

def conv2d(cfg: ProvetConfig, img: np.ndarray, w: np.ndarray,
           use_mac: bool = True) -> Mapping:
    """img: (C_in, H, W) with W <= vfu_width; w: (C_out, C_in, K, K).

    Output: (C_out, H-K+1, W-K+1).  Single-VFU mapping (n_vfus=1) — the
    multi-VFU case packs channels across VFUs via pack_width.
    """
    assert cfg.n_vfus == 1, "use pack_width for multi-VFU packing"
    C_in, H, W = img.shape
    C_out, C_in2, K, K2 = w.shape
    assert C_in == C_in2 and K == K2
    V = cfg.vfu_width
    S = cfg.n_slices
    assert W <= V, "partition_image first (§6.2.1)"
    H_out, W_out = H - K + 1, W - K + 1

    # ---- SRAM layout ----
    # image: channel c row r -> sram row (c*H + r)//S, slice (c*H + r)%S
    rows_img = -(-C_in * H // S)
    # kernels: flattened (C_out*C_in*K*K) operands, after the image
    k_flat = w.reshape(-1)
    rows_ker = -(-len(k_flat) // cfg.sram_width)
    k_base = rows_img
    # outputs staged after kernels
    out_base = rows_img + rows_ker
    rows_out = -(-C_out * H_out // S)
    depth_needed = out_base + rows_out
    assert depth_needed <= cfg.sram_depth, (
        f"layer needs {depth_needed} SRAM rows > depth {cfg.sram_depth}; "
        "partition the layer (§6.2.1)")

    sram = np.zeros((depth_needed, cfg.sram_width), np.float32)
    for c in range(C_in):
        for r in range(H):
            idx = c * H + r
            sram[idx // S, (idx % S) * V: (idx % S) * V + W] = img[c, r]
    for i, val in enumerate(k_flat):
        sram[k_base + i // cfg.sram_width, i % cfg.sram_width] = val

    # ---- program ----
    P: List[Instr] = []
    rng = cfg.vfu_shuffle_range
    loaded_a = [None]            # sram row currently in VWR0 (image)
    loaded_b = [None]            # sram row currently in VWR1 (kernel)

    def load_a(row):
        if loaded_a[0] != row:
            P.append(RLB(vwr=0, row=row))
            loaded_a[0] = row

    def load_b(row):
        if loaded_b[0] != row:
            P.append(RLB(vwr=1, row=row))
            loaded_b[0] = row

    for co in range(C_out):
        for k_out in range(H_out):
            # zero the accumulator
            P.append(PERM(src="R4", dst="R4", pairs=(), fill=0.0))
            for c in range(C_in):
                for j in range(K):
                    img_idx = c * H + (k_out + j)
                    load_a(img_idx // S)
                    img_slice = img_idx % S
                    for i in range(K):
                        tap = ((co * C_in + c) * K + j) * K + i
                        load_b(k_base + tap // cfg.sram_width)
                        tap_in_row = tap % cfg.sram_width
                        P.append(VMV(vwr=1, slice_idx=tap_in_row // V,
                                     dst="R1",
                                     broadcast=tap_in_row % V))
                        if use_mac:
                            P.append(VFUX(mode="mac", in1="R1",
                                          in2=(0, img_slice), out="R4",
                                          acc="R4"))
                        else:
                            P.append(VFUX(mode="mult", in1="R1",
                                          in2=(0, img_slice), out="R2"))
                            P.append(VFUX(mode="addacc", in1="R2",
                                          out="R4", acc="R4"))
                        if i < K - 1:
                            P.extend(_shift_program("R4", "R4", 1, rng))
                    if K > 1:
                        P.extend(_shift_program("R4", "R4", -(K - 1), rng))
            # write the finished output row back: with only 2 VWRs the
            # image VWR must be borrowed, so this is a read-modify-write
            # of the output SRAM row (RLB + RMV + WLB) — the §4.3.4
            # remark that a single-VWR-per-stream mapping pays extra
            # transactions.  Cost is counted honestly.
            out_idx = co * H_out + k_out
            out_row = out_base + out_idx // S
            load_a(out_row)
            P.append(RMV(vwr=0, slice_idx=out_idx % S, src="R4"))
            P.append(WLB(vwr=0, row=out_row))
            loaded_a[0] = None          # VWR0 no longer holds image data

    def extract(m: ProvetMachine) -> np.ndarray:
        out = np.zeros((C_out, H_out, W_out), np.float32)
        for co_ in range(C_out):
            for r in range(H_out):
                idx = co_ * H_out + r
                row = m.sram[out_base + idx // S]
                out[co_, r] = row[(idx % S) * V: (idx % S) * V + W_out]
        return out

    total_macs = C_out * C_in * K * K * H_out * W_out
    return Mapping(cfg, sram, P, extract,
                   meta={"total_macs": total_macs, "H_out": H_out,
                         "W_out": W_out})


def depthwise_conv2d(cfg: ProvetConfig, img: np.ndarray,
                     w: np.ndarray) -> Mapping:
    """img: (C, H, W); w: (C, K, K) — per-channel conv, no reduction.

    The paper's headline low-reuse case (MobileNet §3.4): every weight is
    used H_out*W_out times only; every activation K^2 times.
    """
    C, H, W = img.shape
    C2, K, _ = w.shape
    assert C == C2
    # a depthwise layer is C independent 1-in/1-out convs sharing layout;
    # express it exactly that way (weights block-diagonal, but without
    # materializing the zero cross terms)
    maps = [conv2d(cfg, img[c: c + 1], w[c][None, None]) for c in range(C)]

    # fuse: concatenate programs; each sub-map has its own SRAM image —
    # rebuild a combined layout instead
    return _fuse_per_channel(cfg, img, w, maps)


def _fuse_per_channel(cfg, img, w, maps) -> Mapping:
    """Run C single-channel convs back-to-back in ONE machine so the
    counters accumulate into a whole-layer total."""
    C, H, W = img.shape
    K = w.shape[-1]
    H_out, W_out = H - K + 1, W - K + 1
    sub = maps[0]
    program: List[Instr] = []
    for c in range(C):
        program.extend(maps[c].program)
    mp = Mapping(cfg, sub.sram_image, program, sub.extract,
                 meta={"total_macs": C * K * K * H_out * W_out,
                       "per_channel": maps})

    def run(dtype=np.float32):
        outs = []
        m = ProvetMachine(cfg, dtype=dtype)
        for c in range(C):
            sm = maps[c]
            m.sram[: sm.sram_image.shape[0]] = sm.sram_image
            # every sub-program re-RLBs its own rows, so stale VWR
            # contents across channels are harmless
            m.run(sm.program)
            outs.append(sm.extract(m)[0])
        return np.stack(outs), m

    mp.run = run  # type: ignore[method-assign]
    return mp


# ======================================================================
# Fully connected (GEMV)
# ======================================================================

def fc(cfg: ProvetConfig, x: np.ndarray, w: np.ndarray) -> Mapping:
    """x: (N_in,); w: (N_out, N_in); out = w @ x. N_out <= vfu_width.

    Streaming case: weights have zero reuse — the architecture's VWR
    ratio N is the *only* thing standing between the VFU and the SRAM
    (§5.1); CMR for FC ~= N * utilization.
    """
    assert cfg.n_vfus == 1
    N_out, N_in = w.shape
    V = cfg.vfu_width
    S = cfg.n_slices
    assert N_out <= V, "pack output neurons / tile first"

    # layout: x in row 0 (first ceil(N_in/W) rows); weight columns
    # w[:, i] padded to V, S columns per SRAM row.
    rows_x = -(-N_in // cfg.sram_width)
    w_base = rows_x
    rows_w = -(-N_in // S)
    out_base = w_base + rows_w
    depth = out_base + 1
    assert depth <= cfg.sram_depth, "tile FC first"

    sram = np.zeros((depth, cfg.sram_width), np.float32)
    sram[:rows_x].reshape(-1)[:N_in] = x
    for i in range(N_in):
        row, sl = w_base + i // S, i % S
        sram[row, sl * V: sl * V + N_out] = w[:, i]

    P: List[Instr] = []
    P.append(PERM(src="R4", dst="R4", pairs=(), fill=0.0))
    loaded_a = [None]
    loaded_b = [None]
    for i in range(N_in):
        xr = i // cfg.sram_width
        if loaded_a[0] != xr:
            P.append(RLB(vwr=0, row=xr))
            loaded_a[0] = xr
        wr = w_base + i // S
        if loaded_b[0] != wr:
            P.append(RLB(vwr=1, row=wr))
            loaded_b[0] = wr
        xi = i % cfg.sram_width
        P.append(VMV(vwr=0, slice_idx=xi // V, dst="R1", broadcast=xi % V))
        P.append(VFUX(mode="mac", in1="R1", in2=(1, i % S), out="R4",
                      acc="R4"))
    P.append(RMV(vwr=0, slice_idx=0, src="R4"))
    P.append(WLB(vwr=0, row=out_base))

    def extract(m: ProvetMachine) -> np.ndarray:
        return m.sram[out_base, :N_out].copy()

    return Mapping(cfg, sram, P, extract,
                   meta={"total_macs": N_out * N_in})


# ======================================================================
# Max pooling (window K, stride K)
# ======================================================================

def maxpool(cfg: ProvetConfig, img: np.ndarray, K: int) -> Mapping:
    """img: (H, W), output (H//K, W//K). Sliding max via VFU shuffler."""
    assert cfg.n_vfus == 1
    H, W = img.shape
    V = cfg.vfu_width
    S = cfg.n_slices
    assert W <= V and H % K == 0 and W % K == 0
    H_out, W_out = H // K, W // K

    rows_img = -(-H // S)
    out_base = rows_img
    sram = np.zeros((out_base + 1 + H_out // S, cfg.sram_width), np.float32)
    for r in range(H):
        sram[r // S, (r % S) * V: (r % S) * V + W] = img[r]

    P: List[Instr] = []
    rng = cfg.vfu_shuffle_range
    NEG = -3.0e38
    loaded = [None]
    for t in range(H_out):
        P.append(PERM(src="R4", dst="R4", pairs=(), fill=NEG))
        for j in range(K):
            r = t * K + j
            if loaded[0] != r // S:
                P.append(RLB(vwr=0, row=r // S))
                loaded[0] = r // S
            # R2 <- row; sliding max over i via shift+maxacc
            P.append(VMV(vwr=0, slice_idx=r % S, dst="R2"))
            P.append(VFUX(mode="maxacc", in1="R2", out="R4", acc="R4"))
            for i in range(1, K):
                P.extend(_shift_program("R2", "R2", -1, rng))
                P.append(VFUX(mode="maxacc", in1="R2", out="R4", acc="R4"))
        # R4[x] now holds max over window starting at x; gather x = K*t
        # (distances may exceed the shuffler range: staged moves)
        P.extend(_gather_strided("R4", "R3", K, W_out, rng))
        out_row = out_base + t // S
        if loaded[0] != out_row:            # read-modify-write staging
            P.append(RLB(vwr=0, row=out_row))
        P.append(RMV(vwr=0, slice_idx=t % S, src="R3"))
        P.append(WLB(vwr=0, row=out_row))
        loaded[0] = None

    def extract(m: ProvetMachine) -> np.ndarray:
        out = np.zeros((H_out, W_out), np.float32)
        for t_ in range(H_out):
            row = m.sram[out_base + t_ // S]
            out[t_] = row[(t_ % S) * V: (t_ % S) * V + W_out]
        return out

    return Mapping(cfg, sram, P, extract,
                   meta={"total_macs": H_out * W_out * K * K})


def _gather_strided(src, dst, K, n, rng) -> List[Instr]:
    """dst[q] = src[K*q] for q < n, emitted as range-legal PERM stages."""
    out: List[Instr] = []
    # stage moves: process in descending distance so sources aren't
    # overwritten; all pairs move left (d < s), multi-step if needed.
    cur = {q: K * q for q in range(n)}
    step = 0
    while any(cur[q] != q for q in cur):
        pairs = []
        for q in range(n):
            s = cur[q]
            d = max(q, s - rng)
            pairs.append((s, d))
            cur[q] = d
        out.append(PERM(src=src if step == 0 else dst, dst=dst,
                        pairs=tuple(pairs), fill=0.0))
        step += 1
    if step == 0:
        out.append(PERM(src=src, dst=dst, shift=0))
    return out


# ======================================================================
# §6.2 size-mismatch handling
# ======================================================================

def partition_image(img: np.ndarray, max_w: int, K: int
                    ) -> List[Tuple[np.ndarray, int]]:
    """§6.2.1: split (C,H,W) into vertical strips of width <= max_w with
    K-1 halo duplication. Returns [(strip, out_col_offset)]."""
    C, H, W = img.shape
    strips = []
    out_w = max_w - K + 1
    x = 0
    while x < W - K + 1:
        strip = img[:, :, x: x + max_w]
        strips.append((strip, x))
        x += out_w
    return strips


def stitch_strips(parts: List[Tuple[np.ndarray, int]], W_out: int
                  ) -> np.ndarray:
    """Reassemble strip conv outputs into the full-width output."""
    C_out, H_out = parts[0][0].shape[:2]
    out = np.zeros((C_out, H_out, W_out), np.float32)
    for arr, off in parts:
        w = min(arr.shape[2], W_out - off)
        out[:, :, off: off + w] = arr[:, :, :w]
    return out


def pack_width(images: List[np.ndarray], lane_width: int, K: int
               ) -> Tuple[np.ndarray, List[Tuple[int, int]]]:
    """§6.2.2: place multiple narrow images side by side in the lanes.

    Each image is padded by K-1 dead lanes so kernels never straddle two
    images. Returns (packed (C,H,W_packed), [(offset, width)]).
    """
    C, H = images[0].shape[:2]
    spans = []
    cols = []
    off = 0
    for im in images:
        w = im.shape[2]
        assert off + w <= lane_width, "images do not fit the lanes"
        spans.append((off, w))
        cols.append(im)
        off += w + (K - 1)          # dead zone between images
    packed = np.zeros((C, H, min(off, lane_width)), np.float32)
    for (o, w), im in zip(spans, cols):
        packed[:, :, o: o + w] = im
    return packed, spans
