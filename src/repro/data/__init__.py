from repro.data.pipeline import DataConfig, SyntheticLM, MemmapTokens, Prefetcher, pack_documents  # noqa: F401
