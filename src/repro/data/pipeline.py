"""Deterministic sharded data pipeline.

Sources:
  * SyntheticLM — stateless hash-based token stream: batch(step, shard)
    is a pure function, so any worker can reproduce any shard of any
    step (the property that makes checkpoint/restart and elastic
    re-sharding trivial: no data-loader state to save).
  * MemmapTokens — np.memmap over a flat token file (the real-data
    path); documents are packed into fixed-length rows with EOS
    boundaries and a loss mask that zeroes the first token of each doc.

Background prefetch: a double-buffered thread pipelines host batch
assembly under device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1          # data-parallel shards
    seed: int = 0


class SyntheticLM:
    """Pure-function LM batches: zipfian-ish tokens + shifted labels."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_shards == 0
        self.shard_batch = cfg.global_batch // cfg.n_shards

    def batch(self, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.uint64(c.seed) + np.uint64(step) * np.uint64(c.n_shards)
            + np.uint64(shard))
        # zipf-flavoured ids clipped to vocab (heavy head, like text)
        raw = rng.zipf(1.3, size=(self.shard_batch, c.seq_len + 1))
        tokens = (raw % (c.vocab - 2)).astype(np.int32) + 2
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:].astype(np.int32),
            "loss_mask": np.ones((self.shard_batch, c.seq_len),
                                 np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapTokens:
    """Packed-document loader over a flat int32 token file."""

    def __init__(self, path: str, cfg: DataConfig, eos_id: int = 1):
        self.cfg = cfg
        self.eos = eos_id
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.shard_batch = cfg.global_batch // cfg.n_shards
        self.rows_per_step = cfg.global_batch
        self.n_steps = (len(self.tokens) - 1) // (
            cfg.seq_len * cfg.global_batch)

    def batch(self, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
        c = self.cfg
        step = step % max(self.n_steps, 1)
        base = step * c.seq_len * c.global_batch \
            + shard * c.seq_len * self.shard_batch
        flat = np.asarray(
            self.tokens[base: base + self.shard_batch * c.seq_len + 1])
        tokens = flat[:-1].reshape(self.shard_batch, c.seq_len)
        labels = flat[1:].reshape(self.shard_batch, c.seq_len)
        # mask out the position after each EOS (cross-document leakage)
        mask = np.ones_like(labels, np.float32)
        mask[tokens == self.eos] = 0.0
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32), "loss_mask": mask}


def pack_documents(docs, seq_len: int, eos_id: int = 1) -> np.ndarray:
    """Pack variable-length docs into fixed rows with EOS separators."""
    flat = []
    for d in docs:
        flat.extend(int(t) for t in d)
        flat.append(eos_id)
    n_rows = max(1, len(flat) // seq_len)
    flat = flat[: n_rows * seq_len]
    return np.asarray(flat, np.int32).reshape(n_rows, seq_len)


class Prefetcher:
    """Double-buffered background prefetch of an iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
