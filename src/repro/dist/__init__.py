"""Multi-device distribution layer.

The paper's hierarchy — local VWR / intermediate SRAM / global memory —
maps onto the multi-device stack as shard-local VMEM / per-device HBM /
the interconnect, and the same discipline applies: keep traffic in the
near tier, and when it must cross the far tier, cross it in the widest,
fewest transactions possible.  Each module here is one primitive of
that discipline:

  sharding     one vocabulary (logical axes -> mesh PartitionSpecs) for
               params, train batches, and decode caches, per model
               family and strategy ('fsdp_tp' | 'ddp' | 'serve')
  decode       distributed FlashDecoding: sequence-sharded KV cache,
               per-shard unnormalized softmax partials, one small
               (B, H)-sized combine over the interconnect instead of
               moving the cache
  pipeline     GPipe-style microbatch schedule over a 'pipe' axis with
               ppermute stage handoff (activations move, weights don't)
  compression  int8-quantized all-reduce with error feedback: 4x fewer
               wire bytes per gradient sync, bias carried to the next
               step instead of lost
"""
from repro.dist import compression, decode, pipeline, sharding  # noqa: F401
