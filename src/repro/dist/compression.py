"""Compressed collectives: int8 all-reduce with error feedback.

Gradient sync is the collective that keeps the interconnect saturated
during data-parallel training — the 'global memory' tier of the
multi-device hierarchy.  Quantizing the payload to int8 cuts the wire
bytes 4x (fp32) at the cost of a per-step rounding bias; carrying that
bias forward as *error feedback* (residual added to the next step's
input before quantization) makes the long-run average unbiased —
the two-step mean is strictly closer to the true mean than either
single step (the contract ``tests/test_dist.py`` pins).

Wire format is honest about the compression: the int8 payload and the
per-shard fp32 scale are all-gathered (bytes = n * (size + 4) instead
of the fp32 ring all-reduce's ~2 * 4 * size), and the dequantized sum
is taken locally.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# one int8 idiom in the repo: the wire payload here and the quantized
# KV page pools (engine.paged_cache) share kernels.quant
from repro.kernels.quant import quantize_int8  # noqa: F401  (re-export)


def compressed_psum(x: jax.Array, err: jax.Array, axis_name: str,
                    n_devices: int) -> Tuple[jax.Array, jax.Array]:
    """Mean-reduce ``x`` over ``axis_name`` with int8 payloads.

    Must run inside shard_map/pmap over ``axis_name``.  ``err`` is this
    shard's error-feedback residual from the previous call (zeros on
    the first step).  Returns (mean estimate, new residual); the
    estimate equals ``psum(x)/n`` up to int8 rounding, and feeding the
    residual back shrinks the accumulated bias step over step.
    """
    corrected = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = quantize_int8(corrected)
    deq = q.astype(jnp.float32) * scale
    new_err = corrected - deq
    # int8 + per-shard scale on the wire; dequantize-and-sum locally
    qs = jax.lax.all_gather(q, axis_name)             # (n, *shape) int8
    ss = jax.lax.all_gather(scale, axis_name)         # (n,)
    ss = ss.reshape((n_devices,) + (1,) * x.ndim)
    out = jnp.sum(qs.astype(jnp.float32) * ss, axis=0) / n_devices
    return out.astype(x.dtype), new_err.astype(err.dtype)
