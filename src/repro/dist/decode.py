"""Distributed FlashDecoding: sequence-sharded KV cache decode.

Decode attention at long context is the purest form of the paper's
streaming workload: the KV cache is read once per generated token with
zero reuse, so the byte path — not FLOPs — sets the latency.  Moving
the cache across the interconnect would put those bytes on the *global*
tier; instead each model shard keeps a contiguous slab of the context
resident in its own HBM, computes an **unnormalized** online-softmax
partial against its slab, and only the (B, H)-sized running statistics
cross the wire:

    m* = pmax_i m_i
    o  = sum_i o~_i * exp(m_i - m*)  /  sum_i l_i * exp(m_i - m*)

(`models.attention.flash_decode_partial` documents the same contract
from the single-shard side.)  Collective bytes per token are
O(B * H * (Dh + 2)) — independent of context length.

Per shard the partial comes from the ``decode_partial`` op of the
kernel-dispatch registry (``repro.kernels.dispatch``): backend 'xla'
is the einsum reference, 'pallas' the VWR flash-decode kernel staging
the local slab in wide (bkv x Dh) VMEM blocks, 'auto' the measured
winner.  GQA, absorbed MLA (via ``mla.mla_absorbed_mqa``'s KV=1 view)
and encoder cross-attention all decode through this one surface.

The mesh is an **explicit argument** everywhere here; ``decode_attend``
falls back to the ambient ``with mesh:`` context only through the
deprecated ``hints.resolve_mesh`` shim.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.common.hints import resolve_mesh
from repro.kernels import dispatch as D
from repro.models.attention import decode_attend_local  # noqa: F401  (re-export)


def _normalize(o_t, l, dtype):
    return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def local_decode_attend(q, cache_k, cache_v, cur_len, *,
                        backend="xla") -> jax.Array:
    """Single-shard decode attention (normalized) through the dispatch
    registry."""
    o_t, m, l = D.dispatch("decode_partial", backend, q, cache_k,
                           cache_v, cur_len)
    return _normalize(o_t, l, q.dtype)


def sharded_flash_decode(mesh, q, cache_k, cache_v, cur_len, *,
                         backend: str = "xla",
                         data_axis: str = "data",
                         model_axis: str = "model",
                         kernel_impl: Optional[str] = None):
    """Decode attention with the cache sequence-sharded over
    ``model_axis`` and the batch over ``data_axis``.

    q: (B, H, Dh) one new token; cache_k/v: (B, T, KV, Dh);
    cur_len: scalar count of valid positions (global).  Returns the
    normalized (B, H, Dh) context, bitwise-equivalent (up to fp
    reassociation) to the single-shard path on the unsharded cache.
    ``kernel_impl`` is a deprecated alias for ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.sharded_flash_decode")
        backend = kernel_impl
    # 'auto' resolves HERE, outside shard_map, by cache lookup only
    # (replaying a winner the local decode path measured for these
    # shapes, if any): the measuring dispatch tuner — like the block
    # tuner, hence tune=False below — must not run timed kernels
    # inside shard_map tracing
    backend = D.cached_backend("decode_partial", backend,
                               (q, cache_k, cache_v, cur_len))
    B, H, Dh = q.shape
    T = cache_k.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        # no model axis / ragged split: single-shard reference
        return local_decode_attend(q, cache_k, cache_v, cur_len,
                                   backend=backend)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, k, v, cur):
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = D.dispatch("decode_partial", backend, q, k, v, cur,
                               pos0, tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scale = jnp.exp(m - m_star)                       # (B, H)
        o = jax.lax.psum(o_t * scale[..., None], model_axis)
        l = jax.lax.psum(l * scale, model_axis)
        return _normalize(o, l, q.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, model_axis, None, None),
                  PS(dp, model_axis, None, None),
                  PS()),
        out_specs=PS(dp, None, None),
        # the psum/pmax combine replicates the output over the model
        # axis by construction, but check_rep has no rule for
        # pallas_call — disable the static check rather than the path
        check_rep=False)
    return fn(q, cache_k, cache_v,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def _page_counts(lens, J, page_size):
    """(B,) valid-position counts -> (B, J) per-logical-page counts."""
    return jnp.clip(lens[:, None]
                    - jnp.arange(J, dtype=jnp.int32)[None, :] * page_size,
                    0, page_size).astype(jnp.int32)


def local_paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                              backend="xla") -> jax.Array:
    """Single-shard paged decode attention (normalized).

    q: (B, H, Dh); k_pool/v_pool: (n_pages, page_size, KV, Dh);
    table: (B, max_pages) int32; lens: (B,) int32 valid positions per
    slot (0 = inactive slot -> zero output)."""
    ps = k_pool.shape[1]
    counts = _page_counts(lens, table.shape[1], ps)
    o_t, m, l = D.dispatch("decode_partial_paged", backend, q, k_pool,
                           v_pool, table, counts)
    return _normalize(o_t, l, q.dtype)


def sharded_paged_flash_decode(mesh, q, k_pool, v_pool, table, lens, *,
                               backend: str = "xla",
                               data_axis: str = "data",
                               model_axis: str = "model"):
    """Paged decode attention with the page POOL sharded over
    ``model_axis`` (shard s owns the contiguous slab of pages
    [s*pp, (s+1)*pp)) and the slot batch over ``data_axis``.

    Block tables are replicated and may point at any shard's pages:
    each shard zeroes the counts of pages outside its slab, computes
    the unnormalized partial over the pages it owns, and the same
    pmax/psum statistics combine as ``sharded_flash_decode`` stitches
    the slots back together — so page->shard placement is free (the
    allocator never needs to know the mesh).  Per-token collective
    bytes stay O(B * H * (Dh + 2)), independent of pool size.
    """
    backend = D.cached_backend("decode_partial_paged", backend,
                               (q, k_pool, v_pool, table, lens))
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or n_pages % msize:
        return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                         backend=backend)
    pp = n_pages // msize
    B = q.shape[0]
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)
    J = table.shape[1]

    def shard_fn(q, kp, vp, tbl, lens):
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        counts = jnp.where(owned, _page_counts(lens, J, ps), 0)
        o_t, m, l = D.dispatch("decode_partial_paged", backend, q, kp,
                               vp, tloc, counts, tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scale = jnp.exp(m - m_star)
        o = jax.lax.psum(o_t * scale[..., None], model_axis)
        l = jax.lax.psum(l * scale, model_axis)
        return _normalize(o, l, q.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(model_axis, None, None, None),
                  PS(model_axis, None, None, None),
                  PS(dp, None),
                  PS(dp)),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q, k_pool, v_pool, table.astype(jnp.int32),
              jnp.asarray(lens, jnp.int32))


def paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                        backend: str = "xla", mesh=None,
                        seq_shard: bool = True) -> jax.Array:
    """Mesh-aware paged decode attention used by ``models.lm``.

    The paged sibling of ``decode_attend``: routes to
    ``sharded_paged_flash_decode`` when ``seq_shard`` and a mesh with a
    'model' axis divides the pool evenly, else the local registry op.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.paged_decode_attend")
        n_pages = k_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_paged_flash_decode(mesh, q, k_pool, v_pool,
                                              table, lens,
                                              backend=backend)
    return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                     backend=backend)


def decode_attend(q, cache_k, cache_v, cur_len, *,
                  backend: str = "xla",
                  mesh=None, seq_shard: bool = True,
                  kernel_impl: Optional[str] = None) -> jax.Array:
    """Mesh-aware decode attention used by ``models.lm``.

    Routes to ``sharded_flash_decode`` when ``seq_shard`` and a mesh
    with a 'model' axis is available and the cache splits evenly; falls
    back to the local registry path otherwise, so the same model code
    serves one chip and a pod.  Pass the mesh explicitly (the engine
    does); omitting it hits the deprecated ambient-mesh fallback in
    ``hints.resolve_mesh``.  ``kernel_impl`` is a deprecated alias for
    ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.decode_attend")
        backend = kernel_impl
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.decode_attend")
        T = cache_k.shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and T % mesh.shape["model"] == 0):
            return sharded_flash_decode(mesh, q, cache_k, cache_v,
                                        cur_len, backend=backend)
    return local_decode_attend(q, cache_k, cache_v, cur_len,
                               backend=backend)
