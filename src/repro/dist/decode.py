"""Distributed FlashDecoding: sequence-sharded KV cache decode.

Decode attention at long context is the purest form of the paper's
streaming workload: the KV cache is read once per generated token with
zero reuse, so the byte path — not FLOPs — sets the latency.  Moving
the cache across the interconnect would put those bytes on the *global*
tier; instead each model shard keeps a contiguous slab of the context
resident in its own HBM, computes an **unnormalized** online-softmax
partial against its slab, and only the (B, H)-sized running statistics
cross the wire:

    m* = pmax_i m_i
    o  = sum_i o~_i * exp(m_i - m*)  /  sum_i l_i * exp(m_i - m*)

(`models.attention.flash_decode_partial` documents the same contract
from the single-shard side.)  Collective bytes per token are
O(B * H * (Dh + 2)) — independent of context length.

Per shard the partial is computed either by the XLA reference
(`flash_decode_partial`) or, when ``kernel_impl == 'pallas'``, by the
VWR flash-decode kernel (`repro.kernels.ops.vwr_flash_decode`), which
stages the local cache slab in wide (bkv x Dh) VMEM blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.common.hints import ambient_mesh
from repro.models.attention import decode_attend_local, flash_decode_partial


def _local_partial(q, k, v, cur_len, pos0, n_local, kernel_impl):
    """(o_tilde, m, l) for one contiguous cache slab starting at global
    position ``pos0``."""
    if kernel_impl == "pallas":
        from repro.kernels import autotune, ops
        # block size from the cost-model prior only: the measuring
        # tuner must not fire inside shard_map tracing
        cands = autotune.decode_candidates(n_local, q.shape[-1],
                                           str(q.dtype))
        bkv = min(cands, key=lambda c: autotune.decode_prior(
            q.shape[0], n_local, q.shape[1], k.shape[2], q.shape[-1],
            str(q.dtype), c))[0]
        return ops.vwr_flash_decode(q, k, v, cur_len, pos0=pos0,
                                    bkv=bkv)
    kv_positions = pos0 + jnp.arange(n_local)
    return flash_decode_partial(q, k, v, kv_positions, cur_len)


def sharded_flash_decode(mesh, q, cache_k, cache_v, cur_len, *,
                         kernel_impl: str = "xla",
                         data_axis: str = "data",
                         model_axis: str = "model"):
    """Decode attention with the cache sequence-sharded over
    ``model_axis`` and the batch over ``data_axis``.

    q: (B, H, Dh) one new token; cache_k/v: (B, T, KV, Dh);
    cur_len: scalar count of valid positions (global).  Returns the
    normalized (B, H, Dh) context, bitwise-equivalent (up to fp
    reassociation) to ``decode_attend_local`` on the unsharded cache.
    """
    B, H, Dh = q.shape
    T = cache_k.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        # no model axis / ragged split: single-shard reference
        return decode_attend_local(q, cache_k, cache_v, jnp.arange(T),
                                   cur_len)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, k, v, cur):
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = _local_partial(q, k, v, cur, pos0, n_local,
                                   kernel_impl)
        m_star = jax.lax.pmax(m, model_axis)
        scale = jnp.exp(m - m_star)                       # (B, H)
        o = jax.lax.psum(o_t * scale[..., None], model_axis)
        l = jax.lax.psum(l * scale, model_axis)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, model_axis, None, None),
                  PS(dp, model_axis, None, None),
                  PS()),
        out_specs=PS(dp, None, None),
        # the psum/pmax combine replicates the output over the model
        # axis by construction, but check_rep has no rule for
        # pallas_call — disable the static check rather than the path
        check_rep=False)
    return fn(q, cache_k, cache_v,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def decode_attend(q, cache_k, cache_v, cur_len, *,
                  kernel_impl: str = "xla",
                  mesh=None) -> jax.Array:
    """Mesh-aware decode attention used by ``models.lm``.

    Routes to ``sharded_flash_decode`` when a mesh with a 'model' axis
    is available (explicitly or ambient) and the cache splits evenly;
    falls back to the local kernel/XLA path otherwise, so the same
    model code serves one chip and a pod.
    """
    mesh = mesh if mesh is not None else ambient_mesh()
    T = cache_k.shape[1]
    if (mesh is not None and "model" in mesh.axis_names
            and T % mesh.shape["model"] == 0):
        return sharded_flash_decode(mesh, q, cache_k, cache_v, cur_len,
                                    kernel_impl=kernel_impl)
    if kernel_impl == "pallas":
        from repro.kernels import ops
        o_t, m, l = ops.vwr_flash_decode(q, cache_k, cache_v, cur_len)
        return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return decode_attend_local(q, cache_k, cache_v, jnp.arange(T),
                               cur_len)
