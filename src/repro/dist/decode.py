"""Distributed FlashDecoding: sequence-sharded KV cache decode.

Decode attention at long context is the purest form of the paper's
streaming workload: the KV cache is read once per generated token with
zero reuse, so the byte path — not FLOPs — sets the latency.  Moving
the cache across the interconnect would put those bytes on the *global*
tier; instead each model shard keeps a contiguous slab of the context
resident in its own HBM, computes an **unnormalized** online-softmax
partial against its slab, and only the (B, H)-sized running statistics
cross the wire:

    m* = pmax_i m_i
    o  = sum_i o~_i * exp(m_i - m*)  /  sum_i l_i * exp(m_i - m*)

(`models.attention.flash_decode_partial` documents the same contract
from the single-shard side.)  Collective bytes per token are
O(B * H * (Dh + 2)) — independent of context length.

Per shard the partial comes from the kernel-dispatch registry
(``repro.kernels.dispatch``): backend 'xla' is the einsum reference,
'pallas' the VWR flash-decode kernel staging the local slab in wide
(bkv x Dh) VMEM blocks, 'auto' the measured winner.  GQA and encoder
cross-attention decode through ``decode_partial`` /
``decode_partial_paged``; absorbed MLA decodes through the
split-operand ``decode_partial_mla`` / ``decode_partial_mla_paged``
ops (latent + rope caches as separate operands — no k_cat/v_cat
copies, no rope zero-pad in the value stream), all sharing the one
pmax/psum statistics combine.

The mesh is an **explicit argument** everywhere here; ``decode_attend``
falls back to the ambient ``with mesh:`` context only through the
deprecated ``hints.resolve_mesh`` shim.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.common.hints import resolve_mesh
from repro.kernels import dispatch as D
from repro.models.attention import decode_attend_local  # noqa: F401  (re-export)


def _normalize(o_t, l, dtype):
    return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def local_decode_attend(q, cache_k, cache_v, cur_len, *,
                        backend="xla") -> jax.Array:
    """Single-shard decode attention (normalized) through the dispatch
    registry."""
    o_t, m, l = D.dispatch("decode_partial", backend, q, cache_k,
                           cache_v, cur_len)
    return _normalize(o_t, l, q.dtype)


def sharded_flash_decode(mesh, q, cache_k, cache_v, cur_len, *,
                         backend: str = "xla",
                         data_axis: str = "data",
                         model_axis: str = "model",
                         kernel_impl: Optional[str] = None):
    """Decode attention with the cache sequence-sharded over
    ``model_axis`` and the batch over ``data_axis``.

    q: (B, H, Dh) one new token; cache_k/v: (B, T, KV, Dh);
    cur_len: scalar count of valid positions (global).  Returns the
    normalized (B, H, Dh) context, bitwise-equivalent (up to fp
    reassociation) to the single-shard path on the unsharded cache.
    ``kernel_impl`` is a deprecated alias for ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.sharded_flash_decode")
        backend = kernel_impl
    # 'auto' resolves HERE, outside shard_map, by cache lookup only
    # (replaying a winner the local decode path measured for these
    # shapes, if any): the measuring dispatch tuner — like the block
    # tuner, hence tune=False below — must not run timed kernels
    # inside shard_map tracing
    backend = D.cached_backend("decode_partial", backend,
                               (q, cache_k, cache_v, cur_len))
    B, H, Dh = q.shape
    T = cache_k.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        # no model axis / ragged split: single-shard reference
        return local_decode_attend(q, cache_k, cache_v, cur_len,
                                   backend=backend)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, k, v, cur):
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = D.dispatch("decode_partial", backend, q, k, v, cur,
                               pos0, tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scale = jnp.exp(m - m_star)                       # (B, H)
        o = jax.lax.psum(o_t * scale[..., None], model_axis)
        l = jax.lax.psum(l * scale, model_axis)
        return _normalize(o, l, q.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, model_axis, None, None),
                  PS(dp, model_axis, None, None),
                  PS()),
        out_specs=PS(dp, None, None),
        # the psum/pmax combine replicates the output over the model
        # axis by construction, but check_rep has no rule for
        # pallas_call — disable the static check rather than the path
        check_rep=False)
    return fn(q, cache_k, cache_v,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def sharded_mla_flash_decode(mesh, q_abs, q_rope, cache_ckv,
                             cache_krope, cur_len, *, scale: float,
                             backend: str = "xla",
                             data_axis: str = "data",
                             model_axis: str = "model"):
    """Split-operand absorbed-MLA decode with BOTH latent caches
    sequence-sharded over ``model_axis`` and the batch over
    ``data_axis``.

    q_abs: (B, H, r) fp32 (pre-folded through wk_b); q_rope: (B, H,
    rope); cache_ckv: (B, T, r); cache_krope: (B, T, rope); cur_len:
    scalar global valid count.  Each shard computes the unnormalized
    partial against its slab through the ``decode_partial_mla``
    registry op — latent and rope operands stay separate all the way
    into the kernel, so no shard ever materializes k_cat/v_cat copies
    — and the same pmax/psum statistics combine as
    ``sharded_flash_decode`` stitches the softmax.  Returns the
    normalized (B, H, r) latent context."""
    backend = D.cached_backend("decode_partial_mla", backend,
                               (q_abs, q_rope, cache_ckv, cache_krope,
                                cur_len), {"scale": scale})
    B, H, r = q_abs.shape
    T = cache_ckv.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        return local_mla_decode_attend(q_abs, q_rope, cache_ckv,
                                       cache_krope, cur_len,
                                       scale=scale, backend=backend)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(qa, qr, ckv, kr, cur):
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = D.dispatch("decode_partial_mla", backend, qa, qr,
                               ckv, kr, cur, pos0, scale=scale,
                               tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scl = jnp.exp(m - m_star)                         # (B, H)
        o = jax.lax.psum(o_t * scl[..., None], model_axis)
        l = jax.lax.psum(l * scl, model_axis)
        return _normalize(o, l, qa.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, None, None),
                  PS(dp, model_axis, None),
                  PS(dp, model_axis, None),
                  PS()),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q_abs, q_rope, cache_ckv, cache_krope,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def local_mla_decode_attend(q_abs, q_rope, cache_ckv, cache_krope,
                            cur_len, *, scale: float,
                            backend="xla") -> jax.Array:
    """Single-shard split-operand MLA decode attention (normalized
    (B, H, r) latent context) through the dispatch registry."""
    o_t, m, l = D.dispatch("decode_partial_mla", backend, q_abs, q_rope,
                           cache_ckv, cache_krope, cur_len, scale=scale)
    return _normalize(o_t, l, q_abs.dtype)


def mla_decode_attend(q_abs, q_rope, cache_ckv, cache_krope, cur_len, *,
                      scale: float, backend: str = "xla", mesh=None,
                      seq_shard: bool = True) -> jax.Array:
    """Mesh-aware split-operand MLA decode attention used by
    ``models.lm``.

    The MLA sibling of ``decode_attend``: routes to
    ``sharded_mla_flash_decode`` when ``seq_shard`` and a mesh with a
    'model' axis divides the cache evenly, else the local registry op.
    The latent and rope caches ride as separate operands end to end —
    the copy-free replacement for the concatenated
    ``mla_absorbed_mqa`` + ``decode_attend`` route.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.mla_decode_attend")
        T = cache_ckv.shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and T % mesh.shape["model"] == 0):
            return sharded_mla_flash_decode(mesh, q_abs, q_rope,
                                            cache_ckv, cache_krope,
                                            cur_len, scale=scale,
                                            backend=backend)
    return local_mla_decode_attend(q_abs, q_rope, cache_ckv,
                                   cache_krope, cur_len, scale=scale,
                                   backend=backend)


def _page_counts(lens, J, page_size):
    """(B,) valid-position counts -> (B, J) per-logical-page counts."""
    return jnp.clip(lens[:, None]
                    - jnp.arange(J, dtype=jnp.int32)[None, :] * page_size,
                    0, page_size).astype(jnp.int32)


def local_paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                              backend="xla") -> jax.Array:
    """Single-shard paged decode attention (normalized).

    q: (B, H, Dh); k_pool/v_pool: (n_pages, page_size, KV, Dh);
    table: (B, max_pages) int32; lens: (B,) int32 valid positions per
    slot (0 = inactive slot -> zero output)."""
    ps = k_pool.shape[1]
    J = table.shape[1]
    counts = _page_counts(lens, J, ps)
    # page_size/max_pages ride as static kwargs so the page geometry
    # is an EXPLICIT part of the dispatch cache key (see the note at
    # the registered impls in models/attention.py)
    o_t, m, l = D.dispatch("decode_partial_paged", backend, q, k_pool,
                           v_pool, table, counts, page_size=ps,
                           max_pages=J)
    return _normalize(o_t, l, q.dtype)


def sharded_paged_flash_decode(mesh, q, k_pool, v_pool, table, lens, *,
                               backend: str = "xla",
                               data_axis: str = "data",
                               model_axis: str = "model"):
    """Paged decode attention with the page POOL sharded over
    ``model_axis`` (shard s owns the contiguous slab of pages
    [s*pp, (s+1)*pp)) and the slot batch over ``data_axis``.

    Block tables are replicated and may point at any shard's pages:
    each shard zeroes the counts of pages outside its slab, computes
    the unnormalized partial over the pages it owns, and the same
    pmax/psum statistics combine as ``sharded_flash_decode`` stitches
    the slots back together — so page->shard placement is free (the
    allocator never needs to know the mesh).  Per-token collective
    bytes stay O(B * H * (Dh + 2)), independent of pool size.
    """
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    J = table.shape[1]
    # cache lookup under the same signature the LOCAL measuring path
    # writes — (B, J) counts, not (B,) lens — plus the page geometry
    # statics, so a winner measured locally replays here and a winner
    # from another (page_size, max_pages) does not
    backend = D.cached_backend(
        "decode_partial_paged", backend,
        (q, k_pool, v_pool, table, _page_counts(lens, J, ps)),
        {"page_size": ps, "max_pages": J})
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or n_pages % msize:
        return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                         backend=backend)
    pp = n_pages // msize
    B = q.shape[0]
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, kp, vp, tbl, lens):
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        counts = jnp.where(owned, _page_counts(lens, J, ps), 0)
        o_t, m, l = D.dispatch("decode_partial_paged", backend, q, kp,
                               vp, tloc, counts, page_size=ps,
                               max_pages=J, tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scale = jnp.exp(m - m_star)
        o = jax.lax.psum(o_t * scale[..., None], model_axis)
        l = jax.lax.psum(l * scale, model_axis)
        return _normalize(o, l, q.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(model_axis, None, None, None),
                  PS(model_axis, None, None, None),
                  PS(dp, None),
                  PS(dp)),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q, k_pool, v_pool, table.astype(jnp.int32),
              jnp.asarray(lens, jnp.int32))


def paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                        backend: str = "xla", mesh=None,
                        seq_shard: bool = True) -> jax.Array:
    """Mesh-aware paged decode attention used by ``models.lm``.

    The paged sibling of ``decode_attend``: routes to
    ``sharded_paged_flash_decode`` when ``seq_shard`` and a mesh with a
    'model' axis divides the pool evenly, else the local registry op.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.paged_decode_attend")
        n_pages = k_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_paged_flash_decode(mesh, q, k_pool, v_pool,
                                              table, lens,
                                              backend=backend)
    return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                     backend=backend)


def local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool, krope_pool,
                                  table, lens, *, scale: float,
                                  backend="xla") -> jax.Array:
    """Single-shard split-operand paged MLA decode attention
    (normalized (B, H, r) latent context).

    q_abs: (B, H, r) fp32; q_rope: (B, H, rope); ckv_pool: (n_pages,
    page_size, r); krope_pool: (n_pages, page_size, rope); table:
    (B, max_pages) int32; lens: (B,) int32 valid positions per slot."""
    ps = ckv_pool.shape[1]
    J = table.shape[1]
    counts = _page_counts(lens, J, ps)
    o_t, m, l = D.dispatch("decode_partial_mla_paged", backend, q_abs,
                           q_rope, ckv_pool, krope_pool, table, counts,
                           scale=scale, page_size=ps, max_pages=J)
    return _normalize(o_t, l, q_abs.dtype)


def sharded_mla_paged_flash_decode(mesh, q_abs, q_rope, ckv_pool,
                                   krope_pool, table, lens, *,
                                   scale: float, backend: str = "xla",
                                   data_axis: str = "data",
                                   model_axis: str = "model"):
    """Split-operand paged MLA decode with BOTH latent pools sharded
    over ``model_axis`` (shard s owns pages [s*pp, (s+1)*pp)) and the
    slot batch over ``data_axis``.

    Same ownership-masked-counts construction as
    ``sharded_paged_flash_decode`` — block tables are replicated, each
    shard zeroes the counts of foreign pages and the pmax/psum
    statistics combine stitches the slots — so page->shard placement
    stays free, and no shard ever builds a pool-wide k_cat/v_cat copy.
    """
    n_pages, ps = ckv_pool.shape[0], ckv_pool.shape[1]
    J = table.shape[1]
    backend = D.cached_backend(
        "decode_partial_mla_paged", backend,
        (q_abs, q_rope, ckv_pool, krope_pool, table,
         _page_counts(lens, J, ps)),
        {"scale": scale, "page_size": ps, "max_pages": J})
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or n_pages % msize:
        return local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool,
                                             krope_pool, table, lens,
                                             scale=scale,
                                             backend=backend)
    pp = n_pages // msize
    B = q_abs.shape[0]
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(qa, qr, ckv, kr, tbl, lens):
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        counts = jnp.where(owned, _page_counts(lens, J, ps), 0)
        o_t, m, l = D.dispatch("decode_partial_mla_paged", backend, qa,
                               qr, ckv, kr, tloc, counts, scale=scale,
                               page_size=ps, max_pages=J, tune=False)
        m_star = jax.lax.pmax(m, model_axis)
        scl = jnp.exp(m - m_star)
        o = jax.lax.psum(o_t * scl[..., None], model_axis)
        l = jax.lax.psum(l * scl, model_axis)
        return _normalize(o, l, qa.dtype)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, None, None),
                  PS(model_axis, None, None),
                  PS(model_axis, None, None),
                  PS(dp, None),
                  PS(dp)),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q_abs, q_rope, ckv_pool, krope_pool,
              table.astype(jnp.int32), jnp.asarray(lens, jnp.int32))


def mla_paged_decode_attend(q_abs, q_rope, ckv_pool, krope_pool, table,
                            lens, *, scale: float, backend: str = "xla",
                            mesh=None, seq_shard: bool = True
                            ) -> jax.Array:
    """Mesh-aware split-operand paged MLA decode attention used by
    ``models.lm``.

    Routes to ``sharded_mla_paged_flash_decode`` when ``seq_shard`` and
    a mesh with a 'model' axis divides the pool evenly, else the local
    registry op — the copy-free replacement for concatenating the two
    pools into a KV=1 view of ``paged_decode_attend``.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.mla_paged_decode_attend")
        n_pages = ckv_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_mla_paged_flash_decode(
                mesh, q_abs, q_rope, ckv_pool, krope_pool, table, lens,
                scale=scale, backend=backend)
    return local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool,
                                         krope_pool, table, lens,
                                         scale=scale, backend=backend)


def decode_attend(q, cache_k, cache_v, cur_len, *,
                  backend: str = "xla",
                  mesh=None, seq_shard: bool = True,
                  kernel_impl: Optional[str] = None) -> jax.Array:
    """Mesh-aware decode attention used by ``models.lm``.

    Routes to ``sharded_flash_decode`` when ``seq_shard`` and a mesh
    with a 'model' axis is available and the cache splits evenly; falls
    back to the local registry path otherwise, so the same model code
    serves one chip and a pod.  Pass the mesh explicitly (the engine
    does); omitting it hits the deprecated ambient-mesh fallback in
    ``hints.resolve_mesh``.  ``kernel_impl`` is a deprecated alias for
    ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.decode_attend")
        backend = kernel_impl
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.decode_attend")
        T = cache_k.shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and T % mesh.shape["model"] == 0):
            return sharded_flash_decode(mesh, q, cache_k, cache_v,
                                        cur_len, backend=backend)
    return local_decode_attend(q, cache_k, cache_v, cur_len,
                               backend=backend)
