"""Distributed FlashDecoding: sequence-sharded KV cache decode.

Decode attention at long context is the purest form of the paper's
streaming workload: the KV cache is read once per generated token with
zero reuse, so the byte path — not FLOPs — sets the latency.  Moving
the cache across the interconnect would put those bytes on the *global*
tier; instead each model shard keeps a contiguous slab of the context
resident in its own HBM, computes an **unnormalized** online-softmax
partial against its slab, and only the (B, H)-sized running statistics
cross the wire:

    m* = pmax_i m_i
    o  = sum_i o~_i * exp(m_i - m*)  /  sum_i l_i * exp(m_i - m*)

(`models.attention.flash_decode_partial` documents the same contract
from the single-shard side.)  Collective bytes per token are
O(B * H * (Dh + 2)) — independent of context length.

Per shard the partial comes from the kernel-dispatch registry
(``repro.kernels.dispatch``): backend 'xla' is the einsum reference,
'pallas' the VWR flash-decode kernel staging the local slab in wide
(bkv x Dh) VMEM blocks, 'auto' the measured winner.  GQA and encoder
cross-attention decode through ``decode_partial`` /
``decode_partial_paged``; absorbed MLA decodes through the
split-operand ``decode_partial_mla`` / ``decode_partial_mla_paged``
ops (latent + rope caches as separate operands — no k_cat/v_cat
copies, no rope zero-pad in the value stream), all sharing the one
pmax/psum statistics combine.

The mesh is an **explicit argument** everywhere here; ``decode_attend``
falls back to the ambient ``with mesh:`` context only through the
deprecated ``hints.resolve_mesh`` shim.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.common.hints import resolve_mesh
from repro.kernels import dispatch as D
from repro.models.attention import decode_attend_local  # noqa: F401  (re-export)


def _normalize(o_t, l, dtype):
    return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def _combine_partials(o_t, m, l, axis_name, dtype):
    """pmax/psum statistics combine shared by every sharded decode
    route (must run inside shard_map over ``axis_name``)."""
    m_star = jax.lax.pmax(m, axis_name)
    s = jnp.exp(m - m_star)                               # (B, H)
    o = jax.lax.psum(o_t * s[..., None], axis_name)
    l = jax.lax.psum(l * s, axis_name)
    return _normalize(o, l, dtype)


def local_decode_attend(q, cache_k, cache_v, cur_len, *,
                        k_scale=None, v_scale=None,
                        backend="xla") -> jax.Array:
    """Single-shard decode attention (normalized) through the dispatch
    registry.

    Passing ``k_scale``/``v_scale`` ((B, KV) fp32) selects the q8 op:
    ``cache_k``/``cache_v`` are int8 and dequantize inside the kernel.
    """
    if k_scale is not None:
        o_t, m, l = D.dispatch("decode_partial_q8", backend, q, cache_k,
                               cache_v, k_scale, v_scale, cur_len)
    else:
        o_t, m, l = D.dispatch("decode_partial", backend, q, cache_k,
                               cache_v, cur_len)
    return _normalize(o_t, l, q.dtype)


def sharded_flash_decode(mesh, q, cache_k, cache_v, cur_len, *,
                         k_scale=None, v_scale=None,
                         backend: str = "xla",
                         data_axis: str = "data",
                         model_axis: str = "model",
                         kernel_impl: Optional[str] = None):
    """Decode attention with the cache sequence-sharded over
    ``model_axis`` and the batch over ``data_axis``.

    q: (B, H, Dh) one new token; cache_k/v: (B, T, KV, Dh);
    cur_len: scalar count of valid positions (global).  Returns the
    normalized (B, H, Dh) context, bitwise-equivalent (up to fp
    reassociation) to the single-shard path on the unsharded cache.
    With ``k_scale``/``v_scale`` ((B, KV) fp32, replicated over the
    model axis — one scale covers the whole sequence) the caches are
    int8 and decode through the q8 op.  ``kernel_impl`` is a
    deprecated alias for ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.sharded_flash_decode")
        backend = kernel_impl
    q8 = k_scale is not None
    op = "decode_partial_q8" if q8 else "decode_partial"
    # 'auto' resolves HERE, outside shard_map, by cache lookup only
    # (replaying a winner the local decode path measured for these
    # shapes, if any): the measuring dispatch tuner — like the block
    # tuner, hence tune=False below — must not run timed kernels
    # inside shard_map tracing
    sig = ((q, cache_k, cache_v, k_scale, v_scale, cur_len) if q8
           else (q, cache_k, cache_v, cur_len))
    backend = D.cached_backend(op, backend, sig)
    B, H, Dh = q.shape
    T = cache_k.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        # no model axis / ragged split: single-shard reference
        return local_decode_attend(q, cache_k, cache_v, cur_len,
                                   k_scale=k_scale, v_scale=v_scale,
                                   backend=backend)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, k, v, *rest):
        cur = rest[-1]
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = D.dispatch(op, backend, q, k, v, *rest[:-1], cur,
                               pos0, tune=False)
        return _combine_partials(o_t, m, l, model_axis, q.dtype)

    scale_specs = (PS(dp, None), PS(dp, None)) if q8 else ()
    scale_args = (k_scale, v_scale) if q8 else ()
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, model_axis, None, None),
                  PS(dp, model_axis, None, None))
                 + scale_specs + (PS(),),
        out_specs=PS(dp, None, None),
        # the psum/pmax combine replicates the output over the model
        # axis by construction, but check_rep has no rule for
        # pallas_call — disable the static check rather than the path
        check_rep=False)
    return fn(q, cache_k, cache_v, *scale_args,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def sharded_mla_flash_decode(mesh, q_abs, q_rope, cache_ckv,
                             cache_krope, cur_len, *, scale: float,
                             ckv_scale=None, krope_scale=None,
                             backend: str = "xla",
                             data_axis: str = "data",
                             model_axis: str = "model"):
    """Split-operand absorbed-MLA decode with BOTH latent caches
    sequence-sharded over ``model_axis`` and the batch over
    ``data_axis``.

    q_abs: (B, H, r) fp32 (pre-folded through wk_b); q_rope: (B, H,
    rope); cache_ckv: (B, T, r); cache_krope: (B, T, rope); cur_len:
    scalar global valid count.  Each shard computes the unnormalized
    partial against its slab through the ``decode_partial_mla``
    registry op — latent and rope operands stay separate all the way
    into the kernel, so no shard ever materializes k_cat/v_cat copies
    — and the same pmax/psum statistics combine as
    ``sharded_flash_decode`` stitches the softmax.  With
    ``ckv_scale``/``krope_scale`` ((B,) fp32, replicated over the
    model axis) the caches are int8 q8.  Returns the normalized
    (B, H, r) latent context."""
    q8 = ckv_scale is not None
    op = "decode_partial_mla_q8" if q8 else "decode_partial_mla"
    sig = ((q_abs, q_rope, cache_ckv, cache_krope, ckv_scale,
            krope_scale, cur_len) if q8
           else (q_abs, q_rope, cache_ckv, cache_krope, cur_len))
    backend = D.cached_backend(op, backend, sig, {"scale": scale})
    B, H, r = q_abs.shape
    T = cache_ckv.shape[1]
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or T % msize:
        return local_mla_decode_attend(q_abs, q_rope, cache_ckv,
                                       cache_krope, cur_len,
                                       scale=scale,
                                       ckv_scale=ckv_scale,
                                       krope_scale=krope_scale,
                                       backend=backend)
    n_local = T // msize
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(qa, qr, ckv, kr, *rest):
        cur = rest[-1]
        pos0 = jax.lax.axis_index(model_axis) * n_local
        o_t, m, l = D.dispatch(op, backend, qa, qr, ckv, kr,
                               *rest[:-1], cur, pos0, scale=scale,
                               tune=False)
        return _combine_partials(o_t, m, l, model_axis, qa.dtype)

    scale_specs = (PS(dp), PS(dp)) if q8 else ()
    scale_args = (ckv_scale, krope_scale) if q8 else ()
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, None, None),
                  PS(dp, model_axis, None),
                  PS(dp, model_axis, None))
                 + scale_specs + (PS(),),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q_abs, q_rope, cache_ckv, cache_krope, *scale_args,
              jnp.asarray(cur_len, jnp.int32).reshape(()))


def local_mla_decode_attend(q_abs, q_rope, cache_ckv, cache_krope,
                            cur_len, *, scale: float,
                            ckv_scale=None, krope_scale=None,
                            backend="xla") -> jax.Array:
    """Single-shard split-operand MLA decode attention (normalized
    (B, H, r) latent context) through the dispatch registry.

    ``ckv_scale``/``krope_scale`` ((B,) fp32) select the q8 op over
    int8 latent caches."""
    if ckv_scale is not None:
        o_t, m, l = D.dispatch("decode_partial_mla_q8", backend, q_abs,
                               q_rope, cache_ckv, cache_krope,
                               ckv_scale, krope_scale, cur_len,
                               scale=scale)
    else:
        o_t, m, l = D.dispatch("decode_partial_mla", backend, q_abs,
                               q_rope, cache_ckv, cache_krope, cur_len,
                               scale=scale)
    return _normalize(o_t, l, q_abs.dtype)


def mla_decode_attend(q_abs, q_rope, cache_ckv, cache_krope, cur_len, *,
                      scale: float, ckv_scale=None, krope_scale=None,
                      backend: str = "xla", mesh=None,
                      seq_shard: bool = True) -> jax.Array:
    """Mesh-aware split-operand MLA decode attention used by
    ``models.lm``.

    The MLA sibling of ``decode_attend``: routes to
    ``sharded_mla_flash_decode`` when ``seq_shard`` and a mesh with a
    'model' axis divides the cache evenly, else the local registry op.
    The latent and rope caches ride as separate operands end to end —
    the copy-free replacement for the concatenated
    ``mla_absorbed_mqa`` + ``decode_attend`` route.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.mla_decode_attend")
        T = cache_ckv.shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and T % mesh.shape["model"] == 0):
            return sharded_mla_flash_decode(mesh, q_abs, q_rope,
                                            cache_ckv, cache_krope,
                                            cur_len, scale=scale,
                                            ckv_scale=ckv_scale,
                                            krope_scale=krope_scale,
                                            backend=backend)
    return local_mla_decode_attend(q_abs, q_rope, cache_ckv,
                                   cache_krope, cur_len, scale=scale,
                                   ckv_scale=ckv_scale,
                                   krope_scale=krope_scale,
                                   backend=backend)


def _page_counts(lens, J, page_size):
    """(B,) valid-position counts -> (B, J) per-logical-page counts."""
    return jnp.clip(lens[:, None]
                    - jnp.arange(J, dtype=jnp.int32)[None, :] * page_size,
                    0, page_size).astype(jnp.int32)


def local_paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                              k_scale=None, v_scale=None,
                              backend="xla") -> jax.Array:
    """Single-shard paged decode attention (normalized).

    q: (B, H, Dh); k_pool/v_pool: (n_pages, page_size, KV, Dh);
    table: (B, max_pages) int32; lens: (B,) int32 valid positions per
    slot (0 = inactive slot -> zero output).  ``k_scale``/``v_scale``
    ((n_pages, KV) fp32 per-page per-head sidecars) select the q8 op
    over int8 pools."""
    ps = k_pool.shape[1]
    J = table.shape[1]
    counts = _page_counts(lens, J, ps)
    # page_size/max_pages ride as static kwargs so the page geometry
    # is an EXPLICIT part of the dispatch cache key (see the note at
    # the registered impls in models/attention.py)
    if k_scale is not None:
        o_t, m, l = D.dispatch("decode_partial_paged_q8", backend, q,
                               k_pool, v_pool, k_scale, v_scale, table,
                               counts, page_size=ps, max_pages=J)
    else:
        o_t, m, l = D.dispatch("decode_partial_paged", backend, q,
                               k_pool, v_pool, table, counts,
                               page_size=ps, max_pages=J)
    return _normalize(o_t, l, q.dtype)


def sharded_paged_flash_decode(mesh, q, k_pool, v_pool, table, lens, *,
                               k_scale=None, v_scale=None,
                               backend: str = "xla",
                               data_axis: str = "data",
                               model_axis: str = "model"):
    """Paged decode attention with the page POOL sharded over
    ``model_axis`` (shard s owns the contiguous slab of pages
    [s*pp, (s+1)*pp)) and the slot batch over ``data_axis``.

    Block tables are replicated and may point at any shard's pages:
    each shard zeroes the counts of pages outside its slab, computes
    the unnormalized partial over the pages it owns, and the same
    pmax/psum statistics combine as ``sharded_flash_decode`` stitches
    the slots back together — so page->shard placement is free (the
    allocator never needs to know the mesh).  Per-token collective
    bytes stay O(B * H * (Dh + 2)), independent of pool size.

    ``k_scale``/``v_scale`` ((n_pages, KV) fp32) select the q8 op over
    int8 pools; the sidecars shard on their leading page dim exactly
    like the pools, so each shard dequantizes its own pages locally.
    """
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    J = table.shape[1]
    q8 = k_scale is not None
    op = "decode_partial_paged_q8" if q8 else "decode_partial_paged"
    # cache lookup under the same signature the LOCAL measuring path
    # writes — (B, J) counts, not (B,) lens — plus the page geometry
    # statics, so a winner measured locally replays here and a winner
    # from another (page_size, max_pages) does not
    counts_sig = _page_counts(lens, J, ps)
    sig = ((q, k_pool, v_pool, k_scale, v_scale, table, counts_sig)
           if q8 else (q, k_pool, v_pool, table, counts_sig))
    backend = D.cached_backend(op, backend,
                               sig, {"page_size": ps, "max_pages": J})
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or n_pages % msize:
        return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                         k_scale=k_scale,
                                         v_scale=v_scale,
                                         backend=backend)
    pp = n_pages // msize
    B = q.shape[0]
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(q, kp, vp, *rest):
        tbl, lens = rest[-2], rest[-1]
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        counts = jnp.where(owned, _page_counts(lens, J, ps), 0)
        o_t, m, l = D.dispatch(op, backend, q, kp, vp, *rest[:-2],
                               tloc, counts, page_size=ps,
                               max_pages=J, tune=False)
        return _combine_partials(o_t, m, l, model_axis, q.dtype)

    scale_specs = ((PS(model_axis, None), PS(model_axis, None))
                   if q8 else ())
    scale_args = (k_scale, v_scale) if q8 else ()
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(model_axis, None, None, None),
                  PS(model_axis, None, None, None))
                 + scale_specs + (PS(dp, None), PS(dp)),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q, k_pool, v_pool, *scale_args,
              table.astype(jnp.int32), jnp.asarray(lens, jnp.int32))


def paged_decode_attend(q, k_pool, v_pool, table, lens, *,
                        k_scale=None, v_scale=None,
                        backend: str = "xla", mesh=None,
                        seq_shard: bool = True) -> jax.Array:
    """Mesh-aware paged decode attention used by ``models.lm``.

    The paged sibling of ``decode_attend``: routes to
    ``sharded_paged_flash_decode`` when ``seq_shard`` and a mesh with a
    'model' axis divides the pool evenly, else the local registry op.
    ``k_scale``/``v_scale`` select the q8 (int8 pools) route.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.paged_decode_attend")
        n_pages = k_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_paged_flash_decode(mesh, q, k_pool, v_pool,
                                              table, lens,
                                              k_scale=k_scale,
                                              v_scale=v_scale,
                                              backend=backend)
    return local_paged_decode_attend(q, k_pool, v_pool, table, lens,
                                     k_scale=k_scale, v_scale=v_scale,
                                     backend=backend)


def local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool, krope_pool,
                                  table, lens, *, scale: float,
                                  ckv_scale=None, krope_scale=None,
                                  backend="xla") -> jax.Array:
    """Single-shard split-operand paged MLA decode attention
    (normalized (B, H, r) latent context).

    q_abs: (B, H, r) fp32; q_rope: (B, H, rope); ckv_pool: (n_pages,
    page_size, r); krope_pool: (n_pages, page_size, rope); table:
    (B, max_pages) int32; lens: (B,) int32 valid positions per slot.
    ``ckv_scale``/``krope_scale`` ((n_pages,) fp32 per-page sidecars)
    select the q8 op over int8 pools."""
    ps = ckv_pool.shape[1]
    J = table.shape[1]
    counts = _page_counts(lens, J, ps)
    if ckv_scale is not None:
        o_t, m, l = D.dispatch("decode_partial_mla_paged_q8", backend,
                               q_abs, q_rope, ckv_pool, krope_pool,
                               ckv_scale, krope_scale, table, counts,
                               scale=scale, page_size=ps, max_pages=J)
    else:
        o_t, m, l = D.dispatch("decode_partial_mla_paged", backend,
                               q_abs, q_rope, ckv_pool, krope_pool,
                               table, counts, scale=scale,
                               page_size=ps, max_pages=J)
    return _normalize(o_t, l, q_abs.dtype)


def sharded_mla_paged_flash_decode(mesh, q_abs, q_rope, ckv_pool,
                                   krope_pool, table, lens, *,
                                   scale: float, ckv_scale=None,
                                   krope_scale=None,
                                   backend: str = "xla",
                                   data_axis: str = "data",
                                   model_axis: str = "model"):
    """Split-operand paged MLA decode with BOTH latent pools sharded
    over ``model_axis`` (shard s owns pages [s*pp, (s+1)*pp)) and the
    slot batch over ``data_axis``.

    Same ownership-masked-counts construction as
    ``sharded_paged_flash_decode`` — block tables are replicated, each
    shard zeroes the counts of foreign pages and the pmax/psum
    statistics combine stitches the slots — so page->shard placement
    stays free, and no shard ever builds a pool-wide k_cat/v_cat copy.

    ``ckv_scale``/``krope_scale`` ((n_pages,) fp32) select the q8 op
    over int8 pools; the sidecars shard on the page dim exactly like
    the pools.
    """
    n_pages, ps = ckv_pool.shape[0], ckv_pool.shape[1]
    J = table.shape[1]
    q8 = ckv_scale is not None
    op = ("decode_partial_mla_paged_q8" if q8
          else "decode_partial_mla_paged")
    counts_sig = _page_counts(lens, J, ps)
    sig = ((q_abs, q_rope, ckv_pool, krope_pool, ckv_scale,
            krope_scale, table, counts_sig) if q8
           else (q_abs, q_rope, ckv_pool, krope_pool, table,
                 counts_sig))
    backend = D.cached_backend(
        op, backend, sig,
        {"scale": scale, "page_size": ps, "max_pages": J})
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if model_axis not in mesh.axis_names or n_pages % msize:
        return local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool,
                                             krope_pool, table, lens,
                                             scale=scale,
                                             ckv_scale=ckv_scale,
                                             krope_scale=krope_scale,
                                             backend=backend)
    pp = n_pages // msize
    B = q_abs.shape[0]
    dsize = mesh.shape.get(data_axis, 1)
    dp = (data_axis if data_axis in mesh.axis_names
          and B % max(dsize, 1) == 0 else None)

    def shard_fn(qa, qr, ckv, kr, *rest):
        tbl, lens = rest[-2], rest[-1]
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        counts = jnp.where(owned, _page_counts(lens, J, ps), 0)
        o_t, m, l = D.dispatch(op, backend, qa, qr, ckv, kr,
                               *rest[:-2], tloc, counts, scale=scale,
                               page_size=ps, max_pages=J, tune=False)
        return _combine_partials(o_t, m, l, model_axis, qa.dtype)

    scale_specs = (PS(model_axis), PS(model_axis)) if q8 else ()
    scale_args = (ckv_scale, krope_scale) if q8 else ()
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(dp, None, None),
                  PS(dp, None, None),
                  PS(model_axis, None, None),
                  PS(model_axis, None, None))
                 + scale_specs + (PS(dp, None), PS(dp)),
        out_specs=PS(dp, None, None),
        check_rep=False)
    return fn(q_abs, q_rope, ckv_pool, krope_pool, *scale_args,
              table.astype(jnp.int32), jnp.asarray(lens, jnp.int32))


def mla_paged_decode_attend(q_abs, q_rope, ckv_pool, krope_pool, table,
                            lens, *, scale: float, ckv_scale=None,
                            krope_scale=None, backend: str = "xla",
                            mesh=None, seq_shard: bool = True
                            ) -> jax.Array:
    """Mesh-aware split-operand paged MLA decode attention used by
    ``models.lm``.

    Routes to ``sharded_mla_paged_flash_decode`` when ``seq_shard`` and
    a mesh with a 'model' axis divides the pool evenly, else the local
    registry op — the copy-free replacement for concatenating the two
    pools into a KV=1 view of ``paged_decode_attend``.
    ``ckv_scale``/``krope_scale`` select the q8 (int8 pools) route.
    """
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.mla_paged_decode_attend")
        n_pages = ckv_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_mla_paged_flash_decode(
                mesh, q_abs, q_rope, ckv_pool, krope_pool, table, lens,
                scale=scale, ckv_scale=ckv_scale,
                krope_scale=krope_scale, backend=backend)
    return local_mla_paged_decode_attend(q_abs, q_rope, ckv_pool,
                                         krope_pool, table, lens,
                                         scale=scale,
                                         ckv_scale=ckv_scale,
                                         krope_scale=krope_scale,
                                         backend=backend)


def decode_attend(q, cache_k, cache_v, cur_len, *,
                  k_scale=None, v_scale=None,
                  backend: str = "xla",
                  mesh=None, seq_shard: bool = True,
                  kernel_impl: Optional[str] = None) -> jax.Array:
    """Mesh-aware decode attention used by ``models.lm``.

    Routes to ``sharded_flash_decode`` when ``seq_shard`` and a mesh
    with a 'model' axis is available and the cache splits evenly; falls
    back to the local registry path otherwise, so the same model code
    serves one chip and a pod.  Pass the mesh explicitly (the engine
    does); omitting it hits the deprecated ambient-mesh fallback in
    ``hints.resolve_mesh``.  ``kernel_impl`` is a deprecated alias for
    ``backend``.
    """
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("dist.decode.decode_attend")
        backend = kernel_impl
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.decode_attend")
        T = cache_k.shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and T % mesh.shape["model"] == 0):
            return sharded_flash_decode(mesh, q, cache_k, cache_v,
                                        cur_len, k_scale=k_scale,
                                        v_scale=v_scale,
                                        backend=backend)
    return local_decode_attend(q, cache_k, cache_v, cur_len,
                               k_scale=k_scale, v_scale=v_scale,
                               backend=backend)


# ======================================================================
# chunked prefill: sequence-sharded chunk-prefix attention
# ======================================================================

def local_chunk_prefix_attend(q, k_pool, v_pool, table, counts, *,
                              k_scale=None, v_scale=None,
                              backend="xla"):
    """Single-shard chunk->prior-pages attention partial through the
    dispatch registry.

    q: (C, H, Dh) — one prompt chunk's queries; table: (J,) int32 —
    the chunk's PRIOR whole pages (earlier chunks + prefix-cache
    aliases); counts: (J,) int32 valid slots per page.  Returns the
    UNNORMALIZED fp32 partial (o_t (C, H, Dh), m (C, H), l (C, H)) —
    the caller merges it with the chunk's causal self-attention
    partial (``models.attention.merge_partials``) and normalizes,
    exactly like the local ``chunk_prefill_attend``.
    ``k_scale``/``v_scale`` ((n_pages, KV) fp32) select the q8 op over
    int8 pools."""
    ps, J = k_pool.shape[1], table.shape[0]
    if k_scale is not None:
        return D.dispatch("chunk_prefix_paged_q8", backend, q, k_pool,
                          v_pool, k_scale, v_scale, table, counts,
                          page_size=ps, max_pages=J)
    return D.dispatch("chunk_prefix_paged", backend, q, k_pool, v_pool,
                      table, counts, page_size=ps, max_pages=J)


def sharded_chunk_prefix_attend(mesh, q, k_pool, v_pool, table, counts,
                                *, k_scale=None, v_scale=None,
                                backend: str = "xla",
                                model_axis: str = "model"):
    """Chunk->prior-pages attention with the page pool sharded over
    ``model_axis`` — the chunked-prefill sibling of
    ``sharded_paged_flash_decode``.

    Shard s owns pages [s*pp, (s+1)*pp); the (J,) table is replicated
    and may point anywhere, so each shard zeroes the counts of foreign
    pages, computes its unnormalized partial over the pages it owns,
    and the pmax/psum statistics combine stitches the shards — run
    UNNORMALIZED here (m* = pmax m; o = psum o~*exp(m-m*); l = psum
    l*exp(m-m*)) so the caller can still merge the chunk's replicated
    causal self-attention partial before normalizing.  Collective
    bytes per chunk are O(C * H * (Dh + 2)), independent of prefix
    length — the same wire contract as sharded decode.  A chunk with
    no prior pages (J = 0, or every count zeroed) combines to the
    fully-masked partial (o = 0, m = NEG_INF, l = 0), which the merge
    treats as exact identity."""
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    J = table.shape[0]
    q8 = k_scale is not None
    op = "chunk_prefix_paged_q8" if q8 else "chunk_prefix_paged"
    sig = ((q, k_pool, v_pool, k_scale, v_scale, table, counts)
           if q8 else (q, k_pool, v_pool, table, counts))
    backend = D.cached_backend(op, backend, sig,
                               {"page_size": ps, "max_pages": J})
    msize = mesh.shape.get(model_axis, 1) if model_axis else 1
    if (model_axis not in mesh.axis_names or msize == 1
            or n_pages % msize or J == 0):
        return local_chunk_prefix_attend(q, k_pool, v_pool, table,
                                         counts, k_scale=k_scale,
                                         v_scale=v_scale,
                                         backend=backend)
    pp = n_pages // msize

    def shard_fn(q, kp, vp, *rest):
        tbl, cnt = rest[-2], rest[-1]
        p0 = jax.lax.axis_index(model_axis) * pp
        owned = (tbl >= p0) & (tbl < p0 + pp)
        tloc = jnp.clip(tbl - p0, 0, pp - 1)
        cnt = jnp.where(owned, cnt, 0)
        o_t, m, l = D.dispatch(op, backend, q, kp, vp, *rest[:-2],
                               tloc, cnt, page_size=ps, max_pages=J,
                               tune=False)
        # unnormalized cross-shard combine: keep (o~, m, l) so the
        # caller's self-partial merge stays exact
        m_star = jax.lax.pmax(m, model_axis)
        s = jnp.exp(m - m_star)
        o = jax.lax.psum(o_t * s[..., None], model_axis)
        l = jax.lax.psum(l * s, model_axis)
        return o, m_star, l

    scale_specs = ((PS(model_axis, None), PS(model_axis, None))
                   if q8 else ())
    scale_args = (k_scale, v_scale) if q8 else ()
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(PS(None, None, None),
                  PS(model_axis, None, None, None),
                  PS(model_axis, None, None, None))
                 + scale_specs + (PS(None), PS(None)),
        out_specs=(PS(None, None, None), PS(None, None),
                   PS(None, None)),
        check_rep=False)
    return fn(q, k_pool, v_pool, *scale_args,
              table.astype(jnp.int32), counts.astype(jnp.int32))


def chunk_prefix_attend(q, k_pool, v_pool, table, counts, *,
                        k_scale=None, v_scale=None,
                        backend: str = "xla", mesh=None,
                        seq_shard: bool = True):
    """Mesh-aware chunk-prefix attention partial.

    Routes to ``sharded_chunk_prefix_attend`` when ``seq_shard`` and a
    mesh with a 'model' axis divides the pool evenly, else the local
    registry op.  Either way returns the unnormalized (o_t, m, l)
    partial for the caller's self-attention merge."""
    if seq_shard:
        mesh = resolve_mesh(mesh, "dist.decode.chunk_prefix_attend")
        n_pages = k_pool.shape[0]
        if (mesh is not None and "model" in mesh.axis_names
                and n_pages % mesh.shape["model"] == 0):
            return sharded_chunk_prefix_attend(mesh, q, k_pool, v_pool,
                                               table, counts,
                                               k_scale=k_scale,
                                               v_scale=v_scale,
                                               backend=backend)
    return local_chunk_prefix_attend(q, k_pool, v_pool, table, counts,
                                     k_scale=k_scale, v_scale=v_scale,
                                     backend=backend)
