"""Pipeline parallelism: microbatch schedule over a 'pipe' mesh axis.

Stage weights stay resident on their owning device for the whole pass —
only the (mb, D) activation edge crosses the interconnect, via
``ppermute`` ring handoffs (the multi-device version of the paper's
"move the data once, consume it N times" discipline: a stage's weights
are the wide resident operand, the microbatch stream the narrow one).

GPipe schedule: microbatch t enters stage 0 at tick t and exits stage
S-1 at tick t + S - 1; the pipeline drains after n_micro + S - 1 ticks
with S - 1 bubble ticks — the standard fill/drain cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS


def pipeline_apply(mesh, stage_fn, stage_params, x, *, n_micro: int,
                   axis_name: str = "pipe"):
    """Apply ``stage_fn`` S times in sequence, one stage per device.

    stage_fn: (params_s, (mb, ...)) -> (mb, ...) — one pipeline stage;
    stage_params: pytree whose leaves are stacked (S, ...) per-stage
    weights, sequence-sharded over ``axis_name``;
    x: (n_micro * mb, ...) global input batch.

    Returns stage_fn(w[S-1], ... stage_fn(w[0], x)) — numerically the
    sequential composition, computed with the GPipe microbatch schedule.
    """
    S = mesh.shape[axis_name]
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    assert n_stages == S, (n_stages, S)
    n_tokens = x.shape[0]
    assert n_tokens % n_micro == 0, (n_tokens, n_micro)
    mb = n_tokens // n_micro
    perm = [(i, (i + 1) % S) for i in range(S)]

    def run(wp, xg):
        s = jax.lax.axis_index(axis_name)
        w = jax.tree.map(lambda a: a[0], wp)        # this device's stage
        xm = xg.reshape(n_micro, mb, *xg.shape[1:])
        recv = jnp.zeros_like(xm[0])
        outs = []
        for t in range(n_micro + S - 1):
            fed = xm[t] if t < n_micro else jnp.zeros_like(recv)
            inp = jnp.where(s == 0, fed, recv)
            y = stage_fn(w, inp)
            if t >= S - 1:
                # last stage emits microbatch t - (S - 1) this tick
                outs.append(jnp.where(s == S - 1, y, jnp.zeros_like(y)))
            recv = jax.lax.ppermute(y, axis_name, perm)
        out = jax.lax.psum(jnp.stack(outs), axis_name)
        return out.reshape(n_tokens, *xg.shape[1:])

    fn = shard_map(run, mesh=mesh,
                   in_specs=(PS(axis_name), PS()), out_specs=PS())
    return fn(stage_params, x)
