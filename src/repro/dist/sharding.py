"""The one sharding surface: logical axes -> mesh PartitionSpecs.

Every layer that needs a sharding — ``launch.train`` (init + step),
``launch.dryrun`` (in_shardings for every (arch x shape x mesh) cell),
``launch.serve`` (sharded decode), and the optimizer's ZeRO-1 pass —
goes through this module, so the logical-axis vocabulary declared by
``ParamDef`` specs (see models/layers.py docstring) resolves to mesh
axes in exactly one place.

Strategies:
  'fsdp_tp'  TP over 'model' (vocab / heads / kv / ffn / expert-ffn /
             ssm-inner dims) + the largest remaining param dim
             ('embed') sharded over the data axes (FSDP).  Default.
  'ddp'      params replicated; optimizer state ZeRO-1-shards them
             (``optim.adamw.zero1_pspecs``).  Right for sub-1B archs.
  'serve'    TP only — decode batches are small, so params stay
             gather-free on the data axes and the batch dim carries
             'data'.

A dim is only assigned a mesh axis when its size divides the axis
(product) size; each mesh axis appears at most once per spec.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.common.module import ParamDef

# mesh axes that carry the batch / FSDP dim, in nesting order
DATA_AXES = ("pod", "data")

# logical axes that tensor-parallelize over 'model'
_TP_AXES = ("vocab", "heads", "kv", "ffn", "expert_ff", "inner",
            "inner_all", "q_lora", "kv_lora")


def data_axes(mesh) -> tuple:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def data_size(mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh)) or 1


def model_axis(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def _dp_entry(mesh):
    """The PartitionSpec entry for the data dims: a single axis name or
    a tuple when the mesh also has a 'pod' axis."""
    dp = data_axes(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def rules(cfg, mesh, strategy: Optional[str] = None) -> Dict[str, Any]:
    """logical axis name -> mesh axis (str | tuple | None)."""
    strategy = strategy or cfg.sharding_strategy
    if strategy == "ddp":
        return {}
    mp = model_axis(mesh)
    table: Dict[str, Any] = {ax: mp for ax in _TP_AXES}
    dp = _dp_entry(mesh)
    # experts spread over EVERY axis, or not at all: full EP gives each
    # device whole experts (weights never move — the layout both train
    # and serve want), and a strict-subset expert sharding buys no
    # memory over full EP while adding resharding noise that top-k
    # routing amplifies discontinuously (a ~1e-6 reassociation flips
    # an expert choice into an O(1) logit change — measured on the
    # (2,4) mesh).  The divisibility check in _resolve falls back to
    # replicated when E doesn't cover the full product.
    flat_dp = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    full = flat_dp + ((mp,) if mp else ())
    table["experts"] = (full if len(full) > 1
                        else (full[0] if full else None))
    if strategy == "serve":
        return table
    if strategy != "fsdp_tp":
        raise ValueError(f"unknown sharding strategy {strategy!r}")
    table["embed"] = dp
    return table


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _resolve(d: ParamDef, table: Dict[str, Any], sizes: Dict[str, int]) -> PS:
    used = set()
    out = []
    for ax, size in zip(d.axes, d.shape):
        mesh_ax = table.get(ax)
        flat = (mesh_ax if isinstance(mesh_ax, tuple)
                else ((mesh_ax,) if mesh_ax is not None else ()))
        n = math.prod(sizes[a] for a in flat) if flat else 1
        if not flat or any(a in used for a in flat) or size % n:
            out.append(None)
            continue
        used.update(flat)
        out.append(mesh_ax)
    return PS(*out)


def param_pspecs(cfg, mesh, strategy: Optional[str] = None):
    """PartitionSpec tree matching ``lm.abstract_init(cfg)``."""
    from repro.models import lm  # local import: dist must not cycle

    table = rules(cfg, mesh, strategy)
    sizes = _axis_sizes(mesh)
    return jax.tree.map(lambda d: _resolve(d, table, sizes),
                        lm.model_spec(cfg),
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ----------------------------------------------------------------------
# batch pspecs
# ----------------------------------------------------------------------

def _batched(mesh, aval_or_ndim, batch: Optional[int] = None) -> PS:
    """dim 0 over the data axes (when divisible), rest replicated."""
    if hasattr(aval_or_ndim, "shape"):
        ndim = len(aval_or_ndim.shape)
        batch = aval_or_ndim.shape[0] if aval_or_ndim.shape else None
    else:
        ndim = aval_or_ndim
    dp = _dp_entry(mesh)
    if ndim == 0 or dp is None or batch is None \
            or batch % data_size(mesh):
        return PS(*([None] * ndim))
    return PS(dp, *([None] * (ndim - 1)))


def train_batch_pspecs(cfg, mesh, batch_specs):
    """PartitionSpec tree for a train/prefill batch dict (abstract
    values from ``launch.steps.batch_specs``): the global batch dim
    shards over the data axes, everything else is replicated."""
    return jax.tree.map(lambda a: _batched(mesh, a), batch_specs)


def cache_pspecs(cfg, mesh, batch: int, *, seq_shard: bool = False):
    """PartitionSpec tree matching ``lm.cache_spec(cfg, batch, T)``,
    branch for branch.

    Default (GSPMD decode): batch over the data axes, the kv-head dim
    of attention caches over 'model' when divisible.  With
    ``seq_shard=True`` the cache *sequence* dim takes 'model' instead —
    the layout ``dist.decode.sharded_flash_decode`` consumes (each
    model shard owns a contiguous slab of the context and never sees
    the rest).  Recurrent states (hybrid/ssm) shard their head dim over
    'model': per-head state never crosses shards during decode.
    """
    from repro.models import lm, ssm as SSM, xlstm as XL  # local import

    mp = model_axis(mesh)
    dp = _dp_entry(mesh)
    bax = (dp if dp is not None and batch % data_size(mesh) == 0
           else None)
    sizes = _axis_sizes(mesh)
    seqax = mp if (seq_shard and mp is not None) else None
    kvax = (mp if (not seq_shard and mp is not None
                   and cfg.n_kv_heads % sizes[mp] == 0) else None)

    def heads_ax(n_heads):
        if mp is None or n_heads % sizes[mp]:
            return None
        return mp

    def kv_cache(lead: int) -> PS:
        # (*lead, B, T, KV, Dh)
        return PS(*([None] * lead), bax, seqax, kvax, None)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.mla is not None:
            latent = PS(None, bax, seqax, None)
            return {"ckv": latent, "krope": latent}
        return {"k": kv_cache(1), "v": kv_cache(1)}

    if fam == "moe":
        if cfg.mla is not None:
            latent = PS(None, bax, seqax, None)

            def mla_c():
                return {"ckv": latent, "krope": latent}
            return {"dense": mla_c() if cfg.moe.first_k_dense else None,
                    "moe": mla_c()}

        def gqa_c():
            return {"k": kv_cache(1), "v": kv_cache(1)}
        return {"dense": gqa_c() if cfg.moe.first_k_dense else None,
                "moe": gqa_c()}

    if fam == "hybrid":
        mc = cfg.mamba2
        _, _, tail, _ = lm._hybrid_groups(cfg)
        hax = heads_ax((mc.expand * cfg.d_model) // mc.head_dim)

        def mstate(lead: int):
            # ssm: (*lead, B, H, d_state, head_dim); conv: (*lead, B,
            # d_conv-1, d_xbc)
            return SSM.Mamba2State(
                ssm=PS(*([None] * lead), bax, hax, None, None),
                conv=PS(*([None] * lead), bax, None, None))
        return {
            "mamba_main": mstate(2),
            "mamba_tail": mstate(1) if tail else None,
            "attn_k": kv_cache(1), "attn_v": kv_cache(1),
        }

    if fam == "ssm":
        hax = heads_ax(cfg.n_heads)
        return {
            "mlstm": XL.MLSTMState(
                C=PS(None, None, bax, hax, None, None),
                n=PS(None, None, bax, hax, None),
                m=PS(None, None, bax, hax),
                conv=PS(None, None, bax, None, None)),
            "slstm": XL.SLSTMState(
                c=PS(None, bax, None), n=PS(None, bax, None),
                h=PS(None, bax, None), m=PS(None, bax, None)),
        }

    if fam == "audio":
        return {"self_k": kv_cache(1), "self_v": kv_cache(1),
                "cross_k": kv_cache(1), "cross_v": kv_cache(1)}

    raise ValueError(fam)


def paged_cache_pspecs(cfg, mesh, batch_slots: int, *,
                       seq_shard: bool = False,
                       n_pages: Optional[int] = None,
                       quantized: bool = False):
    """PartitionSpec tree matching ``engine.paged_cache.paged_cache_spec``.

    Pool leaves are ``(L, n_pages, page_size, ...)``: with
    ``seq_shard=True`` the *page* dim takes 'model' (each shard owns a
    contiguous slab of the pool — ``dist.decode.
    sharded_paged_flash_decode`` masks foreign pages by count and
    combines the statistics), else the kv-head dim takes 'model' when
    divisible, mirroring the dense layout.  The audio cross cache stays
    slot-dense (batch over data, replicated over 'model': it is
    attended locally per shard in paged mode).  With ``quantized=True``
    the tree grows the int8 pools' fp32 scale-sidecar leaves, sharded
    on the same page (and, for GQA, kv-head) dims as their pools.
    """
    from repro.engine import paged_cache as PC  # local import: no cycle

    PC.check_family(cfg)
    mp = model_axis(mesh)
    dp = _dp_entry(mesh)
    sizes = _axis_sizes(mesh)
    bax = (dp if dp is not None and batch_slots % data_size(mesh) == 0
           else None)
    pageax = (mp if (seq_shard and mp is not None
                     and (n_pages is None or n_pages % sizes[mp] == 0))
              else None)
    kvax = (mp if (pageax is None and mp is not None
                   and cfg.n_kv_heads % sizes[mp] == 0) else None)

    def gqa_pool():
        sh = PS(None, pageax, None, kvax, None)
        pool = {"k": sh, "v": sh}
        if quantized:
            ssh = PS(None, pageax, kvax)       # (L, n_pages, KV)
            pool["k_scale"] = ssh
            pool["v_scale"] = ssh
        return pool

    def mla_pool():
        latent = PS(None, pageax, None, None)
        pool = {"ckv": latent, "krope": latent}
        if quantized:
            ssh = PS(None, pageax)             # (L, n_pages)
            pool["ckv_scale"] = ssh
            pool["krope_scale"] = ssh
        return pool

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return mla_pool() if cfg.mla is not None else gqa_pool()
    if fam == "moe":
        mk = mla_pool if cfg.mla is not None else gqa_pool
        return {"dense": mk() if cfg.moe.first_k_dense else None,
                "moe": mk()}
    # audio
    pool = gqa_pool()
    cross = PS(None, bax, None, None, None)
    return {"self_k": pool["k"], "self_v": pool["v"],
            "cross_k": cross, "cross_v": cross}


def paged_decode_batch_pspecs(cfg, mesh, global_batch: int, *,
                              seq_shard: bool = False,
                              n_pages: Optional[int] = None,
                              quantized: bool = False):
    """PartitionSpec tree for a paged decode batch
    ({token, cur_len (B,), block_table, cache} [+ enc_lens for
    audio])."""
    out = {
        "token": _batched(mesh, 1, global_batch),
        "cur_len": _batched(mesh, 1, global_batch),
        "block_table": _batched(mesh, 2, global_batch),
        "cache": paged_cache_pspecs(cfg, mesh, global_batch,
                                    seq_shard=seq_shard,
                                    n_pages=n_pages,
                                    quantized=quantized),
    }
    if cfg.family == "audio":
        out["enc_lens"] = _batched(mesh, 1, global_batch)
    return out


def decode_batch_pspecs(cfg, mesh, global_batch: int, *,
                        seq_shard: bool = False):
    """PartitionSpec tree for a decode batch
    ({token, cur_len, cache}, the ``launch.steps.batch_specs`` decode
    layout)."""
    return {
        "token": _batched(mesh, 1, global_batch),
        "cur_len": PS(),
        "cache": cache_pspecs(cfg, mesh, global_batch,
                              seq_shard=seq_shard),
    }


def to_shardings(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree (None leaves pass
    through untouched)."""
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps) if isinstance(ps, PS) else ps,
        tree, is_leaf=lambda x: isinstance(x, PS))
