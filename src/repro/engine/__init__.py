"""Serving engine: explicit-mesh prefill/decode on the dispatch registry.

``DecodeEngine`` owns the mesh, the TP-sharded params, the decode-cache
PartitionSpecs and the jitted step functions; ``pad_cache_from_prefill``
is the prefill->decode cache handoff it (and ``launch.serve``) uses.
"""
from repro.engine.cache import pad_cache_from_prefill
from repro.engine.engine import DecodeEngine, EngineConfig

__all__ = ["DecodeEngine", "EngineConfig", "pad_cache_from_prefill"]
