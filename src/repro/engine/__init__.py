"""Serving engine: explicit-mesh prefill/decode on the dispatch registry.

``DecodeEngine`` owns the mesh, the TP-sharded params, the decode-cache
PartitionSpecs and the jitted step functions; ``pad_cache_from_prefill``
is the prefill->decode cache handoff it (and ``launch.serve``) uses.
With ``EngineConfig(paged=True)`` the cache is a paged page pool +
block tables (``engine.paged_cache``) and ``Scheduler`` / ``Request``
run request-level continuous batching on top of it — every request
walks the ``RequestStatus`` lifecycle and terminates as a
``RequestResult`` (tokens + status/error), with deterministic fault
injectors in ``engine.faults``.  Durability rides on top: the
scheduler's full serving state snapshots crash-consistently
(``engine.snapshot``) and every request event is write-ahead journaled
(``engine.journal``), so ``runtime.resilience.serve_with_recovery``
survives process death with bit-identical streams.
"""
from repro.engine.cache import pad_cache_from_prefill
from repro.engine.engine import DecodeEngine, EngineConfig
from repro.engine.journal import RequestJournal, read_events, replay
from repro.engine.paged_cache import (PageAllocator, PagePoolExhausted,
                                      bucket_table_width, fork_page)
from repro.engine.prefix_cache import PrefixCache
from repro.engine.scheduler import (Request, RequestResult, RequestStatus,
                                    Scheduler)
from repro.engine.snapshot import EngineSnapshotter, restore, snapshot

__all__ = ["DecodeEngine", "EngineConfig", "pad_cache_from_prefill",
           "PageAllocator", "PagePoolExhausted", "PrefixCache", "Request",
           "RequestJournal", "RequestResult", "RequestStatus", "Scheduler",
           "EngineSnapshotter", "bucket_table_width", "fork_page",
           "read_events", "replay", "restore", "snapshot"]
