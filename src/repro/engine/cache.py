"""Decode-cache construction: place prefill KV material into the
fixed-size decode buffers (moved here from ``launch.serve`` — the
engine owns the cache lifecycle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def pad_cache_from_prefill(cfg, caches, batch, max_len, prefill_len=None,
                           enc_len=0):
    """Place prefill KV stacks into fixed-size decode cache buffers
    (at offset 0; the prefill length is implicit in the stacks).

    ``prefill_len`` is accepted for signature compatibility with the
    pre-engine ``launch.serve`` API and ignored — the stacks carry
    their own length."""
    cache = lm.init_cache(cfg, batch, max_len, enc_len=enc_len)
    fam = cfg.family

    def put(buf, kv):           # buf (L,B,T,...) <- kv (L,B,S,...)
        return jax.lax.dynamic_update_slice(
            buf, kv.astype(buf.dtype), (0,) * buf.ndim)

    if fam in ("dense", "vlm"):
        if cfg.mla is not None:
            ckv, krope = caches
            cache = {"ckv": put(cache["ckv"], ckv),
                     "krope": put(cache["krope"], krope)}
        else:
            k, v = caches
            cache = {"k": put(cache["k"], k), "v": put(cache["v"], v)}
    elif fam == "moe":
        kv_d, kv_m = caches
        if cfg.mla is not None:
            if cfg.moe.first_k_dense and kv_d is not None:
                cache["dense"] = {
                    "ckv": put(cache["dense"]["ckv"], kv_d[0]),
                    "krope": put(cache["dense"]["krope"], kv_d[1])}
            cache["moe"] = {"ckv": put(cache["moe"]["ckv"], kv_m[0]),
                            "krope": put(cache["moe"]["krope"], kv_m[1])}
        else:
            if cfg.moe.first_k_dense and kv_d is not None:
                cache["dense"] = {"k": put(cache["dense"]["k"], kv_d[0]),
                                  "v": put(cache["dense"]["v"], kv_d[1])}
            cache["moe"] = {"k": put(cache["moe"]["k"], kv_m[0]),
                            "v": put(cache["moe"]["v"], kv_m[1])}
    elif fam == "hybrid":
        (st_main, kv_main), (st_tail, kv_tail) = caches
        cache["mamba_main"] = st_main
        if st_tail is not None:
            cache["mamba_tail"] = st_tail
        ks = [kv_main[0]] if kv_tail is None else [kv_main[0],
                                                   kv_tail[0][None]]
        vs = [kv_main[1]] if kv_tail is None else [kv_main[1],
                                                   kv_tail[1][None]]
        cache["attn_k"] = put(cache["attn_k"], jnp.concatenate(ks, 0))
        cache["attn_v"] = put(cache["attn_v"], jnp.concatenate(vs, 0))
    elif fam == "ssm":
        m_sts, s_st = caches
        cache = {"mlstm": m_sts, "slstm": s_st}
    elif fam == "audio":
        kv, cross = caches
        cache["self_k"] = put(cache["self_k"], kv[0])
        cache["self_v"] = put(cache["self_v"], kv[1])
        cache["cross_k"] = put(cache["cross_k"], cross[0])
        cache["cross_v"] = put(cache["cross_v"], cross[1])
    return cache
