"""DecodeEngine: the explicit-mesh serving surface.

One object owns everything serving needs — the device mesh, the
TP-sharded parameters, the decode-cache PartitionSpecs, and the jitted
prefill/decode step functions — and threads the mesh *explicitly*
through ``lm.prefill`` / ``lm.decode_step`` / ``dist.decode``.  Nothing
on the decode hot path consults the ambient ``with mesh:`` context
(that lookup survives only as a deprecated fallback in
``common.hints``).

Quickstart::

    from repro.configs import get_config, reduced
    from repro.engine import DecodeEngine, EngineConfig

    cfg = reduced(get_config("qwen1.5-0.5b"))
    eng = DecodeEngine(cfg, EngineConfig(batch=4, max_len=48,
                                         mesh_shape=(1, 1)))
    tokens, stats = eng.generate({"tokens": prompt_tokens}, gen=16)

Migration from the pre-engine API: where you wrote
``steps.build_decode(cfg, mesh)`` + hand-rolled ``device_put`` of
params/cache against ``dist.sharding`` pspecs inside ``with mesh:``,
construct a ``DecodeEngine`` instead — it builds the same step
functions and layouts, and the ``with mesh:`` context is no longer
needed because the mesh rides the call chain.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as SH
from repro.engine import paged_cache
from repro.engine.cache import pad_cache_from_prefill
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving-shape knobs (everything model-side lives in ModelConfig).

    ``decode_shard`` / ``kernel_impl`` default to None = inherit the
    ModelConfig's setting — a cfg pinned to 'pallas'/'seq' is honored
    unless the EngineConfig overrides it explicitly.

    ``paged=True`` replaces the dense ``(batch, max_len)`` decode cache
    with a paged one (``engine.paged_cache``): a shared pool of
    ``n_pages`` pages of ``page_size`` positions plus per-slot block
    tables, so ``batch`` counts *slots* and ``max_len`` bounds any one
    request (it no longer multiplies into every slot's footprint).
    ``n_pages=None`` sizes the pool for a full dense-equivalent batch
    (batch * ceil(max_len / page_size)); continuous batching
    (``engine.scheduler``) typically runs with a smaller pool.

    ``kv_dtype='int8'`` (paged only) stores the page pools as
    symmetric int8 with fp32 per-page scale sidecars — ~2x fewer HBM
    bytes streamed per decoded token than bf16 pools, dequantized
    inside the flash-decode kernels.

    ``prefix_cache=True`` (paged, dense/moe families) turns on the
    prefix-sharing radix cache in ``engine.scheduler``: admission
    matches the longest cached whole-page prompt prefix, aliases those
    refcounted pages into the slot's block table, and prefills only
    the suffix (``engine.prefix_cache``).

    ``chunked_prefill=True`` (paged, dense/moe families) replaces
    batch-1 whole-prompt admission with chunked prefill inside the
    shared decode step: the scheduler grants a prompt all its pages up
    front and feeds it through the unified mixed step
    (``steps.build_mixed_step``) ``chunk_tokens`` tokens at a time,
    packed next to the decoding slots under a token budget — one long
    prompt no longer stalls decode.  ``chunk_tokens`` must be a
    multiple of ``page_size`` so every non-final chunk ends
    page-aligned (the next chunk's resident prefix is then whole
    pages, exactly the suffix-prefill contract)."""
    batch: int = 1
    max_len: int = 128              # prompt + generation budget
    mesh_shape: Tuple[int, int] = (1, 1)      # (data, model)
    decode_shard: Optional[str] = None   # 'none' | 'seq' (dist.decode)
    kernel_impl: Optional[str] = None    # 'xla' | 'pallas' | 'auto'
    param_strategy: str = "serve"   # dist.sharding param strategy
    paged: bool = False             # paged KV cache + block tables
    page_size: int = 16             # positions per page (paged=True)
    n_pages: Optional[int] = None   # pool size; None = dense-equivalent
    kv_dtype: str = "bf16"          # 'bf16' (model dtype) | 'int8'
    prefix_cache: bool = False      # radix prompt-prefix sharing
    chunked_prefill: bool = False   # mixed prefill/decode steps
    chunk_tokens: int = 32          # prefill tokens per mixed step

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


class DecodeEngine:
    """Owns mesh + sharded params + cache pspecs + jitted steps.

    ``params`` may be a ready parameter tree (it is re-laid-out onto
    the engine's mesh) or None to initialize fresh from ``seed``.
    ``mesh`` may be passed explicitly (e.g. a production mesh); by
    default it is built from ``ecfg.mesh_shape`` over local devices.
    """

    def __init__(self, cfg, ecfg: EngineConfig, params=None, mesh=None,
                 seed: int = 0):
        # None in the EngineConfig = inherit the ModelConfig's knob
        ecfg = ecfg.replace(
            kernel_impl=(ecfg.kernel_impl if ecfg.kernel_impl is not None
                         else cfg.kernel_impl),
            decode_shard=(ecfg.decode_shard
                          if ecfg.decode_shard is not None
                          else cfg.decode_shard))
        cfg = cfg.replace(kernel_impl=ecfg.kernel_impl,
                          decode_shard=ecfg.decode_shard)
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh if mesh is not None else make_local_mesh(
            *ecfg.mesh_shape)
        if ecfg.kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"EngineConfig.kv_dtype must be 'bf16' or "
                             f"'int8', got {ecfg.kv_dtype!r}")
        if ecfg.kv_dtype == "int8" and not ecfg.paged:
            raise ValueError(
                "kv_dtype='int8' requires paged=True: the dense decode "
                "cache appends in place every step and a growing "
                "per-sequence scale would re-quantize the whole slab "
                "per token — per-page scales make the rewrite O(page)")
        if ecfg.prefix_cache:
            if not ecfg.paged:
                raise ValueError(
                    "prefix_cache=True needs paged=True: prefix "
                    "sharing aliases physical pages through block "
                    "tables")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"prefix_cache=True supports the token-only "
                    f"families ('dense', 'moe'); family "
                    f"{cfg.family!r} prepends frontend positions a "
                    "token-keyed prefix index cannot match")
        if ecfg.chunked_prefill:
            if not ecfg.paged:
                raise ValueError(
                    "chunked_prefill=True needs paged=True: chunks "
                    "land in granted pages through the block table")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"chunked_prefill=True supports the token-only "
                    f"families ('dense', 'moe'); family "
                    f"{cfg.family!r} prepends frontend positions the "
                    "chunked (suffix-composed) prefill cannot offset")
            if ecfg.chunk_tokens < 1 or \
                    ecfg.chunk_tokens % ecfg.page_size:
                raise ValueError(
                    f"chunk_tokens={ecfg.chunk_tokens} must be a "
                    f"positive multiple of page_size="
                    f"{ecfg.page_size}: every non-final chunk must "
                    "end page-aligned so the next chunk's resident "
                    "prefix is whole pages")
        if ecfg.paged:
            paged_cache.check_family(cfg)
            if ecfg.kv_dtype == "int8" and cfg.family == "audio":
                raise ValueError(
                    "kv_dtype='int8' is unsupported for the audio "
                    "family (slot-dense cross cache stays model-dtype)")
            self.page_size = ecfg.page_size
            self.max_pages = paged_cache.max_pages(ecfg.max_len,
                                                   ecfg.page_size)
            self.n_pages = (ecfg.n_pages if ecfg.n_pages is not None
                            else ecfg.batch * self.max_pages)
        if ecfg.decode_shard == "seq":
            msize = self.mesh.shape.get("model", 1)
            if ecfg.paged:
                if self.n_pages % msize:
                    raise ValueError(
                        f"decode_shard='seq' needs n_pages="
                        f"{self.n_pages} divisible by the model axis "
                        f"({msize})")
            elif ecfg.max_len % msize:
                raise ValueError(
                    f"decode_shard='seq' needs max_len={ecfg.max_len} "
                    f"divisible by the model axis ({msize})")

        self.param_pspecs = SH.param_pspecs(cfg, self.mesh,
                                            ecfg.param_strategy)
        if params is None:
            params = lm.init(cfg, jax.random.PRNGKey(seed))
        self.params = jax.device_put(
            params, SH.to_shardings(self.mesh, self.param_pspecs))

        if ecfg.paged:
            self.cache_pspecs = SH.paged_cache_pspecs(
                cfg, self.mesh, ecfg.batch,
                seq_shard=(ecfg.decode_shard == "seq"),
                n_pages=self.n_pages,
                quantized=(ecfg.kv_dtype == "int8"))
        else:
            self.cache_pspecs = SH.cache_pspecs(
                cfg, self.mesh, ecfg.batch,
                seq_shard=(ecfg.decode_shard == "seq"))
        self.prefill_fn = jax.jit(steps.build_prefill(cfg, mesh=self.mesh))
        self.decode_fn = jax.jit(steps.build_decode(cfg, self.mesh))
        # suffix-only prefill for prefix-cache hits: built for every
        # paged token-only engine (the jit wrapper traces nothing until
        # called), so a Scheduler can enable the cache per-stream even
        # when the EngineConfig default is off
        self.suffix_prefill_fn = (
            jax.jit(steps.build_suffix_prefill(cfg, mesh=self.mesh))
            if ecfg.paged and cfg.family in ("dense", "moe") else None)
        # unified mixed prefill/decode step: built for every paged
        # token-only engine (like suffix_prefill_fn, the jit wrapper
        # traces nothing until called), so a Scheduler can turn
        # chunking on per-stream even when the EngineConfig default is
        # off
        self.mixed_fn = (
            jax.jit(steps.build_mixed_step(cfg, mesh=self.mesh))
            if ecfg.paged and cfg.family in ("dense", "moe") else None)
        self._enc_len = 0           # audio: encoder positions at prefill

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------

    def prefill(self, batch: Dict[str, Any]):
        """Prefill ``batch['tokens']`` (B, P) [+ frontend_emb] and build
        the fixed-size, mesh-laid-out decode cache.

        Returns (last-token logits (B, vocab_padded) fp32, cache)."""
        B, P = batch["tokens"].shape
        if B != self.ecfg.batch:
            raise ValueError(f"batch {B} != engine batch {self.ecfg.batch}")
        # encoder-decoder: the cross-attention cache is sized by the
        # ENCODER sequence (frontend_emb), which need not equal the
        # decoder prompt length
        enc_len = (batch["frontend_emb"].shape[1]
                   if self.cfg.is_encdec and "frontend_emb" in batch
                   else P)
        self._enc_len = enc_len
        logits, caches = self.prefill_fn(self.params, batch)
        if self.ecfg.paged:
            cache = paged_cache.init_paged_cache(
                self.cfg, self.n_pages, self.page_size, B,
                enc_len=enc_len, kv_dtype=self.ecfg.kv_dtype)
            cache = paged_cache.write_prefill(
                self.cfg, cache, caches, self.default_block_table())
        else:
            cache = pad_cache_from_prefill(self.cfg, caches, B,
                                           self.ecfg.max_len,
                                           enc_len=enc_len)
        cache = jax.device_put(
            cache, SH.to_shardings(self.mesh, self.cache_pspecs))
        return logits, cache

    def init_paged_cache(self, enc_len: Optional[int] = None):
        """Zeroed page pools laid out on the engine mesh — the
        starting cache for continuous batching (``engine.scheduler``
        fills it per admitted request).  ``enc_len`` budgets the audio
        cross cache (default: the engine max_len)."""
        if not self.ecfg.paged:
            raise ValueError("init_paged_cache() needs paged=True")
        cache = paged_cache.init_paged_cache(
            self.cfg, self.n_pages, self.page_size, self.ecfg.batch,
            enc_len=(enc_len if enc_len is not None
                     else self.ecfg.max_len),
            kv_dtype=self.ecfg.kv_dtype)
        return jax.device_put(
            cache, SH.to_shardings(self.mesh, self.cache_pspecs))

    def default_block_table(self) -> jax.Array:
        """Whole-batch identity block table: slot b owns pages
        [b * max_pages, (b+1) * max_pages) — the dense-equivalent
        layout ``generate`` uses.  Continuous batching
        (``engine.scheduler``) builds its own tables from the page
        allocator instead."""
        if not self.ecfg.paged:
            raise ValueError("default_block_table() needs paged=True")
        B, J = self.ecfg.batch, self.max_pages
        if self.n_pages < B * J:
            raise ValueError(
                f"whole-batch paged prefill needs n_pages >= "
                f"batch*max_pages = {B * J}, got {self.n_pages}; "
                "drive an oversubscribed pool through "
                "engine.scheduler.Scheduler instead")
        return (jnp.arange(B, dtype=jnp.int32)[:, None] * J
                + jnp.arange(J, dtype=jnp.int32)[None, :])

    def decode_step(self, token, cur_len, cache, block_table=None):
        """One token for the whole batch: token (B,) int32.

        Dense cache: ``cur_len`` is a scalar (every slot at the same
        position).  Paged (ecfg.paged): ``cur_len`` is a per-slot (B,)
        int32 vector and ``block_table`` (B, W) int32 is required,
        with W <= max_pages covering every slot's live pages — the
        scheduler passes the power-of-two width bucket of the longest
        active slot (``paged_cache.bucket_table_width``), so a step
        stages only live pages; the jitted step compiles once per
        distinct W (at most log2(max_pages)+1 shapes).  Returns
        (logits (B, vocab_padded) fp32, new cache).
        """
        if self.ecfg.paged:
            if block_table is None:
                raise ValueError(
                    "paged decode_step needs the block_table operand "
                    "(engine.default_block_table() for whole-batch "
                    "generation)")
            lens = jnp.asarray(cur_len, jnp.int32)
            if lens.ndim == 0:
                lens = jnp.full((self.ecfg.batch,), lens, jnp.int32)
            dbatch = {"token": token, "cur_len": lens,
                      "block_table": jnp.asarray(block_table, jnp.int32),
                      "cache": cache}
            if self.cfg.family == "audio":
                dbatch["enc_lens"] = jnp.full(
                    (self.ecfg.batch,), self._enc_len, jnp.int32)
            return self.decode_fn(self.params, dbatch)
        return self.decode_fn(self.params, {
            "token": token, "cur_len": jnp.int32(cur_len),
            "cache": cache})

    def prefill_len(self, batch) -> int:
        """Positions the prefill occupied (vlm prepends frontend tokens)."""
        P = batch["tokens"].shape[1]
        if self.cfg.family == "vlm":
            P += self.cfg.frontend_tokens
        return P

    # ------------------------------------------------------------------
    # generation loop
    # ------------------------------------------------------------------

    def generate(self, batch: Dict[str, Any], gen: int,
                 temperature: float = 0.0, seed: int = 0,
                 check_finite: bool = False,
                 ) -> Tuple[jax.Array, Dict[str, float]]:
        """Prefill + ``gen`` greedy (or sampled) decode steps.

        ``check_finite=True`` validates every step's logits and raises
        ``engine.faults.NonFiniteLogitsError`` on NaN/inf instead of
        silently emitting a corrupt stream (it costs one host sync per
        step; the scheduler's batched guard is the serving-path
        equivalent).

        Returns (tokens (B, gen) int32, stats with prefill/decode wall
        times and tok/s)."""
        prefill_tokens = self.prefill_len(batch)
        if prefill_tokens + gen - 1 > self.ecfg.max_len:
            raise ValueError(
                f"prompt {prefill_tokens} + gen {gen} exceeds "
                f"max_len {self.ecfg.max_len}")
        B = batch["tokens"].shape[0]

        t0 = time.time()
        logits, cache = self.prefill(batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        base_key = jax.random.PRNGKey(seed)

        def pick(logits, i):
            if temperature > 0:
                # fold_in, NOT PRNGKey(seed + i): additive seeds make
                # step i of seed s and step i-1 of seed s+1 sample with
                # the IDENTICAL key, so adjacent-seed requests in a
                # fleet replay correlated token streams.  fold_in keeps
                # (seed, args) -> tokens deterministic while giving
                # every (seed, step) pair an independent key.
                key = jax.random.fold_in(base_key, i)
                return jax.random.categorical(
                    key, logits / temperature, -1).astype(jnp.int32)
            return jnp.argmax(logits, -1).astype(jnp.int32)

        # first token is always the argmax of the prefill logits (the
        # pre-engine serve CLI's convention)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        block_table = (self.default_block_table() if self.ecfg.paged
                       else None)
        out = [tok]
        t0 = time.time()
        for i in range(gen - 1):
            logits, cache = self.decode_step(
                tok, prefill_tokens + i, cache, block_table=block_table)
            if check_finite and not bool(jnp.all(jnp.isfinite(logits))):
                from repro.engine.faults import NonFiniteLogitsError
                raise NonFiniteLogitsError(
                    f"non-finite logits at decode step {i}")
            tok = pick(logits, i)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        stats = {
            "t_prefill_s": t_prefill,
            "t_decode_s": t_decode,
            "prefill_tok_s": B * prefill_tokens / max(t_prefill, 1e-9),
            "decode_tok_s": B * max(gen - 1, 0) / max(t_decode, 1e-9),
        }
        return jnp.stack(out, 1), stats
