"""Deterministic fault injection for the paged serving path.

At serving scale faults are the steady state: a flaky interconnect
throws mid-step, a numerically cursed request drives logits to NaN, a
co-tenant eats the page pool, a degraded host turns every step into a
straggler.  The scheduler's fault handling (quarantine, bounded retry,
preemption watchdog, straggler flagging — see ``engine.scheduler``) is
only trustworthy if those faults can be reproduced *deterministically*
in tests, so this module injects them on a fixed schedule keyed by the
step-function call index:

  * ``NonFiniteLogits(step, slot)``  — the wrapped decode/prefill call
    number ``step`` returns logits with ``slot``'s row set to NaN/inf
    (the scheduler's isfinite guard must quarantine exactly that slot);
  * ``TransientError(step, count)``  — calls [step, step+count) raise
    ``InjectedFault`` *before* touching the device (the scheduler's
    bounded retry re-invokes; the call index advances, so a transient
    fault heals and a persistent one — large ``count`` — exhausts the
    retry budget and surfaces);
  * ``SlowStep(step, delay_s)``      — call ``step`` sleeps first (the
    StragglerMonitor must flag it);
  * ``CrashFault(step)``             — every call from ``step`` on
    raises ``CrashError`` (simulated process death: it escapes the
    bounded step retry by design; only the durable-serving restart
    loop — snapshot + journal recovery — survives it);
  * ``hold_pages(sched, n)``         — artificial pool pressure: n
    pages vanish from the allocator until the returned ``release()``
    is called (admission serializes / growth preempts — graceful
    degradation instead of a dead stream).

``inject(sched, decode_faults=..., prefill_faults=...)`` wraps the
scheduler's engine in a delegating proxy, so the engine object itself
(possibly shared with other schedulers) is never mutated.
``random_plan(seed, ...)`` draws a reproducible chaos schedule for
soak-style runs — same seed, same faults.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


class InjectedFault(RuntimeError):
    """The exception ``TransientError`` injections raise."""


class NonFiniteLogitsError(RuntimeError):
    """Raised by ``DecodeEngine.generate(check_finite=True)`` when a
    decode step produces NaN/inf logits."""


class CrashError(RuntimeError):
    """Simulated process death: unlike ``InjectedFault`` (transient —
    the scheduler's bounded step retry heals it), a ``CrashError`` is
    raised on EVERY wrapped call from the crash step on, so it always
    escapes the step retry and kills the scheduler loop.  The
    durable-serving supervisor (``runtime.resilience``'s restart loop
    around snapshot + journal recovery) is what survives it."""


@dataclasses.dataclass
class NonFiniteLogits:
    """Corrupt one slot's logits at wrapped-call index ``step``."""
    step: int
    slot: int = 0
    value: float = float("nan")


@dataclasses.dataclass
class TransientError:
    """Raise ``InjectedFault`` on wrapped-call indices
    [step, step + count) — count=1 is a transient blip a single retry
    heals; a large count models a persistent fault."""
    step: int
    count: int = 1
    message: str = "injected transient fault"


@dataclasses.dataclass
class SlowStep:
    """Sleep ``delay_s`` before wrapped-call index ``step`` (straggler)."""
    step: int
    delay_s: float = 0.25


@dataclasses.dataclass
class CrashFault:
    """Raise ``CrashError`` on every wrapped-call index >= ``step`` —
    deterministic process death at step k.  Raised *before* the step
    function touches the device, so the cache holds exactly the state
    of the k-1 completed steps (what a snapshot taken at or before k-1
    restores)."""
    step: int
    message: str = "injected crash (simulated process death)"


Fault = object   # NonFiniteLogits | TransientError | SlowStep | CrashFault


class FaultyStepFn:
    """Wraps a jitted step function with a deterministic fault schedule
    keyed by call index (``.calls``).  Note retries advance the call
    index: attempt k+1 of a step is call index k+1, which is exactly
    how a transient fault heals on retry.

    The wrapped fn may return any tuple whose FIRST element is the
    decode logits — ``(logits, cache)`` for prefill/decode steps,
    ``(logits, chunk_logits, cache)`` for the mixed chunked-prefill
    step — NonFiniteLogits corrupts that first element.  ``counter``
    (a one-element list) lets two wrappers share one call index, so a
    scheduler that alternates decode and mixed steps sees a single
    fault schedule over its step sequence."""

    def __init__(self, fn: Callable, faults: Sequence[Fault] = (),
                 counter: Optional[List[int]] = None):
        self.fn = fn
        self.faults = list(faults)
        self._calls = counter if counter is not None else [0]
        self.injected = 0

    @property
    def calls(self) -> int:
        return self._calls[0]

    def __call__(self, params, batch):
        k = self._calls[0]
        self._calls[0] += 1
        for f in self.faults:
            if isinstance(f, SlowStep) and f.step == k:
                self.injected += 1
                time.sleep(f.delay_s)
            elif isinstance(f, TransientError) \
                    and f.step <= k < f.step + f.count:
                self.injected += 1
                raise InjectedFault(f"{f.message} (call {k})")
            elif isinstance(f, CrashFault) and k >= f.step:
                self.injected += 1
                raise CrashError(f"{f.message} (call {k})")
        out = list(self.fn(params, batch))
        for f in self.faults:
            if isinstance(f, NonFiniteLogits) and f.step == k:
                self.injected += 1
                out[0] = jnp.asarray(out[0]).at[f.slot].set(f.value)
        return tuple(out)


class FaultyEngine:
    """Delegating engine proxy with fault-wrapped step functions: the
    underlying (possibly shared) engine is never mutated.

    ``decode_faults`` schedule over the engine's STEP sequence: the
    decode and mixed (chunked-prefill) step wrappers share one call
    counter and one fault list, so call index k means "the scheduler's
    k-th step" whichever kind it was — a TransientError landing on a
    mixed step exercises the retry-the-current-chunk-only path."""

    def __init__(self, eng, decode_faults: Sequence[Fault] = (),
                 prefill_faults: Sequence[Fault] = ()):
        self._eng = eng
        counter: List[int] = [0]
        step_faults = list(decode_faults)
        self.decode_fn = FaultyStepFn(eng.decode_fn, step_faults,
                                      counter=counter)
        self.prefill_fn = FaultyStepFn(eng.prefill_fn, prefill_faults)
        self.mixed_fn = (
            FaultyStepFn(eng.mixed_fn, step_faults, counter=counter)
            if getattr(eng, "mixed_fn", None) is not None else None)

    def __getattr__(self, name):
        return getattr(self._eng, name)


def inject(sched, decode_faults: Sequence[Fault] = (),
           prefill_faults: Sequence[Fault] = ()) -> FaultyEngine:
    """Point ``sched`` at a fault-wrapped proxy of its engine and
    return the proxy (``proxy.decode_fn.injected`` counts fired
    faults)."""
    sched.eng = FaultyEngine(sched.eng, decode_faults, prefill_faults)
    return sched.eng


def hold_pages(sched_or_allocator, n: int) -> Callable[[], None]:
    """Artificial pool pressure: allocate ``n`` pages out of the
    scheduler's pool so real requests see a smaller pool.  Returns a
    ``release()`` callable (idempotent) that gives them back."""
    alloc = getattr(sched_or_allocator, "allocator", sched_or_allocator)
    pages = alloc.alloc(n)
    released = [False]

    def release() -> None:
        if not released[0]:
            released[0] = True
            alloc.free(pages)
    return release


def random_plan(seed: int, n_steps: int, slots: int = 1,
                p_nonfinite: float = 0.02, p_transient: float = 0.02,
                p_slow: float = 0.0, slow_delay_s: float = 0.25,
                ) -> List[Fault]:
    """A reproducible chaos schedule: per step, independently draw each
    fault kind with the given probabilities (same seed -> same plan)."""
    rng = np.random.default_rng(seed)
    plan: List[Fault] = []
    for k in range(n_steps):
        if rng.random() < p_nonfinite:
            plan.append(NonFiniteLogits(
                step=k, slot=int(rng.integers(slots)),
                value=float(rng.choice([np.nan, np.inf, -np.inf]))))
        if rng.random() < p_transient:
            plan.append(TransientError(step=k))
        if p_slow and rng.random() < p_slow:
            plan.append(SlowStep(step=k, delay_s=slow_delay_s))
    return plan
