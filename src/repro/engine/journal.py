"""Write-ahead request journal: the durability half the snapshot alone
cannot provide.

A snapshot (``engine.snapshot``) captures the serving state every N
steps; everything that happened SINCE the last snapshot — requests
submitted, requests cancelled, requests that went terminal — would be
lost on a crash without a finer-grained record.  This module keeps
that record as an append-only JSONL log, one fsynced line per event:

  ``submit``    the full request (rid, prompt tokens, gen budget,
                temperature, seed, deadline, max_steps) — enough to
                re-queue it verbatim;
  ``cancel``    the cancellation intent (rid);
  ``terminal``  the finished ``RequestResult`` (tokens, status, error,
                latency, token timestamps) — recovered VERBATIM on
                replay, so a result the pre-crash process already
                produced is never lost and never recomputed.

Recovery = load the latest snapshot (or a fresh scheduler when the
crash beat the first cadence) + ``replay`` the journal.  Replay is
idempotent, so no snapshot/journal offset bookkeeping is needed: an
event whose effect is already inside the restored snapshot (a submit
whose request is live, a terminal already in ``finished``) is a no-op,
and only the journal *suffix* — events after the snapshot was cut —
changes the restored state:

  * ``terminal`` is authoritative: the result is recorded verbatim and
    the rid's live residue (slot, queue entry) is released — its decode
    already happened in the pre-crash process;
  * ``submit`` of an unknown rid re-queues the request in original
    arrival order (the journal is the arrival order);
  * ``cancel`` of a still-live rid re-applies — unless a ``terminal``
    for the same rid appears later in the log (cancel is journaled as
    *intent* before its effect), in which case the cancel is a no-op
    and the terminal alone is recovered, verbatim.

The log survives its own crash: a torn final line (the process died
mid-append) is skipped by ``read_events`` and truncated by
``RequestJournal`` on reopen, so the recovered process's appends start
on a clean line boundary.  Rids must be JSON-representable and unique
across the log's lifetime.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

import numpy as np


def _req_event(req) -> Dict[str, Any]:
    ev: Dict[str, Any] = {
        "ev": "submit",
        "rid": req.rid,
        "tokens": np.asarray(req.tokens, np.int32).tolist(),
        "gen": int(req.gen),
        "temperature": float(req.temperature),
        "seed": int(req.seed),
        "deadline_s": req.deadline_s,
        "max_steps": req.max_steps,
    }
    if req.frontend_emb is not None:
        emb = np.asarray(req.frontend_emb)
        ev["frontend_emb"] = {"data": emb.tolist(),
                              "dtype": str(emb.dtype)}
    return ev


def request_from_event(ev: Dict[str, Any]):
    """Rebuild a fresh ``Request`` from a ``submit`` journal event."""
    from repro.engine.scheduler import Request
    emb = None
    if ev.get("frontend_emb") is not None:
        rec = ev["frontend_emb"]
        emb = np.asarray(rec["data"], np.dtype(rec["dtype"]))
    return Request(rid=ev["rid"],
                   tokens=np.asarray(ev["tokens"], np.int32),
                   gen=int(ev["gen"]),
                   temperature=float(ev.get("temperature", 0.0)),
                   seed=int(ev.get("seed", 0)),
                   frontend_emb=emb,
                   deadline_s=ev.get("deadline_s"),
                   max_steps=ev.get("max_steps"))


class RequestJournal:
    """Append-only fsynced JSONL write-ahead log of request events.

    Each append is flushed AND fsynced before returning — a submit
    acknowledged to the client is on disk before the scheduler touches
    it, which is what makes "no acknowledged request is ever lost" a
    guarantee rather than a race."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        _repair_tail(path)
        self._f = open(path, "a", encoding="utf-8")
        # make the directory entry itself durable: without this, a
        # crash shortly after creation can lose the whole file — and
        # every "durably acknowledged" event in it — on filesystems
        # that don't persist the parent dir as a side effect
        _fsync_dir(d or ".")
        self.appended = 0

    def _append(self, ev: Dict[str, Any]) -> None:
        self._f.write(json.dumps(ev) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        self.appended += 1

    # scheduler-facing hooks -------------------------------------------

    def submit(self, req) -> None:
        self._append(_req_event(req))

    def cancel(self, rid: Any) -> None:
        self._append({"ev": "cancel", "rid": rid})

    def terminal(self, rid: Any, res) -> None:
        self._append({
            "ev": "terminal",
            "rid": rid,
            "tokens": np.asarray(res, np.int32).tolist(),
            "status": res.status.value,
            "error": res.error,
            "latency_s": res.latency_s,
            "token_times": res.token_times,
        })

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def _repair_tail(path: str) -> None:
    """Truncate a torn final line (the previous writer died
    mid-append) before reopening for append.  Anything after the last
    complete ``\\n``-terminated line was never acknowledged — the
    append only returns after write+fsync of the full line — so
    dropping it loses nothing, and NOT dropping it would glue the
    next append onto the torn fragment, corrupting an event that IS
    acknowledged and failing recovery on a second crash."""
    try:
        if os.path.getsize(path) == 0:
            return
    except OSError:
        return                      # no file yet: nothing to repair
    with open(path, "rb") as f:
        data = f.read()
    if data.endswith(b"\n"):
        return
    with open(path, "r+b") as f:
        f.truncate(data.rfind(b"\n") + 1)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(d: str) -> None:
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:
        return                      # platform can't open dirs: best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def read_events(path: str) -> List[Dict[str, Any]]:
    """Parse the journal, tolerating a torn final line (the writer
    died mid-append; everything before it is intact because each
    append was fsynced).  A torn line ANYWHERE else is corruption and
    raises."""
    if not os.path.exists(path):
        return []
    events: List[Dict[str, Any]] = []
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break               # torn tail: the crash mid-append
            raise ValueError(
                f"corrupt journal line {i + 1} of {len(lines)} in "
                f"{path!r} (not the tail — this is not a torn append)")
    return events


def replay(sched, events: List[Dict[str, Any]]) -> Dict[str, int]:
    """Apply the journal to a restored (or fresh) scheduler,
    idempotently.  Returns counters: ``recovered`` terminal results
    recorded verbatim, ``requeued`` submits re-queued, ``cancelled``
    live cancels re-applied, ``noop`` events whose effect was already
    in the snapshot.  Journaling is suppressed during replay — the
    events being applied are already on disk."""
    from repro.engine.scheduler import RequestResult, RequestStatus

    stats = {"recovered": 0, "requeued": 0, "cancelled": 0, "noop": 0}
    # rids whose terminal is somewhere in the log: their cancel lines
    # (journaled as intent BEFORE the terminal) must not re-run
    # sched.cancel(), which would synthesize a fresh CANCELLED result
    # from snapshot-time partial state and shadow the verbatim one
    terminal_rids = {ev["rid"] for ev in events if ev["ev"] == "terminal"}
    saved_journal, sched.journal = sched.journal, None
    try:
        for ev in events:
            rid = ev["rid"]
            kind = ev["ev"]
            if kind == "terminal":
                if rid in sched.finished:
                    stats["noop"] += 1
                    continue
                _drop_live(sched, rid,
                           RequestStatus(ev["status"]))
                sched.finished[rid] = RequestResult(
                    np.asarray(ev["tokens"], np.int32),
                    RequestStatus(ev["status"]),
                    error=ev.get("error"),
                    latency_s=ev.get("latency_s"),
                    token_times=ev.get("token_times"))
                stats["recovered"] += 1
            elif kind == "submit":
                if rid in sched.finished or _find_live(sched, rid):
                    stats["noop"] += 1
                    continue
                sched.submit(request_from_event(ev))
                stats["requeued"] += 1
            elif kind == "cancel":
                if (rid in terminal_rids or rid in sched.finished
                        or not _find_live(sched, rid)):
                    stats["noop"] += 1
                    continue
                sched.cancel(rid)
                stats["cancelled"] += 1
            else:
                raise ValueError(f"unknown journal event {kind!r}")
    finally:
        sched.journal = saved_journal
    return stats


def _find_live(sched, rid: Any) -> bool:
    from repro.engine.scheduler import _Slot
    for slot in sched.slots:
        if slot is not None and slot.req.rid == rid:
            return True
    for q in (sched.pending, sched.parked):
        for item in q:
            req = item.req if isinstance(item, _Slot) else item
            if req.rid == rid:
                return True
    return False


def _drop_live(sched, rid: Any, status) -> None:
    """Release the live residue of a rid whose terminal result is
    being recovered verbatim: its decode already happened in the
    pre-crash process, so the restored slot/queue entry must not run
    again (or the result would be produced twice).  A FINISHED slot's
    resident prefix is indexed into the prefix trie first, mirroring
    what ``_retire`` did pre-crash, so post-recovery admissions keep
    hitting the shared prompt."""
    from repro.engine.scheduler import RequestStatus, _Slot
    for slot_id, slot in enumerate(sched.slots):
        if slot is not None and slot.req.rid == rid:
            if (status is RequestStatus.FINISHED
                    and sched.prefix is not None and slot.pages
                    and slot.req.status is not RequestStatus.PREFILLING):
                toks = np.concatenate([
                    np.asarray(slot.req.tokens, np.int32),
                    np.asarray(slot.out[:-1], np.int32)])
                sched.prefix.insert(toks, slot.pages)
            sched._evict(slot_id)
            return
    for q in (sched.pending, sched.parked):
        for item in list(q):
            req = item.req if isinstance(item, _Slot) else item
            if req.rid == rid:
                q.remove(item)
                sched._release_queued(item)
                return
