"""Paged KV cache: a shared page pool + per-slot block tables.

The dense decode cache pads every request to the engine's full
``(B, max_len)`` budget, so at serving scale most cache bytes are
*dead* — allocated, streamed around, never read.  Paging replaces the
per-slot budget with a shared pool of fixed-width pages:

  * each family cache leaf becomes a **page pool** with the (B, T)
    dims replaced by ``(n_pages, page_size)`` — e.g. the GQA leaf
    ``(L, B, T, KV, Dh)`` becomes ``(L, n_pages, page_size, KV, Dh)``;
  * a ``(B_slots, max_pages)`` int32 **block table** maps each slot's
    logical page j to a physical page id (the allocator hands pages
    out on demand, so a slot only ever owns ``ceil(len/page_size)``
    pages).

The page is the software analogue of the paper's intermediate-tier
transaction: a fixed-width unit staged whole into the kernel (the
block-table scalar prefetch in ``kernels.vwr_decode`` resolves the
page id before the DMA fires), so reclaiming dead bytes costs no
transaction width.  Recurrent families (hybrid/ssm) carry O(1) state
per slot — nothing to page — and are rejected here.

This module owns the *layout* (pool specs, zero-init, prefill
scatter) and the host-side page allocator; request-level admission /
eviction policy lives in ``engine.scheduler``.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

PAGED_FAMILIES = ("dense", "vlm", "moe", "audio")


def check_family(cfg) -> None:
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV cache supports the KV-cache families "
            f"{PAGED_FAMILIES}; family {cfg.family!r} carries O(1) "
            "recurrent state per slot (nothing to page) — serve it "
            "with the dense engine")


def max_pages(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def bucket_table_width(live_pages: int, max_pages: int) -> int:
    """Block-table width bucket covering ``live_pages`` columns.

    Fixed-width ``(B, max_pages)`` tables keep the jitted decode step
    at one shape, but every step then stages (or at least masks)
    ``max_pages`` pages per slot even when the longest slot only owns
    a handful — dead table columns are the table-side analogue of the
    dense cache's dead bytes.  Bucketing rounds the live width up to
    the next power of two (capped at ``max_pages``): the step is
    compiled once per bucket — at most log2(max_pages)+1 shapes over a
    stream's lifetime — and a step stages at most the bucket width of
    pages per slot instead of ``max_pages``.
    """
    if live_pages >= max_pages:
        return max_pages
    w = 1
    while w < max(live_pages, 1):
        w *= 2
    return min(w, max_pages)


def paged_cache_spec(cfg, n_pages: int, page_size: int,
                     batch_slots: int, enc_len: int = 0):
    """ShapeDtypeStruct tree for the paged decode cache.

    KV leaves become ``(L, n_pages, page_size, ...)`` pools.  The audio
    cross-attention cache stays slot-dense ``(L, B_slots, enc_len_p,
    KV, Dh)`` — it is written once at admission and sized exactly by
    the encoder length (no dead bytes to reclaim); ``lm`` *views* it as
    an identity-paged pool at attend time, so ``enc_len`` is padded up
    to a page multiple here.
    """
    check_family(cfg)
    fam = cfg.family
    dt_ = jnp.dtype(cfg.dtype)

    def sds(shape, dtype=dt_):
        return jax.ShapeDtypeStruct(shape, dtype)

    def gqa_pool(L):
        sh = (L, n_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        return {"k": sds(sh), "v": sds(sh)}

    def mla_pool(L):
        m = cfg.mla
        return {"ckv": sds((L, n_pages, page_size, m.kv_lora_rank)),
                "krope": sds((L, n_pages, page_size, m.rope_head_dim))}

    if fam in ("dense", "vlm"):
        return mla_pool(cfg.n_layers) if cfg.mla is not None \
            else gqa_pool(cfg.n_layers)

    if fam == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        mk = mla_pool if cfg.mla is not None else gqa_pool
        return {"dense": mk(m.first_k_dense) if m.first_k_dense else None,
                "moe": mk(n_moe)}

    # audio: paged self-attention pool + slot-dense cross cache padded
    # to a page multiple (lm reshapes it into an identity-paged view)
    enc_p = max_pages(max(enc_len, 1), page_size) * page_size
    xh = (cfg.n_layers, batch_slots, enc_p, cfg.n_kv_heads, cfg.d_head)
    pool = gqa_pool(cfg.n_layers)
    return {"self_k": pool["k"], "self_v": pool["v"],
            "cross_k": sds(xh), "cross_v": sds(xh)}


def init_paged_cache(cfg, n_pages: int, page_size: int,
                     batch_slots: int, enc_len: int = 0):
    spec = paged_cache_spec(cfg, n_pages, page_size, batch_slots, enc_len)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ----------------------------------------------------------------------
# prefill -> pages
# ----------------------------------------------------------------------

def _scatter_pages(pool, kv, table):
    """pool (L, n_pages, ps, ...) <- kv (L, B', S, ...) at the pages of
    ``table`` (B', max_pages); S is padded up to a page multiple (the
    zero pad also scrubs stale bytes from reused pages)."""
    L, Bp, S = kv.shape[:3]
    ps = pool.shape[2]
    pad = (-S) % ps
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (kv.ndim - 3))
    J = kv.shape[2] // ps
    kv = kv.reshape(L, Bp, J, ps, *kv.shape[3:]).astype(pool.dtype)
    return pool.at[:, table[:, :J]].set(kv)


def write_prefill(cfg, cache, caches, table, *, enc_caches_slots=None):
    """Scatter prefill KV material into the page pools.

    ``caches`` is the raw ``lm.prefill`` cache material for B' requests
    (B' = full slot count for whole-batch prefill, or 1 for the
    scheduler's admit-into-slot path); ``table`` holds those requests'
    block-table rows (B', max_pages).  For audio,
    ``enc_caches_slots`` is the list of slot indices receiving the
    slot-dense cross cache rows.  Returns the updated cache tree.
    """
    check_family(cfg)
    fam = cfg.family
    cache = dict(cache)

    if fam in ("dense", "vlm"):
        if cfg.mla is not None:
            ckv, krope = caches
            cache["ckv"] = _scatter_pages(cache["ckv"], ckv, table)
            cache["krope"] = _scatter_pages(cache["krope"], krope, table)
        else:
            k, v = caches
            cache["k"] = _scatter_pages(cache["k"], k, table)
            cache["v"] = _scatter_pages(cache["v"], v, table)
        return cache

    if fam == "moe":
        kv_d, kv_m = caches
        keys = ("ckv", "krope") if cfg.mla is not None else ("k", "v")
        if cfg.moe.first_k_dense and kv_d is not None:
            cache["dense"] = {
                kk: _scatter_pages(cache["dense"][kk], kv_d[i], table)
                for i, kk in enumerate(keys)}
        cache["moe"] = {
            kk: _scatter_pages(cache["moe"][kk], kv_m[i], table)
            for i, kk in enumerate(keys)}
        return cache

    # audio
    kv, cross = caches
    cache["self_k"] = _scatter_pages(cache["self_k"], kv[0], table)
    cache["self_v"] = _scatter_pages(cache["self_v"], kv[1], table)
    slots = jnp.asarray(
        enc_caches_slots if enc_caches_slots is not None
        else range(kv[0].shape[1]), jnp.int32)
    enc_p = cache["cross_k"].shape[2]
    for kk, xkv in (("cross_k", cross[0]), ("cross_v", cross[1])):
        pad = enc_p - xkv.shape[2]
        if pad:
            xkv = jnp.pad(xkv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache[kk] = cache[kk].at[:, slots].set(
            xkv.astype(cache[kk].dtype))
    return cache


# ----------------------------------------------------------------------
# host-side page allocator
# ----------------------------------------------------------------------

class PagePoolExhausted(RuntimeError):
    """Raised when an admit/step needs more pages than the pool has
    free — evict a request, shrink the stream, or raise ``n_pages``."""


class PageAllocator:
    """Free-list over physical page ids [0, n_pages).  Pure host state:
    the device only ever sees the resulting block tables.

    Every handed-out page is tracked in an owned set, so ``free`` can
    reject a double free and a page it never handed out as *different*
    faults, and ``check()`` can assert the pool invariant
    (owned ∪ free == all pages, owned ∩ free == ∅) at any point — the
    chaos / property tests call it after every scheduler transition."""

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._owned: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PagePoolExhausted(
                f"page pool exhausted: need {n} page(s), "
                f"{len(self._free)} free of {self.n_pages} "
                f"(evict a request or raise n_pages / EngineConfig."
                f"page_size)")
        out = [self._free.pop() for _ in range(n)]
        self._owned.update(out)
        return out

    def free(self, pages: Sequence[int]) -> None:
        seen: set = set()
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"freeing invalid page id {p}")
            if p in seen:
                raise ValueError(f"double free of page {p} within one "
                                 "free() call")
            if p not in self._owned:
                raise ValueError(
                    f"double free of page {p}: not currently handed "
                    "out (already freed, or never allocated)")
            seen.add(p)
        for p in pages:
            self._owned.discard(p)
        self._free.extend(pages)

    def check(self) -> bool:
        """Validate the pool invariant; raises ``ValueError`` on any
        violation, returns True otherwise."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise ValueError("free list contains duplicate page ids")
        overlap = free & self._owned
        if overlap:
            raise ValueError(f"pages both free and owned: "
                             f"{sorted(overlap)}")
        universe = free | self._owned
        if universe != set(range(self.n_pages)):
            raise ValueError(
                f"page leak: owned ∪ free covers {len(universe)} of "
                f"{self.n_pages} pages "
                f"(missing {sorted(set(range(self.n_pages)) - universe)})")
        return True
