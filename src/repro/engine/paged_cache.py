"""Paged KV cache: a shared page pool + per-slot block tables.

The dense decode cache pads every request to the engine's full
``(B, max_len)`` budget, so at serving scale most cache bytes are
*dead* — allocated, streamed around, never read.  Paging replaces the
per-slot budget with a shared pool of fixed-width pages:

  * each family cache leaf becomes a **page pool** with the (B, T)
    dims replaced by ``(n_pages, page_size)`` — e.g. the GQA leaf
    ``(L, B, T, KV, Dh)`` becomes ``(L, n_pages, page_size, KV, Dh)``;
  * a ``(B_slots, max_pages)`` int32 **block table** maps each slot's
    logical page j to a physical page id (the allocator hands pages
    out on demand, so a slot only ever owns ``ceil(len/page_size)``
    pages).

The page is the software analogue of the paper's intermediate-tier
transaction: a fixed-width unit staged whole into the kernel (the
block-table scalar prefetch in ``kernels.vwr_decode`` resolves the
page id before the DMA fires), so reclaiming dead bytes costs no
transaction width.  Recurrent families (hybrid/ssm) carry O(1) state
per slot — nothing to page — and are rejected here.

With ``kv_dtype='int8'`` each pool stores symmetric int8 pages with an
fp32 **scale sidecar** — per page per KV head for GQA
(``k_scale``/``v_scale`` (L, n_pages, KV)), per page for the flat MLA
latent pools (``ckv_scale``/``krope_scale`` (L, n_pages)).  The
sidecar rides the same block-table indirection as the pools and the
flash-decode kernels dequantize INSIDE the staged block, so the HBM
bytes streamed per token drop ~2x vs bf16 (~4x vs fp32) at identical
transaction geometry.  One scale per whole page keeps the sidecar
O(n_pages) and, because a per-block-constant scale commutes with the
dot products, the q8 kernels are exact in fp32 arithmetic up to the
int8 rounding itself.

This module owns the *layout* (pool specs, zero-init, prefill
scatter, the per-step quantized token write) and the host-side page
allocator; request-level admission / eviction policy lives in
``engine.scheduler``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.quant import int8_scale, quantize_int8

PAGED_FAMILIES = ("dense", "vlm", "moe", "audio")


def check_family(cfg) -> None:
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV cache supports the KV-cache families "
            f"{PAGED_FAMILIES}; family {cfg.family!r} carries O(1) "
            "recurrent state per slot (nothing to page) — serve it "
            "with the dense engine")


def max_pages(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def bucket_table_width(live_pages: int, max_pages: int) -> int:
    """Block-table width bucket covering ``live_pages`` columns.

    Fixed-width ``(B, max_pages)`` tables keep the jitted decode step
    at one shape, but every step then stages (or at least masks)
    ``max_pages`` pages per slot even when the longest slot only owns
    a handful — dead table columns are the table-side analogue of the
    dense cache's dead bytes.  Bucketing rounds the live width up to
    the next power of two (capped at ``max_pages``): the step is
    compiled once per bucket — at most log2(max_pages)+1 shapes over a
    stream's lifetime — and a step stages at most the bucket width of
    pages per slot instead of ``max_pages``.
    """
    if live_pages >= max_pages:
        return max_pages
    w = 1
    while w < max(live_pages, 1):
        w *= 2
    return min(w, max_pages)


def paged_cache_spec(cfg, n_pages: int, page_size: int,
                     batch_slots: int, enc_len: int = 0,
                     kv_dtype: str = None):
    """ShapeDtypeStruct tree for the paged decode cache.

    KV leaves become ``(L, n_pages, page_size, ...)`` pools.  The audio
    cross-attention cache stays slot-dense ``(L, B_slots, enc_len_p,
    KV, Dh)`` — it is written once at admission and sized exactly by
    the encoder length (no dead bytes to reclaim); ``lm`` *views* it as
    an identity-paged pool at attend time, so ``enc_len`` is padded up
    to a page multiple here.

    ``kv_dtype``: None/'bf16' keeps the pools at the model dtype;
    'int8' stores int8 pools plus fp32 per-page scale sidecars
    (``k_scale``/``v_scale`` (L, n_pages, KV) for GQA,
    ``ckv_scale``/``krope_scale`` (L, n_pages) for MLA latents).
    """
    check_family(cfg)
    fam = cfg.family
    dt_ = jnp.dtype(cfg.dtype)
    if kv_dtype not in (None, "bf16", "int8"):
        raise ValueError(f"kv_dtype must be 'bf16' or 'int8', got "
                         f"{kv_dtype!r}")
    q8 = kv_dtype == "int8"
    if q8 and fam == "audio":
        raise ValueError(
            "kv_dtype='int8' is unsupported for the audio family: the "
            "slot-dense cross cache is written once at admission and "
            "stays at the model dtype — serve audio with kv_dtype="
            "'bf16'")
    pool_dt = jnp.dtype(jnp.int8) if q8 else dt_

    def sds(shape, dtype=dt_):
        return jax.ShapeDtypeStruct(shape, dtype)

    def gqa_pool(L):
        sh = (L, n_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        pool = {"k": sds(sh, pool_dt), "v": sds(sh, pool_dt)}
        if q8:
            ssh = (L, n_pages, cfg.n_kv_heads)
            pool["k_scale"] = sds(ssh, jnp.float32)
            pool["v_scale"] = sds(ssh, jnp.float32)
        return pool

    def mla_pool(L):
        m = cfg.mla
        pool = {"ckv": sds((L, n_pages, page_size, m.kv_lora_rank),
                           pool_dt),
                "krope": sds((L, n_pages, page_size, m.rope_head_dim),
                             pool_dt)}
        if q8:
            pool["ckv_scale"] = sds((L, n_pages), jnp.float32)
            pool["krope_scale"] = sds((L, n_pages), jnp.float32)
        return pool

    if fam in ("dense", "vlm"):
        return mla_pool(cfg.n_layers) if cfg.mla is not None \
            else gqa_pool(cfg.n_layers)

    if fam == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        mk = mla_pool if cfg.mla is not None else gqa_pool
        return {"dense": mk(m.first_k_dense) if m.first_k_dense else None,
                "moe": mk(n_moe)}

    # audio: paged self-attention pool + slot-dense cross cache padded
    # to a page multiple (lm reshapes it into an identity-paged view)
    enc_p = max_pages(max(enc_len, 1), page_size) * page_size
    xh = (cfg.n_layers, batch_slots, enc_p, cfg.n_kv_heads, cfg.d_head)
    pool = gqa_pool(cfg.n_layers)
    return {"self_k": pool["k"], "self_v": pool["v"],
            "cross_k": sds(xh), "cross_v": sds(xh)}


def init_paged_cache(cfg, n_pages: int, page_size: int,
                     batch_slots: int, enc_len: int = 0,
                     kv_dtype: str = None):
    spec = paged_cache_spec(cfg, n_pages, page_size, batch_slots,
                            enc_len, kv_dtype)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ----------------------------------------------------------------------
# prefill -> pages
# ----------------------------------------------------------------------

def _scatter_pages(pool, kv, table):
    """pool (L, n_pages, ps, ...) <- kv (L, B', S, ...) at the pages of
    ``table`` (B', max_pages); S is padded up to a page multiple (the
    zero pad also scrubs stale bytes from reused pages)."""
    L, Bp, S = kv.shape[:3]
    ps = pool.shape[2]
    pad = (-S) % ps
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (kv.ndim - 3))
    J = kv.shape[2] // ps
    kv = kv.reshape(L, Bp, J, ps, *kv.shape[3:]).astype(pool.dtype)
    return pool.at[:, table[:, :J]].set(kv)


def _scatter_pages_q8(pool, scales, kv, table):
    """Quantize-on-write prefill scatter into an int8 pool + sidecar.

    Same layout contract as ``_scatter_pages`` but the page material is
    symmetric-int8 quantized per page — per KV head when the pool
    carries a head axis (GQA (L, n_pages, ps, KV, Dh), scale group =
    (ps, Dh) per head), per whole page for the flat MLA latents
    (L, n_pages, ps, r).  The zero pad of a partial last page rides
    inside the scale group, so it both scrubs stale bytes and leaves
    the amax untouched.  Returns (pool, scales)."""
    L, Bp, S = kv.shape[:3]
    ps = pool.shape[2]
    pad = (-S) % ps
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (kv.ndim - 3))
    J = kv.shape[2] // ps
    kvr = kv.reshape(L, Bp, J, ps, *kv.shape[3:])
    if pool.ndim == 5:                      # GQA: per-page per-head
        q, s = quantize_int8(kvr, axis=(3, 5))
        s = s.reshape(L, Bp, J, kvr.shape[4])
    else:                                   # MLA latent: per-page
        q, s = quantize_int8(kvr, axis=(3, 4))
        s = s.reshape(L, Bp, J)
    return (pool.at[:, table[:, :J]].set(q),
            scales.at[:, table[:, :J]].set(s))


def _scatter_family(sub, kvs, keys, table):
    """Scatter one family's prefill material (``kvs`` aligned with
    ``keys``) into its pool dict ``sub``, routing through the q8
    quantize-on-write path when the dict carries scale sidecars."""
    sub = dict(sub)
    q8 = keys[0] + "_scale" in sub
    for kk, kv in zip(keys, kvs):
        if q8:
            sub[kk], sub[kk + "_scale"] = _scatter_pages_q8(
                sub[kk], sub[kk + "_scale"], kv, table)
        else:
            sub[kk] = _scatter_pages(sub[kk], kv, table)
    return sub


def quantized_page_write(pool, scales, pages, offs, x):
    """One decode token per slot into an int8 pool + scale sidecar.

    pool: (n_pages, ps, KV, Dh) or (n_pages, ps, r) int8 (one layer's
    slice); scales: (n_pages, KV) or (n_pages,) fp32; pages/offs: (B,)
    from ``models.lm._page_write_ids`` (page id ``n_pages`` = inactive
    slot, dropped); x: (B, KV, Dh) or (B, r) new-token material.

    Page scales only ever *grow* while a page fills: the write at
    offset 0 resets the scale to the token's own amax (the device-side
    scrub of a reused page — the rest of the page is zeroed, no
    allocator hook needed), and later writes take ``max(s_old,
    s_tok)`` and requantize the already-resident rows of the touched
    page onto the new grid before inserting the token.  One whole-page
    scatter per step, mirroring the bf16 path's single
    ``at[pages, offs].set``."""
    n_pages = pool.shape[0]
    B = x.shape[0]
    per_head = pool.ndim == 4               # (n_pages, ps, KV, Dh)
    xf = x.astype(jnp.float32)
    s_tok = int8_scale(jnp.max(jnp.abs(xf), axis=-1))  # (B, KV) | (B,)
    pidx = jnp.clip(pages, 0, n_pages - 1)
    s_old = scales[pidx]
    fresh = offs == 0
    s_new = jnp.where(fresh[:, None] if per_head else fresh,
                      s_tok, jnp.maximum(s_old, s_tok))

    def ex(s):                              # scale -> page broadcast
        return s[:, None, :, None] if per_head else s[:, None, None]

    page_f = pool[pidx].astype(jnp.float32) * ex(s_old)
    keep = ~fresh.reshape((B,) + (1,) * (page_f.ndim - 1))
    page_f = jnp.where(keep, page_f, 0.0)
    qpage = jnp.clip(jnp.round(page_f / ex(s_new)),
                     -127, 127).astype(jnp.int8)
    qtok = jnp.clip(jnp.round(xf / s_new[..., None]),
                    -127, 127).astype(jnp.int8)
    qpage = qpage.at[jnp.arange(B), offs].set(qtok)
    return (pool.at[pages].set(qpage, mode="drop"),
            scales.at[pages].set(s_new, mode="drop"))


def write_prefill(cfg, cache, caches, table, *, enc_caches_slots=None):
    """Scatter prefill KV material into the page pools.

    ``caches`` is the raw ``lm.prefill`` cache material for B' requests
    (B' = full slot count for whole-batch prefill, or 1 for the
    scheduler's admit-into-slot path); ``table`` holds those requests'
    block-table rows (B', max_pages).  For audio,
    ``enc_caches_slots`` is the list of slot indices receiving the
    slot-dense cross cache rows.  Returns the updated cache tree.
    """
    check_family(cfg)
    fam = cfg.family
    cache = dict(cache)
    keys = ("ckv", "krope") if cfg.mla is not None else ("k", "v")

    if fam in ("dense", "vlm"):
        return _scatter_family(cache, caches, keys, table)

    if fam == "moe":
        kv_d, kv_m = caches
        if cfg.moe.first_k_dense and kv_d is not None:
            cache["dense"] = _scatter_family(cache["dense"], kv_d,
                                             keys, table)
        cache["moe"] = _scatter_family(cache["moe"], kv_m, keys, table)
        return cache

    # audio
    kv, cross = caches
    cache["self_k"] = _scatter_pages(cache["self_k"], kv[0], table)
    cache["self_v"] = _scatter_pages(cache["self_v"], kv[1], table)
    slots = jnp.asarray(
        enc_caches_slots if enc_caches_slots is not None
        else range(kv[0].shape[1]), jnp.int32)
    enc_p = cache["cross_k"].shape[2]
    for kk, xkv in (("cross_k", cross[0]), ("cross_v", cross[1])):
        pad = enc_p - xkv.shape[2]
        if pad:
            xkv = jnp.pad(xkv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache[kk] = cache[kk].at[:, slots].set(
            xkv.astype(cache[kk].dtype))
    return cache


# ----------------------------------------------------------------------
# host-side page allocator
# ----------------------------------------------------------------------

class PagePoolExhausted(RuntimeError):
    """Raised when an admit/step needs more pages than the pool has
    free — evict a request, shrink the stream, or raise ``n_pages``."""


class PageAllocator:
    """Refcounted free-list over physical page ids [0, n_pages).  Pure
    host state: the device only ever sees the resulting block tables.

    ``alloc`` hands a page out at refcount 1; every additional holder
    (a prefix-cache trie node, a second slot aliasing the page through
    its block table) takes a ref with ``incref`` and releases it with
    ``decref`` — the page returns to the free list only when the last
    ref drops.  The legacy ``free`` keeps its exclusive-owner contract
    (it rejects a shared page: freeing under another holder is exactly
    the preempt/retire double-free the prefix cache must not hit), so
    pre-refcount callers and their double-free diagnostics keep
    working.  ``check()`` asserts the pool invariant at any point
    (owned ∪ free == all pages, owned ∩ free == ∅, every owned page
    holds refcount >= 1, no free page holds a ref) — the chaos /
    property tests call it after every scheduler transition."""

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._owned: set = set()
        self._refs: Dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Pages currently held by more than one holder."""
        return sum(1 for r in self._refs.values() if r > 1)

    def refcount(self, page: int) -> int:
        """Current holders of ``page`` (0 = free)."""
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PagePoolExhausted(
                f"page pool exhausted: need {n} page(s), "
                f"{len(self._free)} free of {self.n_pages} "
                f"(evict a request or raise n_pages / EngineConfig."
                f"page_size)")
        out = [self._free.pop() for _ in range(n)]
        self._owned.update(out)
        for p in out:
            self._refs[p] = 1
        return out

    def _validate_owned(self, pages: Sequence[int], verb: str) -> None:
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"{verb} invalid page id {p}")
            if p not in self._owned:
                raise ValueError(
                    f"{verb} page {p}: not currently handed out "
                    "(already freed, or never allocated)")

    def incref(self, pages: Sequence[int]) -> None:
        """Take one more ref on each page (pages must be handed out)."""
        self._validate_owned(pages, "incref of")
        for p in pages:
            self._refs[p] += 1

    def decref(self, pages: Sequence[int]) -> None:
        """Drop one ref per page; a page whose last ref drops returns
        to the free list.  The same page may appear more than once (it
        then loses one ref per occurrence)."""
        self._validate_owned(pages, "decref of")
        counts: Dict[int, int] = {}
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
        for p, n in counts.items():
            if self._refs[p] < n:
                raise ValueError(
                    f"decref of page {p} by {n} holder(s) but only "
                    f"{self._refs[p]} ref(s) held")
        released = []
        for p, n in counts.items():
            self._refs[p] -= n
            if self._refs[p] == 0:
                del self._refs[p]
                self._owned.discard(p)
                released.append(p)
        self._free.extend(released)

    def free(self, pages: Sequence[int]) -> None:
        """Exclusive-owner release: every page must be held by exactly
        one ref.  A shared page raises — the caller is about to pull a
        page out from under the prefix cache / another slot; route
        shared ownership through ``decref`` instead."""
        seen: set = set()
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"freeing invalid page id {p}")
            if p in seen:
                raise ValueError(f"double free of page {p} within one "
                                 "free() call")
            if p not in self._owned:
                raise ValueError(
                    f"double free of page {p}: not currently handed "
                    "out (already freed, or never allocated)")
            if self._refs.get(p, 0) != 1:
                raise ValueError(
                    f"free of shared page {p} (refcount "
                    f"{self._refs.get(p, 0)}): another holder still "
                    "references it — decref instead")
            seen.add(p)
        for p in pages:
            self._owned.discard(p)
            del self._refs[p]
        self._free.extend(pages)

    def check(self) -> bool:
        """Validate the pool invariant; raises ``ValueError`` on any
        violation, returns True otherwise."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise ValueError("free list contains duplicate page ids")
        overlap = free & self._owned
        if overlap:
            raise ValueError(f"pages both free and owned: "
                             f"{sorted(overlap)}")
        universe = free | self._owned
        if universe != set(range(self.n_pages)):
            raise ValueError(
                f"page leak: owned ∪ free covers {len(universe)} of "
                f"{self.n_pages} pages "
                f"(missing {sorted(set(range(self.n_pages)) - universe)})")
        unref = self._owned - set(self._refs)
        if unref:
            raise ValueError(f"owned pages with no refcount: "
                             f"{sorted(unref)}")
        bad = [p for p, r in self._refs.items() if r < 1]
        if bad:
            raise ValueError(f"refcount < 1 on owned pages: {sorted(bad)}")
        ghost = set(self._refs) - self._owned
        if ghost:
            raise ValueError(f"refcounts on pages not handed out: "
                             f"{sorted(ghost)}")
        return True

    def to_state(self) -> Dict[str, Any]:
        """JSON-serializable snapshot of the pool partition.  The free
        LIST (not set) is captured in order: ``alloc`` pops from the
        end, so reproducing the exact order is what makes page
        assignment — and therefore block tables — deterministic across
        a snapshot/restore cycle."""
        return {"n_pages": self.n_pages,
                "free": list(self._free),
                "refs": [[int(p), int(r)]
                         for p, r in sorted(self._refs.items())]}

    def load_state(self, state: Dict[str, Any]) -> None:
        """Restore a ``to_state`` snapshot (validates the partition)."""
        if int(state["n_pages"]) != self.n_pages:
            raise ValueError(
                f"allocator snapshot covers {state['n_pages']} pages "
                f"but this pool has {self.n_pages}")
        self._free = [int(p) for p in state["free"]]
        self._refs = {int(p): int(r) for p, r in state["refs"]}
        self._owned = set(self._refs)
        self.check()


# ----------------------------------------------------------------------
# copy-on-write page fork
# ----------------------------------------------------------------------

def fork_page(cfg, cache, src, dst):
    """Device-side page fork: copy physical page ``src`` onto ``dst``
    across every pool leaf — including the int8 scale sidecar rows,
    which are part of page identity (a forked page must dequantize
    exactly like its original until the divergent write lands).

    ``src``/``dst`` may be traced int32 scalars, so one jitted copy
    serves every (src, dst) pair.  Every leaf of the dense/moe paged
    cache carries the page dim at axis 1 (pools ``(L, n_pages, ps,
    ...)``, sidecars ``(L, n_pages[, KV])``); the audio family's
    slot-dense cross cache breaks that contract and is rejected.
    """
    check_family(cfg)
    if cfg.family == "audio":
        raise ValueError(
            "fork_page does not support the audio family: the "
            "slot-dense cross cache carries slots, not pages, at "
            "axis 1")
    return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]),
                        cache)
