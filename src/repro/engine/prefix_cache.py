"""Prefix-sharing radix cache over the page pool.

Production serving traffic is millions of users hitting a handful of
system prompts — cross-request reuse the paged scheduler used to throw
away by prefilling every prompt into private pages.  This module turns
that reuse into *confined, already-resident* pages, the cross-request
analogue of the paper's intra-kernel reuse hierarchy: a radix/trie
index over prompt token ids whose nodes own refcounted physical pages
(the signature sglang idea).  Block-table indirection already makes
page aliasing free at the kernel level, so a cache hit is just table
contents: admission aliases the matched page ids into the slot's row
and prefills only the suffix.

Granularity is the page.  A trie edge/node is one ``page_size``-token
key owning exactly one physical page of KV; only WHOLE pages are ever
shared — a prompt's partial tail page is always private (its page is
filled by the suffix prefill and never inserted), which is what makes
copy-on-write structurally unreachable on the scheduler's own decode
path: every write page (partial tail or the fresh growth page) is
private by construction.  The allocator-level CoW fork
(``paged_cache.fork_page``) still guards the invariant defensively.

Matching is additionally capped at ``len(tokens) - 1`` tokens so the
suffix is never empty: the engine convention takes the first generated
token from the prefill logits, so at least the last prompt token must
run through the (suffix) prefill.

Ownership protocol (the refcount partition the property tests pin):

  * the trie holds ONE allocator ref per node, taken at ``insert``;
  * a slot holds one ref per page in its block-table row (``alloc`` for
    private pages, ``incref`` of the matched pages at admission);
  * ``evict`` only ever releases nodes whose page has no other holder
    (refcount == 1, i.e. trie-only), LRU-first over leaves, cascading
    upward as children disappear — eviction can never drop a page a
    live slot still reads.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.engine.paged_cache import PageAllocator


class _Node:
    """One whole-page trie node: ``key`` is the page's page_size-token
    tuple, ``page`` the physical page id it owns (one trie ref)."""
    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], page: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_used = 0


class PrefixCache:
    """Radix index over prompt token ids, page-granular.

    The cache does not own device memory — it owns *refs* on pages of
    the scheduler's pool through the shared ``PageAllocator``.  All
    state is host-side; the device only ever sees block tables that
    happen to alias the same page ids."""

    def __init__(self, page_size: int, allocator: PageAllocator):
        self.page_size = int(page_size)
        self.allocator = allocator
        self._root = _Node((), None, None)
        self._clock = 0
        self._n_nodes = 0
        self.stats = {"hits": 0, "misses": 0, "hit_tokens": 0,
                      "insertions": 0, "evictions": 0}

    # ------------------------------------------------------------------

    @property
    def cached_pages(self) -> int:
        """Pages currently held (one per node)."""
        return self._n_nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _keys(self, tokens: Sequence[int], n_pages: int):
        ps = self.page_size
        toks = [int(t) for t in tokens]
        for j in range(n_pages):
            yield tuple(toks[j * ps:(j + 1) * ps])

    # ------------------------------------------------------------------

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached whole-page prefix of ``tokens``.

        Returns the matched physical page ids in prefix order (possibly
        empty).  The match is capped at ``len(tokens) - 1`` tokens so at
        least one suffix token always remains to prefill (its logits
        produce the first generated token).  The caller must ``incref``
        the returned pages before relying on them — a bare match holds
        nothing.
        """
        cap = max(0, (len(tokens) - 1) // self.page_size)
        node = self._root
        pages: List[int] = []
        t = self._tick()
        for key in self._keys(tokens, cap):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = t
            pages.append(child.page)
            node = child
        if pages:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += len(pages) * self.page_size
        else:
            self.stats["misses"] += 1
        return pages

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Index the whole pages of ``tokens``, whose KV lives in
        ``pages`` (the owning slot's block-table row, prefix order).

        Each NEW node takes one allocator ref on its page; a node that
        already exists keeps its canonical page (the caller's duplicate
        stays the slot's private copy — dedup never rewrites tables).
        Returns the number of nodes created."""
        n_whole = len(tokens) // self.page_size
        if n_whole > len(pages):
            raise ValueError(
                f"insert of {n_whole} whole pages but only "
                f"{len(pages)} page ids supplied")
        node = self._root
        t = self._tick()
        created = 0
        for j, key in enumerate(self._keys(tokens, n_whole)):
            child = node.children.get(key)
            if child is None:
                page = int(pages[j])
                self.allocator.incref([page])
                child = _Node(key, page, node)
                node.children[key] = child
                self._n_nodes += 1
                created += 1
                self.stats["insertions"] += 1
            child.last_used = t
            node = child
        return created

    # ------------------------------------------------------------------

    def _evictable_leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif self.allocator.refcount(nd.page) == 1:
                out.append(nd)
        return out

    def evict(self, n: int) -> int:
        """Release up to ``n`` pages back to the pool, LRU-first over
        leaves whose page has no holder besides the trie (refcount 1).
        Dropping a leaf may expose its parent as the next candidate
        (cascading), so eviction frees arbitrarily deep cold branches.
        Returns the number of pages actually freed — 0 means every
        cached page is still pinned by a live slot."""
        freed = 0
        while freed < n:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            self.allocator.decref([victim.page])
            del victim.parent.children[victim.key]
            self._n_nodes -= 1
            freed += 1
            self.stats["evictions"] += 1
        return freed

    def clear(self) -> int:
        """Drop every node (decref all held pages).  Returns the number
        of pages released to refcount 0."""
        released = 0
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if self.allocator.refcount(nd.page) == 1:
                released += 1
            self.allocator.decref([nd.page])
            self._n_nodes -= 1
        self._root.children = {}
        return released

    # ------------------------------------------------------------------

    def to_state(self) -> Dict[str, Any]:
        """JSON-serializable trie snapshot: nodes in parent-before-
        child order, each carrying its page_size-token key, physical
        page id, LRU stamp and parent index (-1 = root), plus the LRU
        clock and counters.  Page *refs* are NOT part of this state —
        the trie's one-ref-per-node ownership lives in the allocator,
        whose partition is snapshotted separately."""
        nodes: List[Dict[str, Any]] = []
        stack: List[Tuple[_Node, int]] = [
            (c, -1) for c in self._root.children.values()]
        while stack:
            nd, pidx = stack.pop()
            nodes.append({"key": [int(t) for t in nd.key],
                          "page": int(nd.page),
                          "last_used": int(nd.last_used),
                          "parent": pidx})
            idx = len(nodes) - 1
            stack.extend((c, idx) for c in nd.children.values())
        return {"nodes": nodes, "clock": int(self._clock),
                "stats": dict(self.stats)}

    def load_state(self, state: Dict[str, Any]) -> None:
        """Rebuild the trie from a ``to_state`` snapshot WITHOUT
        touching the allocator (the restored allocator partition
        already carries the trie's refs — increfing again would leak
        every cached page)."""
        self._root = _Node((), None, None)
        built: List[_Node] = []
        for rec in state["nodes"]:
            parent = (self._root if rec["parent"] < 0
                      else built[rec["parent"]])
            node = _Node(tuple(int(t) for t in rec["key"]),
                         int(rec["page"]), parent)
            node.last_used = int(rec["last_used"])
            parent.children[node.key] = node
            built.append(node)
        self._n_nodes = len(built)
        self._clock = int(state["clock"])
        self.stats.update(state.get("stats", {}))
        self.check()

    def check(self) -> bool:
        """Structural invariants: node count matches the tree, every
        node's page is handed out with refcount >= 1 (the trie's own
        ref must be live).  Raises ``ValueError`` on violation."""
        seen = 0
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            seen += 1
            stack.extend(nd.children.values())
            if len(nd.key) != self.page_size:
                raise ValueError(
                    f"node key width {len(nd.key)} != page_size "
                    f"{self.page_size}")
            if self.allocator.refcount(nd.page) < 1:
                raise ValueError(
                    f"trie node holds page {nd.page} with refcount "
                    f"{self.allocator.refcount(nd.page)}")
        if seen != self._n_nodes:
            raise ValueError(f"node count drift: walked {seen}, "
                             f"tracked {self._n_nodes}")
        return True
