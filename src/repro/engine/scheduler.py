"""Continuous batching: request-level serving on the paged DecodeEngine.

The dense ``engine.generate`` admits a whole batch at once and holds
every slot until the longest request finishes — at serving scale most
of the cache and most of the step budget is spent on retired or
not-yet-started requests.  This scheduler runs the engine's jitted
paged decode step as a *slot machine* instead:

  admit    a pending request takes a free slot: its prompt is prefilled
           alone (batch-1 prefill, one jit cache per prompt length) and
           scattered into freshly allocated pages — survivors in other
           slots are untouched (no re-prefill, no cache copy);
  step     ONE decode step advances every active slot through the
           shared jitted step (per-slot lengths + block tables);
           inactive slots ride along masked;
  grow     a slot crossing a page boundary gets one more page from the
           allocator — a request's footprint is ceil(len/page_size)
           pages, never the engine-wide max_len budget;
  preempt  when growth finds the pool dry, the latest-admitted slot is
           evicted back to the pending queue (pages freed now, prompt +
           generated prefix teacher-forced back in at re-admission) —
           an oversubscribed pool degrades to less concurrency instead
           of killing the stream;
  retire   a finished request frees its pages and its slot immediately;
           the next pending request is admitted on the following
           ``admit()`` — short requests stop paying for long ones.

With ``chunked_prefill`` (EngineConfig or the constructor knob),
admission becomes "grant pages + enqueue chunks": a prompt takes a
free slot and ALL its pages immediately but prefills ``chunk_tokens``
tokens at a time INSIDE the shared step (``steps.build_mixed_step``
runs one prompt chunk + the whole decode batch in a single jitted
call), packed by a token-budget rule (``pack_chunk``) that always
runs every decoding slot and fits the chunk into what budget remains
— one long prompt no longer stalls decode (the head-of-line latency
cliff of batch-1 admission).

Request lifecycle (fault tolerance).  Every request walks a status
machine (PREFILLING appears only with chunked prefill; whole-prompt
admission goes straight to RUNNING)::

    PENDING -> [PREFILLING ->] RUNNING -> FINISHED
       |          |               |-> PREEMPTED -> (again)
       |          |               |     (chunked: in-flight chunks
       |          |               |      dropped, completed pages kept)
       |          |               |-> FAILED / TIMED_OUT / CANCELLED
       |          `-> FAILED / TIMED_OUT / CANCELLED
       |-> REJECTED               (over budget, pool can never fit it)
       |-> CANCELLED / TIMED_OUT  (while still queued)

and every terminal state lands in ``finished`` as a ``RequestResult``
— an int32 token array (so existing callers index/compare it exactly
as before) carrying ``status`` / ``error`` / ``latency_s``.  Faults
are contained per-request: a malformed request is REJECTED instead of
raising away the stream, a slot whose logits go NaN/inf is
quarantined (FAILED) while the other slots' token streams stay
bit-identical, a transient step exception is retried with bounded
backoff (``runtime.resilience.RetryPolicy``), and a slot preempted
more than ``max_preemptions`` times is *parked* — kept out of
admission until the pool quiets down — instead of thrashing the
admit→preempt loop.  ``runtime.resilience.StragglerMonitor`` /
``Heartbeat`` can ride the step loop for slow-step flagging and
external hang detection.  Deterministic fault injectors for all of
this live in ``engine.faults``.

Token streams are bit-identical to a solo ``engine.generate`` run of
the same request (first token = argmax of the prefill logits; sampled
step i uses ``fold_in(PRNGKey(seed), i)``), which the paged-vs-dense
tests pin — except across a preemption, where the re-prefilled prefix
reproduces the decode-written cache only to fp rounding (a near-tie
argmax can flip, the usual recompute-preemption caveat).

All bookkeeping (free slots, free pages, per-slot lengths, block
tables) is host-side numpy; the device only ever sees the batch arrays
of the current step.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.faults import CrashError
from repro.engine.paged_cache import (PageAllocator, PagePoolExhausted,
                                      bucket_table_width, fork_page,
                                      write_prefill)
from repro.engine.prefix_cache import PrefixCache
from repro.runtime.resilience import (Heartbeat, RetryPolicy,
                                      StragglerMonitor, call_with_retries,
                                      percentiles)


class RequestStatus(str, enum.Enum):
    """Request lifecycle states (terminal: FINISHED / REJECTED /
    FAILED / CANCELLED / TIMED_OUT)."""
    PENDING = "PENDING"
    PREFILLING = "PREFILLING"   # chunked prefill in flight
    RUNNING = "RUNNING"
    PREEMPTED = "PREEMPTED"
    FINISHED = "FINISHED"
    REJECTED = "REJECTED"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"
    TIMED_OUT = "TIMED_OUT"


TERMINAL_STATES = frozenset({
    RequestStatus.FINISHED, RequestStatus.REJECTED, RequestStatus.FAILED,
    RequestStatus.CANCELLED, RequestStatus.TIMED_OUT})


class RequestResult(np.ndarray):
    """The tokens of a terminal request, plus how it ended.

    An int32 ndarray view, so every pre-lifecycle caller keeps working
    (``len(result)``, ``result[:k]``, ``assert_array_equal``), with
    ``status`` (RequestStatus), ``error`` (reason string for
    non-FINISHED terminals), ``latency_s`` (submit -> terminal wall
    time) and ``token_times`` (monotonic wall timestamp per emitted
    token, ITL = np.diff of it) riding along."""

    def __new__(cls, tokens, status: RequestStatus,
                error: Optional[str] = None,
                latency_s: Optional[float] = None,
                token_times: Optional[List[float]] = None):
        obj = np.asarray(tokens, np.int32).view(cls)
        obj.status = status
        obj.error = error
        obj.latency_s = latency_s
        obj.token_times = token_times
        return obj

    def __array_finalize__(self, obj):
        if obj is None:
            return
        self.status = getattr(obj, "status", None)
        self.error = getattr(obj, "error", None)
        self.latency_s = getattr(obj, "latency_s", None)
        self.token_times = getattr(obj, "token_times", None)

    @property
    def tokens(self) -> np.ndarray:
        return np.asarray(self)

    @property
    def ok(self) -> bool:
        return self.status is RequestStatus.FINISHED

    def __repr__(self):
        st = getattr(self, "status", None)
        err = getattr(self, "error", None)
        return (f"RequestResult({np.asarray(self).tolist()}, "
                f"status={getattr(st, 'value', st)}"
                + (f", error={err!r}" if err else "") + ")")


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is the (P,) int32 prompt;
    ``gen`` counts generated tokens (prefill argmax included);
    ``frontend_emb`` feeds the vlm/audio modality frontends.

    ``deadline_s`` (wall seconds from ``submit()``) and ``max_steps``
    (decode steps) bound the request; crossing either ends it
    TIMED_OUT with the tokens generated so far.  ``status`` / ``error``
    are scheduler-owned lifecycle fields."""
    rid: Any
    tokens: np.ndarray
    gen: int
    temperature: float = 0.0
    seed: int = 0
    frontend_emb: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None
    max_steps: Optional[int] = None
    status: RequestStatus = RequestStatus.PENDING
    error: Optional[str] = None
    submit_t: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int                     # valid cache positions
    pages: List[int]                # physical pages owned
    out: List[int]                  # generated tokens so far
    steps: int = 0                  # decode steps taken (RNG fold_in)
    order: int = 0                  # admission sequence (LIFO preempt)
    preempts: int = 0               # times evicted (livelock watchdog)
    prefilled: int = 0              # chunked: prompt positions resident
    token_times: List[float] = dataclasses.field(default_factory=list)


def pack_chunk(remaining: int, n_decode: int, budget: int,
               chunk_tokens: int, page_size: int) -> int:
    """Token-budget packing rule for one mixed step: how many prompt
    tokens of the head in-flight prefill ride along with ``n_decode``
    decoding slots under a ``budget``-token step.

    Decode is never starved: every decoding slot always runs (the
    chunk takes only ``budget - n_decode`` tokens, down to zero), and
    the chunk never exceeds ``chunk_tokens``.  A non-final chunk is
    floored to a whole-page multiple so the NEXT chunk's resident
    prefix is whole pages (exactly the suffix-prefill contract); the
    final chunk takes ``remaining`` exactly, page-aligned or not.
    Returns 0 when no chunk fits this step."""
    room = min(budget - n_decode, chunk_tokens)
    if room <= 0:
        return 0
    if room >= remaining:
        return remaining            # final chunk (may be unaligned)
    return (room // page_size) * page_size


class Scheduler:
    """Admit / step / retire requests over a paged ``DecodeEngine``.

    ``enc_len`` budgets the audio cross-attention cache (frames per
    slot); it defaults to the engine's decoder ``max_len``, which is
    usually too SHORT for speech — encoder frame counts routinely
    exceed the decoder token budget, so audio streams should size it
    to the longest expected ``frontend_emb``.

    ``bucket_tables`` (default on) slices the block table each step to
    the power-of-two width bucket covering the longest active slot's
    live page count (``paged_cache.bucket_table_width``), so a step
    stages only live pages instead of ``max_pages`` columns; the
    jitted step compiles once per bucket (at most log2(max_pages)+1
    shapes).  Admission / growth / retirement semantics and the token
    streams are identical either way — only the staged table width
    changes.

    Fault-tolerance knobs:

    ``retry``            RetryPolicy for transient prefill/decode step
                         exceptions (bounded, linear backoff; the last
                         exception re-raises once spent).
    ``max_preemptions``  a slot evicted more than this many times is
                         parked (kept out of admission until the pool
                         quiets) instead of thrashing admit→preempt.
    ``guard_nonfinite``  batched isfinite guard on the step logits:
                         a slot producing NaN/inf is quarantined
                         (FAILED) alone; survivors are untouched.
    ``straggler`` / ``heartbeat``  optional
                         ``runtime.resilience`` monitors wired into
                         every ``step()``.

    ``prefix_cache`` (None = inherit ``EngineConfig.prefix_cache``)
    turns on prompt-prefix sharing (``engine.prefix_cache``):
    admission matches the longest cached whole-page prefix, increfs
    and aliases those pages into the slot's block table, and prefills
    only the suffix; retire/preempt decref instead of free, and when
    an allocation would exhaust the pool, refcount-1 LRU trie leaves
    are evicted BEFORE any slot is preempted.  Greedy token streams
    are bit-identical to the cache-off scheduler for model-dtype
    pools (the suffix prefill reads exactly the KV blocks the cold
    prefill would recompute).  int8 pools dequantize the prefix
    through the same per-page scales decode reads, but a HIT's suffix
    prefill sees the quantized prefix where a cold prefill saw full
    precision, so a near-tie argmax in the hit's own stream can flip
    — miss streams (and every decode step) are unaffected.

    ``chunked_prefill`` / ``chunk_tokens`` / ``token_budget`` (None =
    inherit the first two from EngineConfig; budget defaults to
    ``batch + chunk_tokens``) turn on chunked admission: prompts
    prefill ``chunk_tokens`` at a time inside the shared mixed step
    (see the module docstring), packed under ``token_budget`` by
    ``pack_chunk`` so decode slots are never starved.  Greedy token
    streams stay bit-identical to the non-chunked scheduler for
    model-dtype pools (each chunk is suffix-prefill math over the same
    kv block boundaries as the whole prefill; extra fully-masked kv
    blocks are exact no-ops for online softmax); int8 pools carry the
    same near-tie caveat as a prefix-cache hit, since chunks after the
    first read the earlier chunks' KV through the quantized pages.
    """

    def __init__(self, engine, enc_len: Optional[int] = None,
                 bucket_tables: bool = True,
                 retry: Optional[RetryPolicy] = None,
                 max_preemptions: int = 3,
                 guard_nonfinite: bool = True,
                 straggler: Optional[StragglerMonitor] = None,
                 heartbeat: Optional[Heartbeat] = None,
                 prefix_cache: Optional[bool] = None,
                 chunked_prefill: Optional[bool] = None,
                 chunk_tokens: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 journal=None, snapshotter=None):
        if not engine.ecfg.paged:
            raise ValueError(
                "Scheduler needs a paged engine: EngineConfig("
                "paged=True, page_size=..., n_pages=...)")
        self.eng = engine
        self.cfg = engine.cfg
        B, J = engine.ecfg.batch, engine.max_pages
        self.page_size = engine.page_size
        self.allocator = PageAllocator(engine.n_pages)
        # durability hooks (engine.journal / engine.snapshot): every
        # submit/cancel/terminal is write-ahead logged, and the
        # snapshotter cuts the full serving state every N steps off
        # the step path
        self.journal = journal
        self.snapshotter = snapshotter
        self.slots: List[Optional[_Slot]] = [None] * B
        self.table = np.zeros((B, J), np.int32)
        self.lens = np.zeros((B,), np.int32)
        self.tokens = np.zeros((B,), np.int32)
        self.enc_lens = np.zeros((B,), np.int32)
        self.cache = engine.init_paged_cache(enc_len=enc_len)
        self.enc_budget = (self.cache["cross_k"].shape[2]
                           if self.cfg.family == "audio" else 0)
        self.bucket_tables = bucket_tables
        # default policy: transient step faults retry, a simulated
        # process death (CrashError) surfaces immediately — a crash is
        # the restart loop's problem, not the step retry's
        self.retry = retry if retry is not None else RetryPolicy(
            fatal=(CrashError,))
        self.max_preemptions = max_preemptions
        self.guard_nonfinite = guard_nonfinite
        self.straggler = straggler
        self.heartbeat = heartbeat
        self.pending: deque = deque()   # Request | preempted _Slot
        self.parked: deque = deque()    # watchdog-parked _Slots
        self.finished: Dict[Any, RequestResult] = {}
        if prefix_cache is None:
            prefix_cache = engine.ecfg.prefix_cache
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if self.cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"prefix_cache supports the token-only families "
                    f"('dense', 'moe'); family {self.cfg.family!r} "
                    "prepends frontend positions a token-keyed prefix "
                    "index cannot match")
            if engine.suffix_prefill_fn is None:
                raise ValueError("engine has no suffix_prefill_fn — "
                                 "construct a paged dense/moe engine")
            self.prefix = PrefixCache(self.page_size, self.allocator)
        if chunked_prefill is None:
            chunked_prefill = engine.ecfg.chunked_prefill
        self.chunked = bool(chunked_prefill)
        self.chunk_tokens = 0
        self.token_budget = 0
        if self.chunked:
            if self.cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"chunked_prefill supports the token-only families "
                    f"('dense', 'moe'); got family {self.cfg.family!r}")
            if getattr(engine, "mixed_fn", None) is None:
                raise ValueError("engine has no mixed_fn — construct "
                                 "a paged dense/moe engine")
            ct = (chunk_tokens if chunk_tokens is not None
                  else engine.ecfg.chunk_tokens)
            if ct < 1 or ct % self.page_size:
                raise ValueError(
                    f"chunk_tokens must be a positive multiple of "
                    f"page_size {self.page_size}; got {ct} (a non-final "
                    "chunk must end page-aligned so the next chunk's "
                    "prefix is whole pages)")
            self.chunk_tokens = ct
            # default budget: every slot decodes AND a full chunk fits
            self.token_budget = (token_budget if token_budget is not None
                                 else B + ct)
            if self.token_budget < 1:
                raise ValueError("token_budget must be >= 1")
        self._prefilling: deque = deque()   # slot ids, chunking order
        self.stats = {"prefills": 0, "admitted": 0, "retired": 0,
                      "steps": 0, "peak_pages": 0, "preempted": 0,
                      "table_widths": {},   # width -> steps at it
                      "rejected": 0, "failed": 0, "cancelled": 0,
                      "timed_out": 0, "step_retries": 0,
                      "prefill_retries": 0, "parked": 0,
                      "straggler_flags": 0,
                      # prefix-cache counters (zero when it's off)
                      "prefix_hits": 0, "prefix_misses": 0,
                      "prefix_hit_tokens": 0, "prefix_evictions": 0,
                      "shared_pages": 0,     # peak pages refcount > 1
                      "cow_forks": 0,
                      # chunked-prefill counters (zero when it's off)
                      "mixed_steps": 0, "chunks": 0,
                      "chunked_tokens": 0}
        self._latencies: List[float] = []
        self._itl: List[float] = []     # inter-token latency samples
        self._order = 0
        # jitted prefill->pages scatter with the pool DONATED (where
        # the backend supports donation): the eager .at[].set would
        # copy every full pool leaf per admission
        self._write_prefill = jax.jit(
            lambda cache, caches, table, slots: write_prefill(
                self.cfg, cache, caches, table,
                enc_caches_slots=slots),
            donate_argnums=(() if jax.default_backend() == "cpu"
                            else (0,)))
        # jitted copy-on-write page fork (src/dst ride as traced
        # scalars: one compile serves every pair); donation for the
        # same reason as _write_prefill
        self._fork_page = jax.jit(
            lambda cache, src, dst: fork_page(self.cfg, cache, src, dst),
            donate_argnums=(() if jax.default_backend() == "cpu"
                            else (0,)))
        # one jitted pick for the whole batch: greedy argmax, per-slot
        # fold_in-keyed categorical, and the isfinite guard, packed
        # into a single (3, B) int32 array -> ONE device->host transfer
        # per step (the per-slot categorical used to sync once per
        # sampled slot)
        self._pick_fn = jax.jit(self._pick)

    @staticmethod
    def _pick(logits, seeds, steps, temps):
        keys = jax.vmap(lambda s, i: jax.random.fold_in(
            jax.random.PRNGKey(s), i))(seeds, steps)
        safe_t = jnp.where(temps > 0, temps, 1.0)
        sampled = jax.vmap(lambda k, l, t: jax.random.categorical(
            k, l / t))(keys, logits, safe_t)
        greedy = jnp.argmax(logits, -1)
        finite = jnp.all(jnp.isfinite(logits), -1)
        return jnp.stack([greedy.astype(jnp.int32),
                          sampled.astype(jnp.int32),
                          finite.astype(jnp.int32)])

    # ------------------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, req: Request) -> None:
        req.status = RequestStatus.PENDING
        req.submit_t = time.monotonic()
        if self.journal is not None:
            # write-ahead: the submit is on disk (fsynced) before the
            # scheduler can act on it — an acknowledged request
            # survives a crash even if no snapshot ever sees it
            self.journal.submit(req)
        self.pending.append(req)

    def results(self) -> Dict[Any, RequestResult]:
        return dict(self.finished)

    def latency_percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        """Submit -> terminal wall-latency percentiles over every
        terminal request so far."""
        return percentiles(self._latencies, qs)

    def itl_percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        """Inter-token-latency percentiles (seconds between consecutive
        emitted tokens, per request) aggregated over every terminal
        request so far — the tail (p99) is what a long prompt's
        monopolized prefill inflates, and what chunked prefill pins."""
        return percentiles(self._itl, qs)

    # ------------------------------------------------------------------
    # terminal transitions
    # ------------------------------------------------------------------

    def _terminal(self, req: Request, tokens, status: RequestStatus,
                  error: Optional[str] = None, *,
                  token_times: Optional[List[float]] = None
                  ) -> RequestResult:
        lat = (time.monotonic() - req.submit_t
               if req.submit_t is not None else None)
        req.status = status
        req.error = error
        res = RequestResult(np.asarray(list(tokens), np.int32), status,
                            error=error, latency_s=lat,
                            token_times=(list(token_times)
                                         if token_times else None))
        self.finished[req.rid] = res
        if self.journal is not None:
            self.journal.terminal(req.rid, res)
        if lat is not None:
            self._latencies.append(lat)
        if token_times and len(token_times) > 1:
            self._itl.extend(
                np.diff(np.asarray(token_times, np.float64)).tolist())
        key = {RequestStatus.FINISHED: "retired",
               RequestStatus.REJECTED: "rejected",
               RequestStatus.FAILED: "failed",
               RequestStatus.CANCELLED: "cancelled",
               RequestStatus.TIMED_OUT: "timed_out"}[status]
        self.stats[key] += 1
        return res

    def _evict(self, slot_id: int) -> _Slot:
        """Release a slot's pages + batch-row state (no terminal
        record).  Pages are DECREF'd, not freed: with the prefix cache
        on, a slot's row may alias pages the trie (or another slot)
        still holds — the old unconditional ``free`` double-freed
        exactly those, pulling live prefixes out from under survivors."""
        slot = self.slots[slot_id]
        if slot.pages:
            self.allocator.decref(slot.pages)
            slot.pages = []
        slot.prefilled = 0
        if slot_id in self._prefilling:
            self._prefilling.remove(slot_id)
        self.slots[slot_id] = None
        self.lens[slot_id] = 0
        self.tokens[slot_id] = 0
        self.enc_lens[slot_id] = 0
        return slot

    def _retire(self, slot_id: int) -> None:
        slot = self.slots[slot_id]
        if self.prefix is not None:
            # index the retiring request's whole pages — prompt AND
            # generated tokens (multi-turn reuse: a follow-up prompt
            # that extends this conversation hits the whole history).
            # The cache holds positions [0, length) = prompt + out[:-1]
            # (the last picked token's KV is written by the step that
            # never came).
            toks = np.concatenate([
                np.asarray(slot.req.tokens, np.int32),
                np.asarray(slot.out[:-1], np.int32)])
            self.prefix.insert(toks, slot.pages)
        slot = self._evict(slot_id)
        self._terminal(slot.req, slot.out, RequestStatus.FINISHED,
                       token_times=slot.token_times)

    def _fail_slot(self, slot_id: int, reason: str) -> None:
        slot = self._evict(slot_id)
        self._terminal(slot.req, slot.out, RequestStatus.FAILED, reason,
                       token_times=slot.token_times)

    def _preempt(self, slot_id: int) -> None:
        """Evict an active slot back to the FRONT of the pending queue
        (vLLM-style recompute preemption): its pages free immediately
        and its prompt + generated prefix is teacher-forced back in at
        re-admission, so no tokens are lost — only the prefix compute
        is redone.  A slot past ``max_preemptions`` is parked instead:
        re-admitting it just feeds the same thrash, so it waits out the
        pool pressure (re-admitted when nothing else is runnable).

        A PREFILLING slot (chunked prefill in flight) drops only its
        in-flight chunk: the whole pages its completed chunks already
        wrote stay WITH the slot across the queue, so re-admission
        grants the missing tail and resumes chunking where it left off
        instead of re-prefilling from scratch.  ``prefilled`` is always
        page-aligned while PREFILLING (non-final chunks end on page
        boundaries), so the kept prefix is exactly whole pages — and at
        least one tail page frees (the grant covers the next unwritten
        position), so pool-pressure preemption still makes progress."""
        slot = self.slots[slot_id]
        if slot.req.status is RequestStatus.PREFILLING:
            keep = slot.prefilled // self.page_size
            tail = slot.pages[keep:]
            if tail:
                self.allocator.decref(tail)
            slot.pages = slot.pages[:keep]
            if slot_id in self._prefilling:
                self._prefilling.remove(slot_id)
            self.slots[slot_id] = None
            self.lens[slot_id] = 0
            self.tokens[slot_id] = 0
            self.enc_lens[slot_id] = 0
        else:
            slot = self._evict(slot_id)
        slot.preempts += 1
        slot.req.status = RequestStatus.PREEMPTED
        if slot.preempts > self.max_preemptions:
            self.parked.append(slot)
            self.stats["parked"] += 1
        else:
            self.pending.appendleft(slot)
        self.stats["preempted"] += 1

    def cancel(self, rid: Any) -> bool:
        """Cancel a request wherever it is: mid-flight (slot + pages
        freed immediately, partial tokens attached), pending, or
        parked.  Returns False if ``rid`` is unknown or already
        terminal."""
        if self.journal is not None:
            # intent record — the terminal event that follows is what
            # replay treats as authoritative
            self.journal.cancel(rid)
        for slot_id, slot in enumerate(self.slots):
            if slot is not None and slot.req.rid == rid:
                slot = self._evict(slot_id)
                self._terminal(slot.req, slot.out,
                               RequestStatus.CANCELLED,
                               "cancelled mid-flight",
                               token_times=slot.token_times)
                return True
        for q, where in ((self.pending, "pending"),
                         (self.parked, "parked")):
            for item in list(q):
                req = item.req if isinstance(item, _Slot) else item
                if req.rid == rid:
                    q.remove(item)
                    self._release_queued(item)
                    toks = item.out if isinstance(item, _Slot) else []
                    self._terminal(req, toks, RequestStatus.CANCELLED,
                                   f"cancelled while {where}",
                                   token_times=getattr(
                                       item, "token_times", None))
                    return True
        return False

    def _release_queued(self, item) -> None:
        """Drop the pages a queued item still holds.  A chunk-preempted
        slot keeps its completed prefix pages across the queue (so
        re-admission resumes chunking instead of restarting); if the
        item goes terminal while queued, those pages must be released
        here or they leak."""
        if isinstance(item, _Slot) and item.pages:
            self.allocator.decref(item.pages)
            item.pages = []
            item.prefilled = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _prefill_positions(self, req: Request) -> int:
        P = len(req.tokens)
        if self.cfg.family == "vlm":
            P += self.cfg.frontend_tokens
        return P

    @staticmethod
    def _teacher_tokens(item) -> np.ndarray:
        """Every token position the admission prefill must occupy: the
        prompt, plus — for a preempted slot being re-admitted — the
        generated prefix except the last token (that one is the slot's
        pending input, written by the next step)."""
        req = item.req if isinstance(item, _Slot) else item
        tokens = np.asarray(req.tokens, np.int32)
        if isinstance(item, _Slot):
            tokens = np.concatenate(
                [tokens, np.asarray(item.out[:-1], np.int32)])
        return tokens

    def _pages_needed(self, positions: int, more_writes: bool) -> int:
        """Pages covering ``positions`` occupied slots — plus the page
        the next decode token writes to, but only when one is coming
        (a gen-exhausted request must not ask for a page beyond its
        block-table row)."""
        last = positions + 1 if more_writes else positions
        return -(-last // self.page_size)

    def _deadline_expired(self, req: Request) -> bool:
        return (req.deadline_s is not None
                and req.submit_t is not None
                and time.monotonic() - req.submit_t > req.deadline_s)

    def _validate(self, req: Request) -> Optional[str]:
        """Admission-blocking fault in ``req``, or None if admissible."""
        P = self._prefill_positions(req)
        if P + req.gen - 1 > self.eng.ecfg.max_len:
            return (f"prompt {P} + gen {req.gen} exceeds engine "
                    f"max_len {self.eng.ecfg.max_len}")
        if (self.cfg.family == "audio"
                and req.frontend_emb is not None
                and req.frontend_emb.shape[0] > self.enc_budget):
            return (f"{req.frontend_emb.shape[0]} encoder frames "
                    f"exceed the cross-cache budget {self.enc_budget} "
                    "— construct the Scheduler with enc_len >= the "
                    "longest expected frontend_emb")
        return None

    def admit(self) -> int:
        """Admit pending requests (or preempted slots) into free slots
        while pages allow.  Returns the number admitted (0 = no free
        slot, nothing pending, or the pool is momentarily too full —
        retiring slots frees pages, so admission retries on the next
        call).

        Malformed requests (over-budget prompt, encoder frames beyond
        the cross-cache budget, a single request larger than the whole
        pool) are REJECTED individually — the stream keeps serving —
        and a request whose deadline lapsed while queued ends
        TIMED_OUT here instead of wasting a prefill."""
        if (self.n_active == 0 and not self.pending and self.parked):
            # nothing else runnable: the parked slots get their turn
            while self.parked:
                self.pending.append(self.parked.popleft())
        admitted = 0
        while self.pending:
            try:
                slot_id = self.slots.index(None)
            except ValueError:
                break
            item = self.pending[0]
            req = item.req if isinstance(item, _Slot) else item
            partial = item.out if isinstance(item, _Slot) else []
            if self._deadline_expired(req):
                self.pending.popleft()
                self._release_queued(item)
                self._terminal(req, partial, RequestStatus.TIMED_OUT,
                               f"deadline_s={req.deadline_s} lapsed "
                               "while queued",
                               token_times=getattr(
                                   item, "token_times", None))
                continue
            fault = self._validate(req)
            if fault is not None:
                self.pending.popleft()
                self._release_queued(item)
                self._terminal(req, partial, RequestStatus.REJECTED,
                               fault)
                continue
            P = self._prefill_positions(req)
            # a chunk-preempted slot can be re-queued with out == []
            # (it never finished prefilling): it behaves like a fresh
            # request here — the prefill emits its first token
            done = (max(len(item.out), 1)
                    if isinstance(item, _Slot) else 1)
            positions = P + (max(len(item.out) - 1, 0)
                             if isinstance(item, _Slot) else 0)
            need = self._pages_needed(positions, done < req.gen)
            if need > self.allocator.n_pages:
                self.pending.popleft()
                self._release_queued(item)
                self._terminal(
                    req, partial, RequestStatus.REJECTED,
                    f"needs {need} pages but the pool only has "
                    f"{self.allocator.n_pages} in total — raise "
                    "EngineConfig.n_pages or page_size")
                continue
            # pages a chunk-preempted slot kept across the queue: its
            # completed prefix is already resident, so prefix matching
            # is skipped (the slot holds its own refs) and only the
            # missing tail is allocated
            held = (list(item.pages)
                    if isinstance(item, _Slot) else [])
            # prefix-cache match: alias the longest cached whole-page
            # prefix (incref'd NOW, so eviction below can't reclaim it)
            # and only allocate private pages for the suffix + growth
            matched: List[int] = []
            if self.prefix is not None and not held:
                matched = self.prefix.match(self._teacher_tokens(item))
                if matched:
                    self.allocator.incref(matched)
            resident = held or matched
            private = need - len(resident)
            if private > self.allocator.free_pages \
                    and self.prefix is not None:
                # refcount-1 LRU trie leaves go before any preemption
                # (the matched pages just took a slot ref, so eviction
                # cannot reclaim them out from under this admission)
                self.stats["prefix_evictions"] += self.prefix.evict(
                    private - self.allocator.free_pages)
            if private > self.allocator.free_pages:
                if matched:
                    self.allocator.decref(matched)
                break               # wait for a retirement
            self.pending.popleft()
            if self.prefix is not None and not held:
                if matched:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += \
                        len(matched) * self.page_size
                else:
                    self.stats["prefix_misses"] += 1
                self.stats["shared_pages"] = max(
                    self.stats["shared_pages"],
                    self.allocator.shared_pages)
            pages = resident + self.allocator.alloc(private)
            if self.chunked:
                ok = self._admit_chunked(slot_id, item, pages,
                                         n_resident=len(resident))
            else:
                ok = self._admit_into(slot_id, item, pages,
                                      n_shared=len(matched))
            if ok:
                admitted += 1
        return admitted

    def _admit_into(self, slot_id: int, item, pages: List[int],
                    n_shared: int = 0) -> bool:
        """Prefill ``item`` (a fresh Request, or a preempted _Slot whose
        prompt + generated prefix is teacher-forced back in) into the
        allocated pages of ``slot_id``.  The first ``n_shared`` pages
        are prefix-cache aliases already resident in the pool: the
        prefill runs suffix-only over the remaining tokens (attending
        to the shared pages read-only) and the scatter touches only the
        private suffix pages.  A prefill that keeps failing past the
        retry budget FAILs the request (pages decref'd) rather than the
        stream.  Returns True if the slot went active."""
        resumed = isinstance(item, _Slot)
        req = item.req if resumed else item
        tokens = self._teacher_tokens(item)
        M = n_shared * self.page_size   # cached positions (page-whole)
        batch = {"tokens": jnp.asarray(tokens[M:])[None]}
        if req.frontend_emb is not None:
            batch["frontend_emb"] = jnp.asarray(req.frontend_emb)[None]
        if n_shared:
            batch["pages"] = jnp.asarray(pages[:n_shared], jnp.int32)
            batch["cache"] = self.cache
            prefill_fn = self.eng.suffix_prefill_fn
        else:
            prefill_fn = self.eng.prefill_fn

        def _count_retry(attempt, exc):
            self.stats["prefill_retries"] += 1

        try:
            logits, caches = call_with_retries(
                prefill_fn, self.eng.params, batch,
                policy=self.retry, on_retry=_count_retry)
        except Exception as e:                      # noqa: BLE001
            self.allocator.decref(pages)
            self._terminal(req, item.out if resumed else [],
                           RequestStatus.FAILED,
                           f"prefill failed after "
                           f"{self.retry.max_retries} retries: {e}")
            return False
        self.stats["prefills"] += 1
        row = np.zeros((1, self.table.shape[1]), np.int32)
        row[0, :len(pages)] = pages
        # scatter ONLY the suffix caches into the private suffix pages:
        # the shared prefix pages already hold their KV (that is the
        # whole point of the hit) and must never be written through
        srow = np.zeros((1, self.table.shape[1]), np.int32)
        srow[0, :len(pages) - n_shared] = pages[n_shared:]
        self.cache = self._write_prefill(self.cache, caches,
                                         jnp.asarray(srow),
                                         jnp.asarray([slot_id]))
        if resumed:
            slot = _Slot(req=req, length=self._prefill_positions(req)
                         + len(item.out) - 1,
                         pages=list(pages), out=list(item.out),
                         steps=item.steps, order=self._order,
                         preempts=item.preempts,
                         token_times=list(item.token_times))
            tok = item.out[-1]
        else:
            # engine convention: the first generated token is the
            # argmax of the prefill logits; sampled steps start at
            # fold_in(key, 0)
            tok = int(jnp.argmax(logits[0]))
            slot = _Slot(req=req, length=self._prefill_positions(req),
                         pages=list(pages), out=[tok],
                         order=self._order,
                         token_times=[time.monotonic()])
        self._order += 1
        req.status = RequestStatus.RUNNING
        self.slots[slot_id] = slot
        self.table[slot_id] = row[0]
        self.lens[slot_id] = slot.length
        self.tokens[slot_id] = tok
        self.enc_lens[slot_id] = (req.frontend_emb.shape[0]
                                  if self.cfg.family == "audio"
                                  and req.frontend_emb is not None else 0)
        self.stats["admitted"] += 1
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.allocator.used_pages)
        if self.prefix is not None:
            # index the freshly prefilled whole pages NOW (not just at
            # retirement) so concurrent requests sharing this prompt
            # hit while it is still in flight; the trie increfs only
            # nodes it creates, so re-inserting a matched prefix is a
            # no-op walk
            self.prefix.insert(tokens, slot.pages)
        if len(slot.out) >= req.gen:
            self._retire(slot_id)   # gen=1: the prefill already ends it
        return True

    def _admit_chunked(self, slot_id: int, item, pages: List[int],
                       n_resident: int = 0) -> bool:
        """Grant pages + enqueue chunks — the chunked-admission
        counterpart of ``_admit_into``.  NO model call happens here:
        the slot goes PREFILLING with all ``need`` pages granted up
        front, and subsequent mixed steps prefill ``chunk_tokens`` at a
        time (``step()`` packs them under the token budget).  The first
        ``n_resident`` pages already hold KV — a prefix-cache match, or
        the completed pages a chunk-preempted slot kept — so chunking
        starts at position ``n_resident * page_size``.  The slot rides
        the decode batch inactive meanwhile (cur_len == 0: write
        dropped, attention masked, logits discarded)."""
        resumed = isinstance(item, _Slot)
        req = item.req if resumed else item
        if resumed:
            slot = item             # keep out/steps/preempts/times
        else:
            slot = _Slot(req=req, length=0, pages=[], out=[])
        slot.pages = list(pages)
        slot.prefilled = n_resident * self.page_size
        slot.length = 0
        slot.order = self._order
        self._order += 1
        req.status = RequestStatus.PREFILLING
        self.slots[slot_id] = slot
        row = np.zeros((self.table.shape[1],), np.int32)
        row[:len(pages)] = pages
        self.table[slot_id] = row
        self.lens[slot_id] = 0
        self.tokens[slot_id] = 0
        self.enc_lens[slot_id] = 0
        self._prefilling.append(slot_id)
        self.stats["admitted"] += 1
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.allocator.used_pages)
        return True

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------

    def _grow_pages(self) -> None:
        """A slot whose next write position opens a new page gets one
        more from the pool (the only mid-flight allocation).  When the
        pool is dry, refcount-1 LRU trie leaves are evicted first (a
        cached-but-unreferenced prefix is the cheapest thing to drop);
        only once the trie has nothing reclaimable is the
        LATEST-admitted active slot preempted (decref'ing its pages)
        until the allocation fits — the stream degrades to less
        concurrency instead of dying with every in-flight request
        lost.  A preempted slot's trie-held prefix pages stay resident
        (refcount 1, trie) and become evictable next iteration, so the
        loop still terminates: the final victim is the needy slot
        itself."""
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            page_idx = slot.length // self.page_size
            if page_idx < len(slot.pages):
                continue
            while self.allocator.free_pages < 1:
                if self.prefix is not None:
                    self.stats["prefix_evictions"] += \
                        self.prefix.evict(1)
                    if self.allocator.free_pages >= 1:
                        break
                victim = max(
                    (s for s, sl in enumerate(self.slots)
                     if sl is not None),
                    key=lambda s: self.slots[s].order)
                self._preempt(victim)
                if victim == slot_id:
                    break           # the needy slot itself backed off
            if self.slots[slot_id] is None:
                continue
            (page,) = self.allocator.alloc(1)
            slot.pages.append(page)
            self.table[slot_id, page_idx] = page
            self.stats["peak_pages"] = max(
                self.stats["peak_pages"], self.allocator.used_pages)

    def _cow_guard(self) -> None:
        """Copy-on-write: fork any slot's WRITE page (the page its next
        decode token lands in) that is shared (refcount > 1), so the
        write cannot corrupt another reader's prefix.  On the normal
        scheduler path this never fires — matched prefixes are
        whole-page and the partial tail / growth pages are always
        privately allocated — but external incref'ing (snapshots,
        speculative forks, tests) makes a write page shared, and this
        guard is what keeps the aliasing safe rather than silently
        corrupting."""
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.req.status is RequestStatus.PREFILLING:
                # no decode write while chunking (cur_len == 0 drops
                # it), and chunk writes only touch private suffix pages
                # — pages[0] may be a shared prefix alias, which is
                # exactly NOT a reason to fork
                continue
            wp = slot.length // self.page_size
            page = slot.pages[wp]
            if self.allocator.refcount(page) <= 1:
                continue
            if self.allocator.free_pages < 1:
                self.stats["prefix_evictions"] += self.prefix.evict(1)
            if self.allocator.free_pages < 1:
                # no page to fork into: back this slot off rather than
                # write through a shared page
                self._preempt(slot_id)
                continue
            (new,) = self.allocator.alloc(1)
            self.cache = self._fork_page(self.cache, jnp.int32(page),
                                         jnp.int32(new))
            slot.pages[wp] = new
            self.table[slot_id, wp] = new
            self.allocator.decref([page])
            self.stats["cow_forks"] += 1
            self.stats["peak_pages"] = max(
                self.stats["peak_pages"], self.allocator.used_pages)

    def _expire_deadlines(self) -> None:
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            req = slot.req
            if (req.max_steps is not None
                    and slot.steps >= req.max_steps):
                slot = self._evict(slot_id)
                self._terminal(slot.req, slot.out,
                               RequestStatus.TIMED_OUT,
                               f"max_steps={req.max_steps} reached",
                               token_times=slot.token_times)
            elif self._deadline_expired(req):
                slot = self._evict(slot_id)
                self._terminal(slot.req, slot.out,
                               RequestStatus.TIMED_OUT,
                               f"deadline_s={req.deadline_s} lapsed",
                               token_times=slot.token_times)

    def _run_decode(self, dbatch):
        def _count_retry(attempt, exc):
            self.stats["step_retries"] += 1
        # the jitted step is functional (the new cache is returned, the
        # old one untouched), so re-running it after a transient fault
        # is safe — nothing was mutated
        return call_with_retries(self.eng.decode_fn, self.eng.params,
                                 dbatch, policy=self.retry,
                                 on_retry=_count_retry)

    def _run_mixed(self, mbatch):
        def _count_retry(attempt, exc):
            self.stats["step_retries"] += 1
        # functional like the decode step: a transient-fault retry
        # re-runs only the CURRENT chunk + decode step against the
        # untouched previous cache — completed chunks stay resident
        return call_with_retries(self.eng.mixed_fn, self.eng.params,
                                 mbatch, policy=self.retry,
                                 on_retry=_count_retry)

    def _pack_chunk_for_step(self, n_decode: int):
        """(slot_id, C) for the head in-flight prefill's next chunk
        under the token budget, or (None, 0) when nothing chunks this
        step."""
        if not (self.chunked and self._prefilling):
            return None, 0
        sid = self._prefilling[0]
        slot = self.slots[sid]
        remaining = len(self._teacher_tokens(slot)) - slot.prefilled
        C = pack_chunk(remaining, n_decode, self.token_budget,
                       self.chunk_tokens, self.page_size)
        return (sid, C) if C > 0 else (None, 0)

    def _promote(self, slot_id: int, chunk_logits) -> None:
        """Final chunk done: the slot leaves PREFILLING and joins the
        decode batch next step.  Mirrors the end of ``_admit_into``:
        first token = argmax of the (final-chunk) prefill logits for a
        fresh request, the pending generated token for a resumed one;
        the whole prefilled prefix is indexed into the prefix trie."""
        slot = self.slots[slot_id]
        req = slot.req
        if self.guard_nonfinite and \
                not bool(jnp.all(jnp.isfinite(chunk_logits))):
            self._fail_slot(slot_id, "non-finite logits in chunked "
                            "prefill (final chunk)")
            return
        self.stats["prefills"] += 1
        if slot.out:
            tok = slot.out[-1]
            slot.length = (self._prefill_positions(req)
                           + len(slot.out) - 1)
        else:
            tok = int(jnp.argmax(chunk_logits[0]))
            slot.out = [tok]
            slot.length = self._prefill_positions(req)
            slot.token_times.append(time.monotonic())
        req.status = RequestStatus.RUNNING
        self.lens[slot_id] = slot.length
        self.tokens[slot_id] = tok
        if self.prefix is not None:
            self.prefix.insert(self._teacher_tokens(slot), slot.pages)
        if len(slot.out) >= req.gen:
            self._retire(slot_id)   # gen=1: the prefill already ends it

    def step(self) -> None:
        """One decode step for every RUNNING slot — plus, in chunked
        mode, up to ``chunk_tokens`` of the head in-flight prompt
        packed into the SAME jitted call (``engine.mixed_fn``) under
        the token budget — then retirement.

        Fault handling per step: deadlines expire first (TIMED_OUT with
        partial tokens), a transient step exception is retried up to
        ``retry.max_retries`` times (a mixed-step retry redoes only the
        current chunk — earlier chunks are already resident), and —
        with ``guard_nonfinite`` — any slot whose logits contain
        NaN/inf is quarantined (FAILED) alone while every other slot's
        stream is untouched (a PREFILLING slot is guarded at its final
        chunk, where its logits first matter)."""
        if self.n_active == 0:
            return
        self._expire_deadlines()
        if self.n_active == 0:
            return
        self._grow_pages()
        if self.n_active == 0:      # growth preempted everything
            return
        if self.prefix is not None:
            self._cow_guard()
            if self.n_active == 0:
                return
        # snapshot who decodes THIS step: PREFILLING slots ride the
        # batch masked (cur_len == 0), and a slot promoted after the
        # mixed call must not consume this step's (garbage) logits row
        was_running = [sid for sid, s in enumerate(self.slots)
                       if s is not None
                       and s.req.status is not RequestStatus.PREFILLING]
        c_slot, C = self._pack_chunk_for_step(len(was_running))
        if c_slot is None and not was_running:
            return                  # nothing decodable, nothing chunks
        if self.straggler is not None:
            self.straggler.start_step()
        # table-width bucketing: stage only live pages.  After
        # _grow_pages every active slot owns the page its next write
        # lands in, so the max live page count bounds every per-slot
        # index the step takes into the table row.
        W = self.table.shape[1]
        if self.bucket_tables:
            live = max(len(s.pages) for s in self.slots if s is not None)
            W = bucket_table_width(live, self.table.shape[1])
        self.stats["table_widths"][W] = \
            self.stats["table_widths"].get(W, 0) + 1
        dbatch = {"token": jnp.asarray(self.tokens),
                  "cur_len": jnp.asarray(self.lens),
                  "block_table": jnp.asarray(self.table[:, :W]),
                  "cache": self.cache}
        if self.cfg.family == "audio":
            dbatch["enc_lens"] = jnp.asarray(self.enc_lens)
        if c_slot is not None:
            slot = self.slots[c_slot]
            toks = self._teacher_tokens(slot)
            p0 = slot.prefilled
            jp = p0 // self.page_size           # whole prefix pages
            jw = -(-(p0 + C) // self.page_size)  # end page (excl)
            dbatch["chunk_tokens"] = jnp.asarray(
                toks[p0:p0 + C], jnp.int32)[None]
            dbatch["chunk_pages"] = jnp.asarray(
                slot.pages[:jp], jnp.int32)
            dbatch["chunk_write_pages"] = jnp.asarray(
                slot.pages[jp:jw], jnp.int32)
            logits, chunk_logits, self.cache = self._run_mixed(dbatch)
            self.stats["mixed_steps"] += 1
            self.stats["chunks"] += 1
            self.stats["chunked_tokens"] += C
            slot.prefilled = p0 + C
            if slot.prefilled >= len(toks):
                self._prefilling.popleft()
                self._promote(c_slot, chunk_logits)
        else:
            logits, self.cache = self._run_decode(dbatch)
        self.stats["steps"] += 1
        # one jitted pick (batched argmax + per-slot fold_in keys +
        # batched categorical + isfinite guard) and ONE device->host
        # transfer for the whole step
        B = len(self.slots)
        seeds = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        for slot_id in was_running:
            slot = self.slots[slot_id]
            seeds[slot_id] = slot.req.seed
            steps[slot_id] = slot.steps
            temps[slot_id] = slot.req.temperature
        picked = np.asarray(self._pick_fn(
            logits, jnp.asarray(seeds), jnp.asarray(steps),
            jnp.asarray(temps)))
        greedy, sampled, finite = picked[0], picked[1], picked[2]
        now = time.monotonic()
        for slot_id in was_running:
            slot = self.slots[slot_id]
            if self.guard_nonfinite and not finite[slot_id]:
                # quarantine ONLY this slot: its pages free, its
                # partial stream is attached, survivors untouched
                self._fail_slot(
                    slot_id,
                    f"non-finite logits at decode step {slot.steps}")
                continue
            tok = int(sampled[slot_id] if slot.req.temperature > 0
                      else greedy[slot_id])
            slot.steps += 1
            slot.length += 1
            slot.out.append(tok)
            slot.token_times.append(now)
            self.lens[slot_id] = slot.length
            self.tokens[slot_id] = tok
            if len(slot.out) >= slot.req.gen:
                self._retire(slot_id)
        if self.straggler is not None:
            if self.straggler.end_step() is not None:
                self.stats["straggler_flags"] += 1
        if self.heartbeat is not None:
            self.heartbeat.beat(self.stats["steps"], extra={
                "active": self.n_active,
                "pending": len(self.pending),
                "finished": len(self.finished),
                "failed": self.stats["failed"],
                "retries": self.stats["step_retries"]})
        if self.snapshotter is not None:
            # async cadence: the host cut happens here, the disk
            # writes on the store's background pool
            self.snapshotter.on_step(self)

    def run(self) -> Dict[Any, RequestResult]:
        """Drain the pending queue: admit / step until every request is
        terminal.  A stream deadlock (pending work, no active slot, and
        still not enough pages) REJECTS the blocking request and keeps
        going — already-finished results are never lost; everything the
        scheduler ever saw comes back with a status."""
        while self.pending or self.parked or self.n_active:
            self.admit()
            if self.n_active == 0:
                if not (self.pending or self.parked):
                    break
                if not self.pending:
                    # only parked work left: admit() unparks on the
                    # next call now that nothing is runnable
                    continue
                # deadlock: nothing active to retire, head unadmittable
                item = self.pending.popleft()
                req = item.req if isinstance(item, _Slot) else item
                toks = item.out if isinstance(item, _Slot) else []
                self._release_queued(item)
                self._terminal(
                    req, toks, RequestStatus.REJECTED,
                    f"page pool exhausted: cannot admit with "
                    f"{self.allocator.free_pages} free page(s) of "
                    f"{self.allocator.n_pages} and no active request "
                    "left to retire — raise EngineConfig.n_pages")
                continue
            self.step()
        if self.snapshotter is not None:
            # surface a failed background snapshot at run end instead
            # of silently dropping it with the drained queue
            self.snapshotter.wait()
        return dict(self.finished)
