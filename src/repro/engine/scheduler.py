"""Continuous batching: request-level serving on the paged DecodeEngine.

The dense ``engine.generate`` admits a whole batch at once and holds
every slot until the longest request finishes — at serving scale most
of the cache and most of the step budget is spent on retired or
not-yet-started requests.  This scheduler runs the engine's jitted
paged decode step as a *slot machine* instead:

  admit    a pending request takes a free slot: its prompt is prefilled
           alone (batch-1 prefill, one jit cache per prompt length) and
           scattered into freshly allocated pages — survivors in other
           slots are untouched (no re-prefill, no cache copy);
  step     ONE decode step advances every active slot through the
           shared jitted step (per-slot lengths + block tables);
           inactive slots ride along masked;
  grow     a slot crossing a page boundary gets one more page from the
           allocator — a request's footprint is ceil(len/page_size)
           pages, never the engine-wide max_len budget;
  preempt  when growth finds the pool dry, the latest-admitted slot is
           evicted back to the pending queue (pages freed now, prompt +
           generated prefix teacher-forced back in at re-admission) —
           an oversubscribed pool degrades to less concurrency instead
           of killing the stream;
  retire   a finished request frees its pages and its slot immediately;
           the next pending request is admitted on the following
           ``admit()`` — short requests stop paying for long ones.

Token streams are bit-identical to a solo ``engine.generate`` run of
the same request (first token = argmax of the prefill logits; sampled
step i uses ``fold_in(PRNGKey(seed), i)``), which the paged-vs-dense
tests pin — except across a preemption, where the re-prefilled prefix
reproduces the decode-written cache only to fp rounding (a near-tie
argmax can flip, the usual recompute-preemption caveat).

All bookkeeping (free slots, free pages, per-slot lengths, block
tables) is host-side numpy; the device only ever sees the batch arrays
of the current step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.paged_cache import (PageAllocator, PagePoolExhausted,
                                      bucket_table_width, write_prefill)


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is the (P,) int32 prompt;
    ``gen`` counts generated tokens (prefill argmax included);
    ``frontend_emb`` feeds the vlm/audio modality frontends."""
    rid: Any
    tokens: np.ndarray
    gen: int
    temperature: float = 0.0
    seed: int = 0
    frontend_emb: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int                     # valid cache positions
    pages: List[int]                # physical pages owned
    out: List[int]                  # generated tokens so far
    steps: int = 0                  # decode steps taken (RNG fold_in)
    order: int = 0                  # admission sequence (LIFO preempt)


class Scheduler:
    """Admit / step / retire requests over a paged ``DecodeEngine``.

    ``enc_len`` budgets the audio cross-attention cache (frames per
    slot); it defaults to the engine's decoder ``max_len``, which is
    usually too SHORT for speech — encoder frame counts routinely
    exceed the decoder token budget, so audio streams should size it
    to the longest expected ``frontend_emb``.

    ``bucket_tables`` (default on) slices the block table each step to
    the power-of-two width bucket covering the longest active slot's
    live page count (``paged_cache.bucket_table_width``), so a step
    stages only live pages instead of ``max_pages`` columns; the
    jitted step compiles once per bucket (at most log2(max_pages)+1
    shapes).  Admission / growth / retirement semantics and the token
    streams are identical either way — only the staged table width
    changes."""

    def __init__(self, engine, enc_len: Optional[int] = None,
                 bucket_tables: bool = True):
        if not engine.ecfg.paged:
            raise ValueError(
                "Scheduler needs a paged engine: EngineConfig("
                "paged=True, page_size=..., n_pages=...)")
        self.eng = engine
        self.cfg = engine.cfg
        B, J = engine.ecfg.batch, engine.max_pages
        self.page_size = engine.page_size
        self.allocator = PageAllocator(engine.n_pages)
        self.slots: List[Optional[_Slot]] = [None] * B
        self.table = np.zeros((B, J), np.int32)
        self.lens = np.zeros((B,), np.int32)
        self.tokens = np.zeros((B,), np.int32)
        self.enc_lens = np.zeros((B,), np.int32)
        self.cache = engine.init_paged_cache(enc_len=enc_len)
        self.enc_budget = (self.cache["cross_k"].shape[2]
                           if self.cfg.family == "audio" else 0)
        self.bucket_tables = bucket_tables
        self.pending: deque = deque()   # Request | preempted _Slot
        self.finished: Dict[Any, np.ndarray] = {}
        self.stats = {"prefills": 0, "admitted": 0, "retired": 0,
                      "steps": 0, "peak_pages": 0, "preempted": 0,
                      "table_widths": {}}   # width -> steps at it
        self._order = 0
        # jitted prefill->pages scatter with the pool DONATED (where
        # the backend supports donation): the eager .at[].set would
        # copy every full pool leaf per admission
        self._write_prefill = jax.jit(
            lambda cache, caches, table, slots: write_prefill(
                self.cfg, cache, caches, table,
                enc_caches_slots=slots),
            donate_argnums=(() if jax.default_backend() == "cpu"
                            else (0,)))

    # ------------------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _prefill_positions(self, req: Request) -> int:
        P = len(req.tokens)
        if self.cfg.family == "vlm":
            P += self.cfg.frontend_tokens
        return P

    def _pages_needed(self, positions: int, more_writes: bool) -> int:
        """Pages covering ``positions`` occupied slots — plus the page
        the next decode token writes to, but only when one is coming
        (a gen-exhausted request must not ask for a page beyond its
        block-table row)."""
        last = positions + 1 if more_writes else positions
        return -(-last // self.page_size)

    def admit(self) -> int:
        """Admit pending requests (or preempted slots) into free slots
        while pages allow.  Returns the number admitted (0 = no free
        slot, nothing pending, or the pool is momentarily too full —
        retiring slots frees pages, so admission retries on the next
        call)."""
        admitted = 0
        while self.pending:
            try:
                slot_id = self.slots.index(None)
            except ValueError:
                break
            item = self.pending[0]
            req = item.req if isinstance(item, _Slot) else item
            P = self._prefill_positions(req)
            if P + req.gen - 1 > self.eng.ecfg.max_len:
                raise ValueError(
                    f"request {req.rid!r}: prompt {P} + gen {req.gen} "
                    f"exceeds engine max_len {self.eng.ecfg.max_len}")
            if (self.cfg.family == "audio"
                    and req.frontend_emb is not None
                    and req.frontend_emb.shape[0] > self.enc_budget):
                raise ValueError(
                    f"request {req.rid!r}: {req.frontend_emb.shape[0]} "
                    f"encoder frames exceed the cross-cache budget "
                    f"{self.enc_budget} — construct the Scheduler with "
                    "enc_len >= the longest expected frontend_emb")
            done = len(item.out) if isinstance(item, _Slot) else 1
            positions = P + (len(item.out) - 1
                             if isinstance(item, _Slot) else 0)
            need = self._pages_needed(positions, done < req.gen)
            if need > self.allocator.n_pages:
                raise PagePoolExhausted(
                    f"request {req.rid!r} needs {need} pages but the "
                    f"pool only has {self.allocator.n_pages} in total "
                    "— raise EngineConfig.n_pages or page_size")
            if need > self.allocator.free_pages:
                break               # wait for a retirement
            self.pending.popleft()
            self._admit_into(slot_id, item, self.allocator.alloc(need))
            admitted += 1
        return admitted

    def _admit_into(self, slot_id: int, item, pages: List[int]) -> None:
        """Prefill ``item`` (a fresh Request, or a preempted _Slot whose
        prompt + generated prefix is teacher-forced back in) into the
        allocated pages of ``slot_id``."""
        resumed = isinstance(item, _Slot)
        req = item.req if resumed else item
        tokens = np.asarray(req.tokens, np.int32)
        if resumed:
            # re-prefill everything already in the cache at preemption:
            # prompt + generated tokens except the last, which is the
            # slot's pending input token (written by the next step)
            tokens = np.concatenate([tokens,
                                     np.asarray(item.out[:-1], np.int32)])
        batch = {"tokens": jnp.asarray(tokens)[None]}
        if req.frontend_emb is not None:
            batch["frontend_emb"] = jnp.asarray(req.frontend_emb)[None]
        logits, caches = self.eng.prefill_fn(self.eng.params, batch)
        self.stats["prefills"] += 1
        row = np.zeros((1, self.table.shape[1]), np.int32)
        row[0, :len(pages)] = pages
        self.cache = self._write_prefill(self.cache, caches,
                                         jnp.asarray(row),
                                         jnp.asarray([slot_id]))
        if resumed:
            slot = _Slot(req=req, length=self._prefill_positions(req)
                         + len(item.out) - 1,
                         pages=list(pages), out=list(item.out),
                         steps=item.steps, order=self._order)
            tok = item.out[-1]
        else:
            # engine convention: the first generated token is the
            # argmax of the prefill logits; sampled steps start at
            # fold_in(key, 0)
            tok = int(jnp.argmax(logits[0]))
            slot = _Slot(req=req, length=self._prefill_positions(req),
                         pages=list(pages), out=[tok],
                         order=self._order)
        self._order += 1
        self.slots[slot_id] = slot
        self.table[slot_id] = row[0]
        self.lens[slot_id] = slot.length
        self.tokens[slot_id] = tok
        self.enc_lens[slot_id] = (req.frontend_emb.shape[0]
                                  if self.cfg.family == "audio"
                                  and req.frontend_emb is not None else 0)
        self.stats["admitted"] += 1
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.allocator.used_pages)
        if len(slot.out) >= req.gen:
            self._retire(slot_id)   # gen=1: the prefill already ends it

    def _retire(self, slot_id: int) -> None:
        slot = self.slots[slot_id]
        self.finished[slot.req.rid] = np.asarray(slot.out, np.int32)
        self.allocator.free(slot.pages)
        self.slots[slot_id] = None
        self.lens[slot_id] = 0
        self.tokens[slot_id] = 0
        self.enc_lens[slot_id] = 0
        self.stats["retired"] += 1

    def _preempt(self, slot_id: int) -> None:
        """Evict an active slot back to the FRONT of the pending queue
        (vLLM-style recompute preemption): its pages free immediately
        and its prompt + generated prefix is teacher-forced back in at
        re-admission, so no tokens are lost — only the prefix compute
        is redone."""
        slot = self.slots[slot_id]
        self.allocator.free(slot.pages)
        slot.pages = []
        self.pending.appendleft(slot)
        self.slots[slot_id] = None
        self.lens[slot_id] = 0
        self.tokens[slot_id] = 0
        self.enc_lens[slot_id] = 0
        self.stats["preempted"] += 1

    def _grow_pages(self) -> None:
        """A slot whose next write position opens a new page gets one
        more from the pool (the only mid-flight allocation).  When the
        pool is dry, the LATEST-admitted active slot is preempted
        (freeing its pages) until the allocation fits — the stream
        degrades to less concurrency instead of dying with every
        in-flight request lost."""
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            page_idx = slot.length // self.page_size
            if page_idx < len(slot.pages):
                continue
            while self.allocator.free_pages < 1:
                victim = max(
                    (s for s, sl in enumerate(self.slots)
                     if sl is not None),
                    key=lambda s: self.slots[s].order)
                self._preempt(victim)
                if victim == slot_id:
                    break           # the needy slot itself backed off
            if self.slots[slot_id] is None:
                continue
            (page,) = self.allocator.alloc(1)
            slot.pages.append(page)
            self.table[slot_id, page_idx] = page
            self.stats["peak_pages"] = max(
                self.stats["peak_pages"], self.allocator.used_pages)

    def step(self) -> None:
        """One decode step for every active slot, then retirement."""
        if self.n_active == 0:
            return
        self._grow_pages()
        if self.n_active == 0:      # growth preempted everything
            return
        # table-width bucketing: stage only live pages.  After
        # _grow_pages every active slot owns the page its next write
        # lands in, so the max live page count bounds every per-slot
        # index the step takes into the table row.
        W = self.table.shape[1]
        if self.bucket_tables:
            live = max(len(s.pages) for s in self.slots if s is not None)
            W = bucket_table_width(live, self.table.shape[1])
        self.stats["table_widths"][W] = \
            self.stats["table_widths"].get(W, 0) + 1
        dbatch = {"token": jnp.asarray(self.tokens),
                  "cur_len": jnp.asarray(self.lens),
                  "block_table": jnp.asarray(self.table[:, :W]),
                  "cache": self.cache}
        if self.cfg.family == "audio":
            dbatch["enc_lens"] = jnp.asarray(self.enc_lens)
        logits, self.cache = self.eng.decode_fn(self.eng.params, dbatch)
        self.stats["steps"] += 1
        # one batched argmax + one device->host transfer for the whole
        # step; only sampled (temperature > 0) slots pay a per-slot
        # categorical on top
        greedy = np.asarray(jnp.argmax(logits, -1))
        for slot_id, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.req.temperature > 0:
                key = jax.random.fold_in(
                    jax.random.PRNGKey(slot.req.seed), slot.steps)
                tok = int(jax.random.categorical(
                    key, logits[slot_id] / slot.req.temperature))
            else:
                tok = int(greedy[slot_id])
            slot.steps += 1
            slot.length += 1
            slot.out.append(tok)
            self.lens[slot_id] = slot.length
            self.tokens[slot_id] = tok
            if len(slot.out) >= slot.req.gen:
                self._retire(slot_id)

    def run(self) -> Dict[Any, np.ndarray]:
        """Drain the pending queue: admit / step until everything
        retires.  Raises ``PagePoolExhausted`` if the stream deadlocks
        (pending work, no active slots, and still not enough pages)."""
        while self.pending or self.n_active:
            self.admit()
            if self.n_active == 0:
                if self.pending:
                    raise PagePoolExhausted(
                        f"page pool exhausted: {len(self.pending)} "
                        f"pending request(s) cannot be admitted with "
                        f"{self.allocator.free_pages} free page(s) of "
                        f"{self.allocator.n_pages} and no active "
                        "request left to retire — raise "
                        "EngineConfig.n_pages")
                break
            self.step()
        return dict(self.finished)
