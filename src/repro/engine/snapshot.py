"""Engine snapshots: the full serving state, crash-consistently on disk.

The scheduler's state is exactly the bytes the paper's memory
hierarchy exists to keep resident — the paged KV pool (int8 scale
sidecars included) plus the host bookkeeping that makes those pages
mean something: the ``PageAllocator`` free/owned/refcount partition,
per-slot block tables, request lifecycle (PENDING/PREFILLING/RUNNING,
chunked-prefill progress, generated prefixes, per-request RNG
seed+step), the pending queue and parked set, the ``PrefixCache``
radix trie, finished results and counters.  ``snapshot`` serializes
ALL of it through ``checkpoint.CheckpointStore``'s shard format — the
device pools as ``.npy`` shards, the host state as one JSON blob
riding as a uint8 leaf — so a snapshot inherits the store's
crash-consistency discipline verbatim: written into ``step_N.tmp``,
committed with a fsynced ``_COMPLETE`` marker, atomically renamed.  A
crash mid-snapshot leaves the previous snapshot intact; keep-k GC
bounds disk.

``restore`` rebuilds a ``Scheduler`` over a live engine (the engine —
params, jitted step functions — is NOT part of the snapshot; params
belong to training checkpoints) and resumes decode bit-exactly:

  * the pool bytes round-trip exactly (npy preserves bf16/int8 bits),
  * the allocator free list is restored IN ORDER (``alloc`` pops from
    the end — order is what makes post-restore page assignment, and
    thus block tables, replay deterministically),
  * each slot's ``steps`` counter is its RNG state (sampled step i
    uses ``fold_in(PRNGKey(seed), i)``), so sampling resumes on the
    same key sequence,
  * monotonic timestamps (submit times, token times) are rebased by
    the snapshot→restore clock delta, so deadlines and ITL stats stay
    meaningful across a process restart.

``EngineSnapshotter`` adds the async cadence: every ``every`` steps
the scheduler's step path hands the state off to the store's
background writer (device→host copy is synchronous — the functional
step never mutates a published cache, so the copied tree is a
consistent cut — while the ``.npy`` writes happen off the step path).
``wait()``/``close()`` join the writer and re-raise its failure, so a
dying disk is never silently dropped.

Greedy token streams are pinned bit-identical crash+recover vs
crash-free (gqa/mla × bf16/int8 × prefix-cache × chunked-prefill in
``tests/test_snapshot.py``).  One caveat rides along from the prefix
cache: recovery re-indexes a FINISHED slot's prefix only up to its
snapshot-time length, so a post-recovery admission may match a
SHORTER cached prefix than it would have pre-crash — bit-identical
for model-dtype pools either way, but on int8 pools a near-tie argmax
in a hit's own stream can flip (the same caveat a cache hit already
carries vs a cold prefill).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore

SNAPSHOT_VERSION = 1


# ----------------------------------------------------------------------
# host-state (de)serialization
# ----------------------------------------------------------------------

def _req_state(req) -> Dict[str, Any]:
    d = {"rid": req.rid,
         "tokens": np.asarray(req.tokens, np.int32).tolist(),
         "gen": int(req.gen),
         "temperature": float(req.temperature),
         "seed": int(req.seed),
         "deadline_s": req.deadline_s,
         "max_steps": req.max_steps,
         "status": req.status.value,
         "error": req.error,
         "submit_t": req.submit_t}
    if req.frontend_emb is not None:
        emb = np.asarray(req.frontend_emb)
        d["frontend_emb"] = {"data": emb.tolist(),
                             "dtype": str(emb.dtype)}
    return d


def _req_from_state(d: Dict[str, Any], delta: float):
    from repro.engine.scheduler import Request, RequestStatus
    emb = None
    if d.get("frontend_emb") is not None:
        rec = d["frontend_emb"]
        emb = np.asarray(rec["data"], np.dtype(rec["dtype"]))
    return Request(rid=d["rid"],
                   tokens=np.asarray(d["tokens"], np.int32),
                   gen=int(d["gen"]),
                   temperature=float(d["temperature"]),
                   seed=int(d["seed"]),
                   frontend_emb=emb,
                   deadline_s=d.get("deadline_s"),
                   max_steps=d.get("max_steps"),
                   status=RequestStatus(d["status"]),
                   error=d.get("error"),
                   submit_t=(d["submit_t"] + delta
                             if d.get("submit_t") is not None else None))


def _slot_state(slot) -> Dict[str, Any]:
    return {"req": _req_state(slot.req),
            "length": int(slot.length),
            "pages": [int(p) for p in slot.pages],
            "out": [int(t) for t in slot.out],
            "steps": int(slot.steps),
            "order": int(slot.order),
            "preempts": int(slot.preempts),
            "prefilled": int(slot.prefilled),
            "token_times": list(slot.token_times)}


def _slot_from_state(d: Dict[str, Any], delta: float):
    from repro.engine.scheduler import _Slot
    return _Slot(req=_req_from_state(d["req"], delta),
                 length=int(d["length"]),
                 pages=[int(p) for p in d["pages"]],
                 out=[int(t) for t in d["out"]],
                 steps=int(d["steps"]),
                 order=int(d["order"]),
                 preempts=int(d["preempts"]),
                 prefilled=int(d["prefilled"]),
                 token_times=[t + delta for t in d["token_times"]])


def _queue_state(q) -> List[Dict[str, Any]]:
    from repro.engine.scheduler import _Slot
    out = []
    for item in q:
        if isinstance(item, _Slot):
            out.append({"kind": "slot", **_slot_state(item)})
        else:
            out.append({"kind": "req", **_req_state(item)})
    return out


def _queue_from_state(items, delta: float) -> deque:
    q: deque = deque()
    for d in items:
        if d["kind"] == "slot":
            q.append(_slot_from_state(d, delta))
        else:
            q.append(_req_from_state(d, delta))
    return q


def _result_state(res) -> Dict[str, Any]:
    return {"tokens": np.asarray(res, np.int32).tolist(),
            "status": res.status.value,
            "error": res.error,
            "latency_s": res.latency_s,
            "token_times": res.token_times}


def _result_from_state(d: Dict[str, Any]):
    from repro.engine.scheduler import RequestResult, RequestStatus
    return RequestResult(np.asarray(d["tokens"], np.int32),
                         RequestStatus(d["status"]),
                         error=d.get("error"),
                         latency_s=d.get("latency_s"),
                         token_times=d.get("token_times"))


def scheduler_state(sched) -> Dict[str, Any]:
    """The scheduler's complete host-side state as one JSON-able dict
    (the device pools ride separately as npy shards)."""
    ecfg = sched.eng.ecfg
    state = {
        "version": SNAPSHOT_VERSION,
        "step": int(sched.stats["steps"]),
        "mono": time.monotonic(),
        "engine": {"page_size": int(sched.page_size),
                   "n_pages": int(sched.allocator.n_pages),
                   "batch": int(ecfg.batch),
                   "max_len": int(ecfg.max_len),
                   "family": sched.cfg.family,
                   "kv_dtype": getattr(ecfg, "kv_dtype", None)},
        "sched": {"bucket_tables": bool(sched.bucket_tables),
                  "max_preemptions": int(sched.max_preemptions),
                  "guard_nonfinite": bool(sched.guard_nonfinite),
                  "prefix_cache": sched.prefix is not None,
                  "chunked_prefill": bool(sched.chunked),
                  "chunk_tokens": int(sched.chunk_tokens) or None,
                  "token_budget": int(sched.token_budget) or None,
                  "enc_len": (int(sched.enc_budget)
                              if sched.enc_budget else None)},
        "allocator": sched.allocator.to_state(),
        "table": sched.table.tolist(),
        "lens": sched.lens.tolist(),
        "tokens": sched.tokens.tolist(),
        "enc_lens": sched.enc_lens.tolist(),
        "slots": [None if s is None else _slot_state(s)
                  for s in sched.slots],
        "pending": _queue_state(sched.pending),
        "parked": _queue_state(sched.parked),
        "prefilling": [int(s) for s in sched._prefilling],
        "finished": [[rid, _result_state(res)]
                     for rid, res in sched.finished.items()],
        "prefix": (sched.prefix.to_state()
                   if sched.prefix is not None else None),
        "stats": {**sched.stats,
                  "table_widths": [[int(w), int(n)] for w, n in
                                   sched.stats["table_widths"].items()]},
        "latencies": list(sched._latencies),
        "itl": list(sched._itl),
        "order": int(sched._order),
    }
    return state


def snapshot_tree(sched) -> Dict[str, Any]:
    """The pytree one snapshot save writes: the device cache plus the
    host state as a uint8 JSON leaf (so the whole snapshot commits —
    or doesn't — as ONE atomic store step)."""
    blob = json.dumps(scheduler_state(sched)).encode("utf-8")
    return {"cache": sched.cache,
            "host": np.frombuffer(blob, np.uint8)}


# ----------------------------------------------------------------------
# snapshot / restore
# ----------------------------------------------------------------------

def _as_store(directory_or_store, keep: int = 3) -> CheckpointStore:
    if isinstance(directory_or_store, CheckpointStore):
        return directory_or_store
    if isinstance(directory_or_store, EngineSnapshotter):
        return directory_or_store.store
    return CheckpointStore(str(directory_or_store), keep=keep)


def snapshot(sched, directory_or_store, step: Optional[int] = None,
             *, async_: bool = False, keep: int = 3) -> int:
    """Write one snapshot of ``sched`` (device pools + host state)
    into the store at ``step`` (default: the scheduler's current step
    count).  Returns the step id.  ``async_`` hands the disk writes to
    the store's background pool — the device→host copy still happens
    here, synchronously, so the cut is consistent no matter how the
    scheduler mutates on."""
    store = _as_store(directory_or_store, keep=keep)
    if step is None:
        step = int(sched.stats["steps"])
    store.save(step, snapshot_tree(sched), async_=async_)
    return step


def _read_host_state(store: CheckpointStore, step: int) -> Dict[str, Any]:
    d = os.path.join(store.dir, f"step_{step}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    if "host" not in index:
        raise ValueError(
            f"{d} is not an engine snapshot (no 'host' leaf — a "
            "training checkpoint?)")
    shard = index["host"]["shards"][0]
    blob = np.load(os.path.join(d, shard["file"]))
    return json.loads(bytes(bytearray(np.asarray(blob, np.uint8))))


def restore(directory_or_store, engine, step: Optional[int] = None,
            *, journal=None, snapshotter=None, **sched_overrides):
    """Rebuild a ``Scheduler`` over ``engine`` from the snapshot at
    ``step`` (default: the latest complete one).  With no snapshot on
    disk a FRESH scheduler is returned — recovery before the first
    cadence is just "replay the whole journal into an empty engine".

    The engine must match the snapshot's geometry (page_size, n_pages,
    batch, family, kv_dtype); scheduler knobs (bucketing, chunking,
    prefix cache, budgets) are restored from the snapshot and can be
    overridden via ``sched_overrides``."""
    from repro.engine.scheduler import Scheduler

    store = _as_store(directory_or_store)
    if step is None:
        step = store.latest_step()
    kw = dict(sched_overrides)
    if step is None:
        return Scheduler(engine, journal=journal,
                         snapshotter=snapshotter, **kw)

    state = _read_host_state(store, step)
    if state.get("version") != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot version {state.get('version')} != "
                         f"supported {SNAPSHOT_VERSION}")
    geo = state["engine"]
    ecfg = engine.ecfg
    mine = {"page_size": int(engine.page_size),
            "n_pages": int(engine.n_pages),
            "batch": int(ecfg.batch),
            "max_len": int(ecfg.max_len),
            "family": engine.cfg.family,
            "kv_dtype": getattr(ecfg, "kv_dtype", None)}
    if geo != mine:
        raise ValueError(
            f"snapshot geometry {geo} does not match the engine "
            f"{mine} — restore needs the same engine config the "
            "snapshot was taken under")

    sk = state["sched"]
    for key in ("bucket_tables", "max_preemptions", "guard_nonfinite",
                "prefix_cache", "chunked_prefill", "chunk_tokens",
                "token_budget", "enc_len"):
        kw.setdefault(key, sk[key])
    sched = Scheduler(engine, journal=journal, snapshotter=snapshotter,
                      **kw)

    # device pools: restore the npy shards against the fresh cache's
    # own specs/shardings (same engine config -> same tree), then
    # device_put leaf-by-leaf so sharded pools land where the engine
    # expects them
    target = {"cache": jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sched.cache)}
    restored = store.restore(step, target)["cache"]
    sched.cache = jax.tree.map(
        lambda r, c: jax.device_put(np.asarray(r), c.sharding),
        restored, sched.cache)

    # host bookkeeping
    delta = time.monotonic() - state["mono"]
    sched.allocator.load_state(state["allocator"])
    sched.table = np.asarray(state["table"], np.int32)
    sched.lens = np.asarray(state["lens"], np.int32)
    sched.tokens = np.asarray(state["tokens"], np.int32)
    sched.enc_lens = np.asarray(state["enc_lens"], np.int32)
    sched.slots = [None if s is None else _slot_from_state(s, delta)
                   for s in state["slots"]]
    sched.pending = _queue_from_state(state["pending"], delta)
    sched.parked = _queue_from_state(state["parked"], delta)
    sched._prefilling = deque(int(s) for s in state["prefilling"])
    sched.finished = {rid: _result_from_state(res)
                      for rid, res in state["finished"]}
    if state["prefix"] is not None:
        if sched.prefix is None:
            raise ValueError("snapshot carries a prefix-cache trie but "
                             "the restored scheduler has prefix_cache "
                             "disabled")
        sched.prefix.load_state(state["prefix"])
    stats = dict(state["stats"])
    stats["table_widths"] = {int(w): int(n)
                             for w, n in stats["table_widths"]}
    sched.stats.update(stats)
    sched._latencies = list(state["latencies"])
    sched._itl = list(state["itl"])
    sched._order = int(state["order"])
    sched.allocator.check()
    if sched.prefix is not None:
        sched.prefix.check()
    return sched


class EngineSnapshotter:
    """Snapshot cadence riding the scheduler's step path.

    Construct with ``every=N`` and hand to the ``Scheduler``
    (``snapshotter=``): after every N-th step the scheduler calls
    ``on_step``, which cuts the state synchronously (host copy) and
    writes it on the store's background pool — decode is never blocked
    on disk.  Exposes ``latest_step()`` so it plugs directly into
    ``runtime.resilience.run_with_restarts`` as the resume store.
    ``wait()``/``close()`` join the background writer and re-raise its
    failure (the snapshot-cadence teardown the ``CheckpointStore.
    wait`` satellite exists for); the scheduler also calls ``wait()``
    when its drain loop ends."""

    def __init__(self, directory: str, *, every: int = 0, keep: int = 3):
        self.store = CheckpointStore(directory, keep=keep)
        self.every = int(every)
        self.saved = 0
        self._last: Optional[int] = None

    def latest_step(self) -> Optional[int]:
        return self.store.latest_step()

    def save(self, sched, *, async_: bool = True) -> int:
        step = snapshot(sched, self.store, async_=async_)
        self._last = step
        self.saved += 1
        return step

    def on_step(self, sched) -> None:
        step = int(sched.stats["steps"])
        if self.every and step != self._last and step % self.every == 0:
            self.save(sched, async_=True)

    def wait(self) -> None:
        self.store.wait()

    def close(self) -> None:
        self.store.wait()
