"""Block-size autotuner for the VWR Pallas kernels.

The paper's knob is the access-width ratio N: one ultra-wide SRAM/VWR
fill feeding N narrow VFU reads (§4.1).  Our kernels expose the same
knob as static block sizes — (bm, bk, bn) for the matmul, (bq, bkv)
for attention — and the right setting depends on the shape: small
problems want small blocks (padding waste), large problems want the
widest blocks VMEM can hold (arithmetic intensity).

This module picks the blocks per call shape:

  1. *prior*: every legal candidate is scored with the paper's
     width-ratio/arithmetic-intensity cost model — a roofline time
     estimate t = max(flops / PEAK_FLOPS, staged_bytes / HBM_BW)
     (constants from ``launch.roofline``) with the per-bit staging
     energy of ``core.machine.sram_bit_energy_fj`` as the tie-breaker
     (wider transactions are cheaper per bit, eq. 2 / Fig. 2b);
  2. *measure*: the top prior candidates are timed with the real
     kernel (interpret mode on CPU, Mosaic on TPU);
  3. *persist*: the winner lands in a JSON cache keyed by
     (op, shape, dtype, backend) that ``ops`` consults on every call —
     a process restart re-reads the file instead of re-measuring.

Measurement hygiene (all backends): the first call of every candidate
is discarded — it times XLA/Mosaic compilation, not the kernel — and
the reported number is the **median** of the remaining reps, which is
robust to scheduler noise on shared CI runners where a mean of 3 is a
coin-flip.

Environment knobs:
  REPRO_AUTOTUNE=0        disable: cost-model prior only, no cache I/O
  REPRO_AUTOTUNE_CACHE    cache file (default ~/.cache/repro/autotune.json)
  REPRO_AUTOTUNE_TOPK     candidates measured per miss (default 3)
  REPRO_AUTOTUNE_REPS     timed reps per candidate, median-reported
                          (default 5; the compile rep is extra)
"""
from __future__ import annotations

import json
import math
import os
import tempfile
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.machine import sram_bit_energy_fj
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

DEFAULT_BLOCKS = {
    "matmul": (256, 512, 256),
    "swiglu": (256, 512, 256),
    "attention": (256, 512),
    "conv": (8, 128),
    "decode": (512,),
}

# VMEM working-set budget per grid step (bytes).  Real v5e VMEM is
# 128 MiB/core but blocks also need double-buffering headroom.
VMEM_BUDGET = 12 * 1024 * 1024

# in-memory mirror of the JSON file: {path: {key: entry}}
_MEM: Dict[str, Dict[str, dict]] = {}

stats = {"hits": 0, "misses": 0, "measured": 0}


def enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def cache_key(op: str, shape: Sequence[int], dtype: str,
              backend: str) -> str:
    return f"{op}|{'x'.join(str(int(s)) for s in shape)}|{dtype}|{backend}"


def reset() -> None:
    """Drop the in-memory cache mirror and zero the stats (tests)."""
    _MEM.clear()
    for k in stats:
        stats[k] = 0


def _load(path: str) -> Dict[str, dict]:
    if path not in _MEM:
        try:
            with open(path) as f:
                _MEM[path] = json.load(f)
        except (OSError, ValueError):
            _MEM[path] = {}
    return _MEM[path]


def _persist(path: str, table: Dict[str, dict]) -> None:
    _MEM[path] = table
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # merge-with-disk then atomic replace: concurrent processes
        # tuning different shapes don't clobber each other's wins
        on_disk: Dict[str, dict] = {}
        try:
            with open(path) as f:
                on_disk = json.load(f)
        except (OSError, ValueError):
            pass
        on_disk.update(table)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(on_disk, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        _MEM[path] = on_disk
    except OSError:
        pass                     # read-only FS: in-memory cache still works


# ======================================================================
# candidate generation + width-ratio cost prior
# ======================================================================

def _pow2s(lo: int, hi: int, cap: int) -> Tuple[int, ...]:
    """Powers of two in [lo, min(hi, cap)] — pure powers of two so any
    two candidates nest (bq/bkv constraint) and blocks stay aligned to
    Mosaic's tiling on real TPUs.  A shape smaller than ``lo`` still
    yields (lo,): ops pads inputs up to block multiples, so oversized
    blocks cost padding, not correctness."""
    out = []
    b = lo
    while b <= min(hi, cap):
        out.append(b)
        b *= 2
    return tuple(out) if out else (lo,)


def _dtype_bytes(dtype: str) -> int:
    # '16' first: 'bfloat16'/'float16' contain no '8'; int8/fp8 do
    if "16" in dtype:
        return 2
    if "8" in dtype:
        return 1
    return 4


# fixed staging-buffer capacity for the energy tie-break: widening the
# transaction at fixed capacity makes it shallower, and eq. (2)'s
# per-bit energy D*BL + WL falls with depth D — the Fig. 2b monotone.
_STAGE_CAP_BITS = 1 << 20


def _stage_energy_fj_per_bit(width_bits: int) -> float:
    w = max(128, min(width_bits, 8192))
    return sram_bit_energy_fj(w, max(1, _STAGE_CAP_BITS // w))


def matmul_candidates(M: int, K: int, N: int, dtype: str
                      ) -> Tuple[Tuple[int, int, int], ...]:
    dt = _dtype_bytes(dtype)
    cands = []
    for bm in _pow2s(32, 256, max(32, M)):
        for bk in _pow2s(64, 512, max(64, K)):
            for bn in _pow2s(32, 256, max(32, N)):
                # staged LHS/RHS + dtype output block + fp32 accumulator
                vmem = (bm * bk + bk * bn + bm * bn) * dt + bm * bn * 4
                if vmem <= VMEM_BUDGET:
                    cands.append((bm, bk, bn))
    return tuple(cands)


def matmul_prior(M: int, K: int, N: int, dtype: str,
                 cand: Tuple[int, int, int]) -> Tuple[float, float]:
    """(roofline time estimate, per-bit staging energy) — sorted
    lexicographically, so energy breaks compute-bound ties in favour
    of the wider transaction (the paper's eq. 2 monotonicity)."""
    bm, bk, bn = cand
    dt = _dtype_bytes(dtype)
    nm, nn, nk = (math.ceil(M / bm), math.ceil(N / bn), math.ceil(K / bk))
    # padded-problem flops: padding waste is what penalizes oversized
    # blocks on small shapes
    flops = 2.0 * (nm * bm) * (nk * bk) * (nn * bn)
    staged = nm * nn * nk * (bm * bk + bk * bn) * dt + nm * nn * bm * bn * dt
    t = max(flops / PEAK_FLOPS, staged / HBM_BW)
    # wide-transaction width = one staged LHS row (bk operands)
    e_bit = _stage_energy_fj_per_bit(bk * dt * 8)
    return (t, e_bit)


def swiglu_candidates(M: int, K: int, N: int, dtype: str
                      ) -> Tuple[Tuple[int, int, int], ...]:
    """Dual-matmul swiglu: the staged x block is shared by both
    matmuls, but two weight blocks and two fp32 accumulators live in
    VMEM at once."""
    dt = _dtype_bytes(dtype)
    cands = []
    for bm in _pow2s(32, 256, max(32, M)):
        for bk in _pow2s(64, 512, max(64, K)):
            for bn in _pow2s(32, 256, max(32, N)):
                vmem = (bm * bk + 2 * bk * bn + bm * bn) * dt \
                    + 2 * bm * bn * 4
                if vmem <= VMEM_BUDGET:
                    cands.append((bm, bk, bn))
    return tuple(cands)


def swiglu_prior(M: int, K: int, N: int, dtype: str,
                 cand: Tuple[int, int, int]) -> Tuple[float, float]:
    """Matmul prior with doubled flops/weight-bytes and a *shared* LHS
    stage: the x block is fetched once per grid step for both matmuls,
    which is exactly the fusion's bandwidth win over two separate
    matmul calls (which would stage x twice and round-trip g and h)."""
    bm, bk, bn = cand
    dt = _dtype_bytes(dtype)
    nm, nn, nk = (math.ceil(M / bm), math.ceil(N / bn), math.ceil(K / bk))
    flops = 2 * 2.0 * (nm * bm) * (nk * bk) * (nn * bn)
    staged = nm * nn * nk * (bm * bk + 2 * bk * bn) * dt \
        + nm * nn * bm * bn * dt
    t = max(flops / PEAK_FLOPS, staged / HBM_BW)
    e_bit = _stage_energy_fj_per_bit(bk * dt * 8)
    return (t, e_bit)


def attention_candidates(S: int, D: int, dtype: str, causal: bool = True
                         ) -> Tuple[Tuple[int, int], ...]:
    dt = _dtype_bytes(dtype)
    cands = []
    for bq in _pow2s(64, 512, max(64, S)):
        for bkv in _pow2s(64, 1024, max(64, S)):
            big, small = max(bq, bkv), min(bq, bkv)
            if big % small:                 # bq/bkv must nest (ops pads
                continue                    # to the larger of the two)
            if not causal and S % big:      # non-causal can't mask away
                continue                    # kv padding
            # q block + k/v blocks + fp32 acc/p scratch
            vmem = (bq * D + 2 * bkv * D) * dt \
                + (bq * D + bq * bkv + 2 * bq) * 4
            if vmem <= VMEM_BUDGET:
                cands.append((bq, bkv))
    if not causal and not cands:
        # ragged S with no divisible power-of-two: the clamped (S, S)
        # single-block pair is the one shape-agnostic legal config
        # (the pre-autotuner default behavior of min(block, S)) — but
        # only while it still fits the VMEM budget; past that there is
        # genuinely no legal block and the caller gets the loud
        # "no legal block candidates" error
        vmem = 3 * S * D * dt + (S * D + S * S + 2 * S) * 4
        if vmem <= VMEM_BUDGET:
            cands.append((S, S))
    return tuple(cands)


def attention_prior(B: int, S: int, H: int, KV: int, D: int, dtype: str,
                    cand: Tuple[int, int]) -> Tuple[float, float]:
    bq, bkv = cand
    dt = _dtype_bytes(dtype)
    nq, nk = math.ceil(S / bq), math.ceil(S / bkv)
    Sp = max(nq * bq, nk * bkv)
    nq, nk = Sp // bq, Sp // bkv
    BH = B * H
    flops = BH * nq * nk * (2.0 * bq * bkv * D * 2)       # qk + pv
    # q staged once per q block + output store; K/V blocks are
    # re-fetched for every (head, q-block, kv-block) grid step — the
    # zero-copy GQA layout shrinks the HBM *footprint* by G, not the
    # per-grid-step DMA count, so no G division here
    staged = BH * nq * bq * D * dt \
        + BH * nq * nk * 2 * bkv * D * dt \
        + BH * nq * bq * D * dt
    t = max(flops / PEAK_FLOPS, staged / HBM_BW)
    e_bit = _stage_energy_fj_per_bit(bkv * dt * 8)
    return (t, e_bit)


def conv_candidates(N: int, H: int, W: int, C: int, KH: int, KW: int,
                    F: int, dtype: str) -> Tuple[Tuple[int, int], ...]:
    """(bh, bf) row-block / filter-block candidates for vwr_conv2d."""
    dt = _dtype_bytes(dtype)
    H_out = max(1, H - KH + 1)
    cands = []
    for bh in _pow2s(2, 32, max(2, H_out)):
        for bf in _pow2s(32, 256, max(32, F)):
            # halo'd input row block + weight block + fp32 accumulator
            vmem = ((bh + KH - 1) * W * C + KH * KW * C * bf) * dt \
                + bh * W * bf * 4
            if vmem <= VMEM_BUDGET:
                cands.append((bh, bf))
    return tuple(cands)


def conv_prior(N: int, H: int, W: int, C: int, KH: int, KW: int, F: int,
               dtype: str, cand: Tuple[int, int]) -> Tuple[float, float]:
    """Same (roofline time, staging energy) shape as the matmul prior —
    the staged wide transaction is one halo'd input row block, and its
    width feeds the shared eq.-2 energy tie-break."""
    bh, bf = cand
    dt = _dtype_bytes(dtype)
    H_out, W_out = max(1, H - KH + 1), max(1, W - KW + 1)
    nr = math.ceil(H_out / bh)
    nf = math.ceil(F / bf)
    flops = 2.0 * N * (nr * bh) * W_out * C * (nf * bf) * KH * KW
    staged = N * nr * nf * ((bh + KH - 1) * W * C
                            + KH * KW * C * bf) * dt \
        + N * nr * nf * bh * W_out * bf * dt
    t = max(flops / PEAK_FLOPS, staged / HBM_BW)
    e_bit = _stage_energy_fj_per_bit((bh + KH - 1) * W * C * dt * 8)
    return (t, e_bit)


def decode_candidates(T: int, D: int, dtype: str
                      ) -> Tuple[Tuple[int], ...]:
    """(bkv,) cache-block candidates for the flash-decode kernel."""
    dt = _dtype_bytes(dtype)
    cands = []
    for bkv in _pow2s(64, 1024, max(64, T)):
        vmem = 2 * bkv * D * dt + (bkv + 3 * D + 2) * 4
        if vmem <= VMEM_BUDGET:
            cands.append((bkv,))
    return tuple(cands)


def decode_prior(B: int, T: int, H: int, KV: int, D: int, dtype: str,
                 cand: Tuple[int]) -> Tuple[float, float]:
    bkv, = cand
    dt = _dtype_bytes(dtype)
    nk = math.ceil(T / bkv)
    G = max(1, H // KV)
    flops = B * KV * nk * (2.0 * G * bkv * D * 2)
    # the cache slab is streamed once per token — pure bandwidth
    staged = B * KV * nk * 2 * bkv * D * dt
    t = max(flops / PEAK_FLOPS, staged / HBM_BW)
    e_bit = _stage_energy_fj_per_bit(bkv * dt * 8)
    return (t, e_bit)


# ======================================================================
# tune-or-lookup driver
# ======================================================================

def _measure(run: Callable[[], None], reps: Optional[int] = None) -> float:
    reps = reps if reps is not None else max(
        1, int(os.environ.get("REPRO_AUTOTUNE_REPS", "5")))
    run()               # first call discarded: times compile, not kernel
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6                # median, microseconds


def get_blocks(op: str, shape: Sequence[int], dtype: str, backend: str,
               candidates: Sequence[Tuple[int, ...]],
               prior: Callable[[Tuple[int, ...]], Tuple[float, float]],
               runner: Optional[Callable[[Tuple[int, ...]], Callable]],
               ) -> Tuple[int, ...]:
    """Cache lookup -> (on miss) prior-ranked measurement -> persist.

    ``runner(cand)`` returns a zero-arg callable executing the kernel
    at that candidate (or None to skip measurement and trust the
    prior — used when REPRO_AUTOTUNE=0)."""
    if not candidates:
        raise ValueError(f"no legal block candidates for {op} {shape}")
    if not enabled() or runner is None:
        return min(candidates, key=prior)

    path = cache_path()
    table = _load(path)
    key = cache_key(op, shape, dtype, backend)
    hit = table.get(key)
    if hit is not None:
        stats["hits"] += 1
        return tuple(hit["blocks"])

    stats["misses"] += 1
    ranked = sorted(candidates, key=prior)
    topk = int(os.environ.get("REPRO_AUTOTUNE_TOPK", "3"))
    best, best_us, n_measured = None, float("inf"), 0
    for cand in ranked[:max(1, topk)]:
        us = _measure(runner(cand))
        stats["measured"] += 1
        n_measured += 1
        if us < best_us:
            best, best_us = cand, us
    t_prior, e_bit = prior(best)
    table[key] = {
        "blocks": list(best), "us": best_us,
        "prior_t_s": t_prior, "prior_e_fj_per_bit": e_bit,
        "measured": n_measured,
    }
    _persist(path, table)
    return best
