"""Kernel-dispatch registry: one seam between model code and kernels.

The paper's thesis is that versatility across streaming workloads comes
from one adaptive memory/compute surface, not per-workload special
cases.  The code-level analogue: model and distribution code never
compares implementation strings (``if kernel_impl == "pallas"``) —
every op with more than one realization is *registered* here per
backend, and callers say ``dispatch(op, cfg, *args)``.  New backends
(a future ``custom_vjp`` training path, a second accelerator) plug in
with a ``@register`` decorator instead of another branch in every
caller.

Backends:
  'xla'     einsum/blockwise reference formulations (GSPMD-shardable,
            differentiable) — the default.
  'pallas'  VWR Pallas kernels (fused epilogues, zero-copy GQA,
            autotuned blocks).  Forward-only.
  'auto'    per-op, per-shape choice.  Consults the same persisted
            autotuner cache as the block tuner (``kernels.autotune``):
            on a miss both backends are *measured* on synthesized
            inputs of the call's shapes and the winner is cached under
            ``dispatch:<op>``; with measurement disabled
            (``REPRO_AUTOTUNE=0``) the prior picks the fused Pallas
            path when one is registered (the paper's wide-staging
            default).

Registration lives next to the reference implementation of each op
(``models/attention.py``, ``models/layers.py``), so importing the
model layer populates the registry; the Pallas bodies keep their lazy
``from repro.kernels import ops`` imports.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Tuple

# op -> backend -> implementation
_REGISTRY: Dict[str, Dict[str, Callable]] = {}

# preferred backend order for 'auto' (first = the prior's pick)
AUTO_ORDER: Tuple[str, ...] = ("pallas", "xla")

# backends jax.grad can differentiate through.  When the custom_vjp
# training path lands (ROADMAP), 'pallas' joins this tuple and
# training picks it up with no model-code change — this property is
# the registry's, not scattered string comparisons'.
DIFFERENTIABLE_BACKENDS: Tuple[str, ...] = ("xla",)


def training_backend(cfg_or_backend: Any) -> str:
    """The backend training may use: 'auto' narrows to the
    differentiable set; a non-differentiable pin raises."""
    backend = backend_for(cfg_or_backend)
    if backend == "auto":
        return DIFFERENTIABLE_BACKENDS[0]
    if backend not in DIFFERENTIABLE_BACKENDS:
        raise ValueError(
            f"kernel_impl={backend!r} is forward-only (prefill/decode/"
            "eval): the VWR Pallas kernels define no VJP yet, and "
            "jax.grad through them dies with an opaque assertion.  "
            f"Train with kernel_impl in {DIFFERENTIABLE_BACKENDS} "
            "(see ROADMAP open items).")
    return backend


def register(op: str, backend: str) -> Callable[[Callable], Callable]:
    """Decorator: ``@register("mlp", "pallas")`` adds an implementation.
    Re-registration overwrites (tests monkeypatch through this)."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn

    return deco


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def backends(op: str) -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY.get(op, ())))


def backend_for(cfg_or_backend: Any) -> str:
    """A ModelConfig (uses ``cfg.kernel_impl``) or a backend string."""
    if isinstance(cfg_or_backend, str):
        return cfg_or_backend
    return getattr(cfg_or_backend, "kernel_impl", "xla")


def resolve(op: str, cfg_or_backend: Any, args=(), kwargs=None) -> Callable:
    """The implementation ``dispatch`` would call (without calling it)."""
    table = _REGISTRY.get(op)
    if not table:
        raise KeyError(
            f"no implementations registered for op {op!r}; "
            f"registered ops: {ops()}")
    backend = backend_for(cfg_or_backend)
    if backend == "auto":
        backend = _resolve_auto(op, table, args, kwargs or {})
    impl = table.get(backend)
    if impl is None:
        raise KeyError(
            f"op {op!r} has no {backend!r} backend; "
            f"registered: {backends(op)}")
    return impl


def dispatch(op: str, cfg_or_backend: Any, *args, **kwargs):
    """Call the registered implementation of ``op`` for the backend
    selected by ``cfg_or_backend`` (a ModelConfig or backend string)."""
    return resolve(op, cfg_or_backend, args, kwargs)(*args, **kwargs)


def cached_backend(op: str, cfg_or_backend: Any, args=(),
                   kwargs=None) -> str:
    """Resolve 'auto' by pure cache LOOKUP — replay a measured
    ``dispatch:<op>`` winner if one exists for these arg shapes, else
    fall back to the prior order.  Never measures and never writes, so
    it is safe while *constructing* a shard_map program (the measuring
    path is only unsafe inside the traced body)."""
    backend = backend_for(cfg_or_backend)
    if backend != "auto":
        return backend
    table = _REGISTRY.get(op, {})
    cands = [b for b in AUTO_ORDER if b in table]
    cands += [b for b in sorted(table) if b not in cands]
    if not cands:
        return "xla"
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    shape, dtype = _arg_signature(args, kwargs or {})
    if shape and autotune.enabled():
        tag = kops._backend_tag(kops._auto_interpret(None))
        key = autotune.cache_key(f"dispatch:{op}", shape, dtype, tag)
        hit = autotune._load(autotune.cache_path()).get(key)
        if hit is not None:
            name = _decode_winner(hit["blocks"][0], cands)
            if name is not None:
                return name
    return cands[0]


def _decode_winner(entry, cands) -> "str | None":
    """A persisted dispatch winner: the backend NAME (current format —
    immune to registry growth/reordering), or a legacy positional index
    into the candidate list (pre-paged-kernel cache files), tolerated
    as long as it is still in range."""
    if isinstance(entry, str):
        return entry if entry in cands else None
    idx = int(entry)
    return cands[idx] if 0 <= idx < len(cands) else None


# ======================================================================
# 'auto': measured xla-vs-pallas choice through the autotuner cache
# ======================================================================

def _arg_signature(args, kwargs):
    """Flattened shapes of every array-typed argument, plus the
    deduplicated dtypes of ALL array args with the non-array static
    args (activation name, causal flag, ...) folded in — the cache key
    for a dispatch decision.  Without the static part,
    ``mlp(..., 'gelu')`` and ``mlp(..., 'relu')`` at the same shapes
    would collide on one measured winner; keying only the FIRST array
    dtype would collide an fp32-query int8-pool call with its all-bf16
    twin (the query leads both), so every distinct operand dtype
    joins the key."""
    import jax

    shape: list = []
    static: list = []
    dtypes: dict = {}                       # ordered de-dup
    for leaf in jax.tree.leaves(
            (args, kwargs), is_leaf=lambda x: x is None):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            shape.extend(int(s) for s in leaf.shape)
            shape.append(-1)                    # arg separator
            dtypes[str(leaf.dtype)] = None
        elif isinstance(leaf, (str, bool, int, float)) or leaf is None:
            static.append(str(leaf))
    dtype = ",".join(dtypes) or "float32"
    if static:
        dtype = dtype + ";" + ",".join(static)
    return tuple(shape), dtype


def _synthesize(args, kwargs):
    """Concrete zero-filled stand-ins for (possibly traced) call args,
    so candidate backends can be timed at trace time — the same move
    the block autotuner's runners make with ``jnp.ones``."""
    import jax
    import jax.numpy as jnp

    def conc(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return jnp.zeros(leaf.shape, leaf.dtype)
        return leaf

    return jax.tree.map(conc, (args, kwargs))


def _resolve_auto(op: str, table: Dict[str, Callable], args, kwargs) -> str:
    from repro.kernels import autotune
    from repro.kernels import ops as kops
    import jax

    cands = [b for b in AUTO_ORDER if b in table]
    cands += [b for b in sorted(table) if b not in cands]
    if len(cands) == 1:
        return cands[0]
    shape, dtype = _arg_signature(args, kwargs)
    if not shape:                       # nothing to key on: trust prior
        return cands[0]
    tag = kops._backend_tag(kops._auto_interpret(None))

    # migrate legacy positional-index entries to backend names: an
    # index decoded against the CURRENT candidate list silently shifts
    # meaning whenever a backend is registered (or a test monkeypatches
    # an op), so the name is the only stable thing to persist
    if autotune.enabled():
        path = autotune.cache_path()
        tbl = autotune._load(path)
        key = autotune.cache_key(f"dispatch:{op}", shape, dtype, tag)
        hit = tbl.get(key)
        if hit is not None and not isinstance(hit["blocks"][0], str):
            name = _decode_winner(hit["blocks"][0], cands)
            if name is None:
                tbl.pop(key)        # unmappable: re-measure below
            else:
                tbl[key] = {**hit, "blocks": [name]}
                autotune._persist(path, tbl)

    def runner(cand):
        impl = table[cand[0]]
        cargs, ckw = _synthesize(args, kwargs)

        def run():
            jax.block_until_ready(impl(*cargs, **ckw))
        return run

    winner, = autotune.get_blocks(
        f"dispatch:{op}", shape, dtype, tag,
        # candidates are the backend NAMES — the persisted entry
        # replays by name, so later registrations can't shift it
        candidates=tuple((b,) for b in cands),
        # prior: registration-preference order (pallas first); the
        # measured pass, when enabled, overrides it per shape
        prior=lambda c: (float(cands.index(c[0])), 0.0),
        runner=runner if autotune.enabled() else None)
    if winner not in table:             # stale name (backend removed)
        return cands[0]
    return winner


# ======================================================================
# deprecation shim for the old kernel_impl= call-site kwarg
# ======================================================================

_KERNEL_IMPL_WARNED = False


def warn_kernel_impl_kwarg(site: str) -> None:
    """One DeprecationWarning per process for the legacy ``kernel_impl=``
    kwarg on ``attention.qkv_proj``/``o_proj`` and ``layers.mlp``."""
    global _KERNEL_IMPL_WARNED
    if _KERNEL_IMPL_WARNED:
        return
    _KERNEL_IMPL_WARNED = True
    warnings.warn(
        f"{site}: the kernel_impl= kwarg is deprecated; pass backend= "
        "(or a ModelConfig) and let repro.kernels.dispatch route the "
        "call — implementations are registered per backend there.",
        DeprecationWarning, stacklevel=3)
