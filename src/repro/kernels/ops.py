"""Public jit'd wrappers for the VWR Pallas kernels.

Handles shape padding to block multiples, GQA head expansion, and
interpret-mode selection (CPU containers validate kernels with
``interpret=True``; on real TPU the same calls compile to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vwr_attention import vwr_attention_p
from repro.kernels.vwr_conv2d import vwr_conv2d_p
from repro.kernels.vwr_depthwise import vwr_depthwise_p
from repro.kernels.vwr_matmul import vwr_matmul_p


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_dim(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def vwr_matmul(x, w, *, bm=256, bk=512, bn=256, interpret=None):
    """x: (M, K) @ w: (K, N) with arbitrary shapes (padded internally)."""
    interpret = _auto_interpret(interpret)
    M, K = x.shape
    N = w.shape[1]
    bm_, bk_, bn_ = (min(bm, M) if M else bm, min(bk, K), min(bn, N))
    xp = _pad_dim(_pad_dim(x, 0, bm_), 1, bk_)
    wp = _pad_dim(_pad_dim(w, 0, bk_), 1, bn_)
    out = vwr_matmul_p(xp, wp, bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bh", "bf", "interpret"))
def vwr_conv2d(x, w, *, bh=8, bf=128, interpret=None):
    """x: (N,H,W,C); w: (KH,KW,C,F); stride 1, VALID."""
    interpret = _auto_interpret(interpret)
    KH = w.shape[0]
    F = w.shape[3]
    H_out = x.shape[1] - KH + 1
    bh_ = min(bh, H_out)
    bf_ = min(bf, F)
    # pad H so H_out divides bh (extra rows are discarded)
    pad_h = (-H_out) % bh_
    xp = _pad_dim(x, 1, 1) if pad_h == 0 else jnp.pad(
        x, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    wp = _pad_dim(w, 3, bf_)
    out = vwr_conv2d_p(xp, wp, bh=bh_, bf=bf_, interpret=interpret)
    return out[:, :H_out, :, :F]


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def vwr_depthwise(x, w, *, bh=8, interpret=None):
    """x: (N,H,W,C); w: (KH,KW,C); stride 1, VALID."""
    interpret = _auto_interpret(interpret)
    KH = w.shape[0]
    H_out = x.shape[1] - KH + 1
    bh_ = min(bh, H_out)
    pad_h = (-H_out) % bh_
    xp = x if pad_h == 0 else jnp.pad(
        x, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    out = vwr_depthwise_p(xp, w, bh=bh_, interpret=interpret)
    return out[:, :H_out]


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def vwr_attention(q, k, v, *, causal=True, bq=256, bkv=512, interpret=None):
    """q: (B,S,H,D); k,v: (B,S,KV,D) (GQA: KV divides H). Causal masks
    use true positions, so KV-padding to block multiples is masked out
    by construction only for causal=True; for causal=False we pad K
    with -inf-free zeros and rely on the softmax of -1e30... instead we
    require S % bkv == 0 for causal=False (asserted)."""
    interpret = _auto_interpret(interpret)
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    bq_ = min(bq, S)
    bkv_ = min(bkv, S)
    big = max(bq_, bkv_)
    assert big % min(bq_, bkv_) == 0, "bq and bkv must nest"
    if not causal:
        assert S % big == 0, "non-causal path needs S % block == 0"
    # pad to a common block multiple; padded kv rows sit at positions
    # beyond every real query position, so causal masking removes them
    qf = _pad_dim(qf, 1, big)
    kf = _pad_dim(kf, 1, big)
    vf = _pad_dim(vf, 1, big)

    out = vwr_attention_p(qf, kf, vf, causal=causal, bq=bq_, bkv=bkv_,
                          interpret=interpret)
    out = out[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return out
