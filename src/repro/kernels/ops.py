"""Public wrappers for the VWR Pallas kernels.

Handles shape padding to block multiples, zero-copy GQA head routing,
fused epilogues (bias / activation / residual inside the matmul's
final-K store), block-size autotuning (``repro.kernels.autotune``, a
JSON cache keyed by op/shape/dtype/backend consulted on every call
when block sizes are not pinned), and interpret-mode selection (CPU
containers validate kernels with ``interpret=True``; on real TPU the
same calls compile to Mosaic).

Each public op is a thin Python wrapper (block-size resolution happens
at trace time) around a jitted implementation, so calls from inside
jitted model code inline cleanly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.vwr_attention import vwr_attention_p
from repro.kernels.vwr_conv2d import vwr_conv2d_p
from repro.kernels.vwr_decode import (vwr_chunk_prefix_attend_p,
                                      vwr_chunk_prefix_attend_q8_p,
                                      vwr_flash_decode_p,
                                      vwr_flash_decode_q8_p,
                                      vwr_mla_chunk_prefix_attend_p,
                                      vwr_mla_chunk_prefix_attend_q8_p,
                                      vwr_mla_flash_decode_p,
                                      vwr_mla_flash_decode_q8_p,
                                      vwr_mla_paged_flash_decode_p,
                                      vwr_mla_paged_flash_decode_q8_p,
                                      vwr_paged_flash_decode_p,
                                      vwr_paged_flash_decode_q8_p)
from repro.kernels.vwr_depthwise import vwr_depthwise_p
from repro.kernels.vwr_matmul import vwr_matmul_p, vwr_swiglu_p


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _backend_tag(interpret: bool) -> str:
    """Cache key component: measured winners are per-hardware, so the
    tag carries the device kind (v5e vs v6e tune differently), not
    just the platform name."""
    if interpret:
        return "interp"
    kind = jax.devices()[0].device_kind.replace(" ", "_")
    return f"{jax.default_backend()}:{kind}"


def _pad_dim(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ======================================================================
# matmul (+ fused epilogue)
# ======================================================================

@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn",
                                             "activation", "interpret"))
def _vwr_matmul_jit(x, w, bias, residual, *, bm, bk, bn, activation,
                    interpret):
    M, K = x.shape
    N = w.shape[1]
    bm_, bk_, bn_ = (min(bm, M) if M else bm, min(bk, K), min(bn, N))
    xp = _pad_dim(_pad_dim(x, 0, bm_), 1, bk_)
    wp = _pad_dim(_pad_dim(w, 0, bk_), 1, bn_)
    bp = None if bias is None else _pad_dim(bias.reshape(1, N), 1, bn_)
    rp = None if residual is None else _pad_dim(
        _pad_dim(residual, 0, bm_), 1, bn_)
    out = vwr_matmul_p(xp, wp, bp, rp, bm=bm_, bk=bk_, bn=bn_,
                       activation=activation, interpret=interpret)
    return out[:M, :N]


def vwr_matmul(x, w, bias=None, residual=None, *, activation=None,
               bm=None, bk=None, bn=None, interpret=None):
    """``act(x @ w + bias) + residual`` in one kernel pass.

    x: (M, K) @ w: (K, N), arbitrary shapes (padded internally).
    bias: (N,) or (1, N); residual: (M, N); activation in
    {None, 'relu', 'gelu', 'silu'} — all applied to the fp32
    accumulator inside the final-K store (no extra HBM round-trip).
    With all of bm/bk/bn unspecified the autotuner resolves them
    (cost-model prior + measured winners cached in a JSON file);
    pinning any subset keeps the pins and fills the rest from the
    static defaults (a pinned knob is a deliberate experiment — the
    tuner must not override it)."""
    interpret = _auto_interpret(interpret)
    M, K = x.shape
    N = w.shape[1]
    if bm is None and bk is None and bn is None:
        bm, bk, bn = _matmul_blocks(M, K, N, str(x.dtype), interpret)
    else:
        d_bm, d_bk, d_bn = autotune.DEFAULT_BLOCKS["matmul"]
        bm = d_bm if bm is None else bm
        bk = d_bk if bk is None else bk
        bn = d_bn if bn is None else bn
    return _vwr_matmul_jit(x, w, bias, residual, bm=bm, bk=bk, bn=bn,
                           activation=activation, interpret=interpret)


def _matmul_blocks(M, K, N, dtype, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bm, bk, bn = cand
        xz = jnp.ones((M, K), jnp.dtype(dtype))
        wz = jnp.ones((K, N), jnp.dtype(dtype))

        def run():
            jax.block_until_ready(_vwr_matmul_jit(
                xz, wz, None, None, bm=bm, bk=bk, bn=bn,
                activation=None, interpret=interpret))
        return run

    return autotune.get_blocks(
        "matmul", (M, K, N), dtype, backend,
        candidates=autotune.matmul_candidates(M, K, N, dtype),
        prior=lambda c: autotune.matmul_prior(M, K, N, dtype, c),
        runner=runner if autotune.enabled() else None)


# ======================================================================
# fused swiglu (dual matmul, shared LHS staging)
# ======================================================================

@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn",
                                             "interpret"))
def _vwr_swiglu_jit(x, wg, wi, *, bm, bk, bn, interpret):
    M, K = x.shape
    N = wg.shape[1]
    bm_, bk_, bn_ = (min(bm, M) if M else bm, min(bk, K), min(bn, N))
    xp = _pad_dim(_pad_dim(x, 0, bm_), 1, bk_)
    wgp = _pad_dim(_pad_dim(wg, 0, bk_), 1, bn_)
    wip = _pad_dim(_pad_dim(wi, 0, bk_), 1, bn_)
    out = vwr_swiglu_p(xp, wgp, wip, bm=bm_, bk=bk_, bn=bn_,
                       interpret=interpret)
    return out[:M, :N]


def vwr_swiglu(x, wg, wi, *, bm=None, bk=None, bn=None, interpret=None):
    """``silu(x @ wg) * (x @ wi)`` in one kernel pass.

    x: (M, K); wg, wi: (K, N), arbitrary shapes (padded internally).
    The staged x block feeds both matmuls and the gate product is
    applied to the two fp32 accumulators inside the final-K store, so
    the swiglu hidden activation costs one HBM round-trip total — no
    separate ``g * h`` elementwise pass.  Block resolution follows the
    matmul convention (autotuner when unpinned, defaults fill a
    partial pin)."""
    interpret = _auto_interpret(interpret)
    M, K = x.shape
    N = wg.shape[1]
    if bm is None and bk is None and bn is None:
        bm, bk, bn = _swiglu_blocks(M, K, N, str(x.dtype), interpret)
    else:
        d_bm, d_bk, d_bn = autotune.DEFAULT_BLOCKS["swiglu"]
        bm = d_bm if bm is None else bm
        bk = d_bk if bk is None else bk
        bn = d_bn if bn is None else bn
    return _vwr_swiglu_jit(x, wg, wi, bm=bm, bk=bk, bn=bn,
                           interpret=interpret)


def _swiglu_blocks(M, K, N, dtype, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bm, bk, bn = cand
        xz = jnp.ones((M, K), jnp.dtype(dtype))
        wz = jnp.ones((K, N), jnp.dtype(dtype))

        def run():
            jax.block_until_ready(_vwr_swiglu_jit(
                xz, wz, wz, bm=bm, bk=bk, bn=bn, interpret=interpret))
        return run

    return autotune.get_blocks(
        "swiglu", (M, K, N), dtype, backend,
        candidates=autotune.swiglu_candidates(M, K, N, dtype),
        prior=lambda c: autotune.swiglu_prior(M, K, N, dtype, c),
        runner=runner if autotune.enabled() else None)


# ======================================================================
# conv
# ======================================================================

@functools.partial(jax.jit, static_argnames=("bh", "bf", "activation",
                                             "interpret"))
def _vwr_conv2d_jit(x, w, bias, *, bh, bf, activation, interpret):
    KH = w.shape[0]
    F = w.shape[3]
    H_out = x.shape[1] - KH + 1
    bh_ = min(bh, H_out)
    bf_ = min(bf, F)
    # pad H so H_out divides bh (extra rows are discarded)
    pad_h = (-H_out) % bh_
    xp = _pad_dim(x, 1, 1) if pad_h == 0 else jnp.pad(
        x, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    wp = _pad_dim(w, 3, bf_)
    bp = None if bias is None else _pad_dim(bias.reshape(1, F), 1, bf_)
    out = vwr_conv2d_p(xp, wp, bp, bh=bh_, bf=bf_, activation=activation,
                       interpret=interpret)
    return out[:, :H_out, :, :F]


def vwr_conv2d(x, w, bias=None, *, activation=None, bh=None, bf=None,
               interpret=None):
    """``act(conv2d(x, w) + bias)`` in one kernel pass.

    x: (N,H,W,C); w: (KH,KW,C,F); stride 1, VALID.  bias: (F,) and
    activation in {None,'relu','gelu','silu'} are fused into the fp32
    accumulator before the single output store (no extra elementwise
    HBM pass).  With both bh/bf unspecified the autotuner resolves them
    via the shared staging-energy prior; pinning any subset keeps the
    pins and fills the rest from the static defaults."""
    interpret = _auto_interpret(interpret)
    if bh is None and bf is None:
        bh, bf = _conv_blocks(x.shape, w.shape, str(x.dtype), interpret)
    else:
        d_bh, d_bf = autotune.DEFAULT_BLOCKS["conv"]
        bh = d_bh if bh is None else bh
        bf = d_bf if bf is None else bf
    return _vwr_conv2d_jit(x, w, bias, bh=bh, bf=bf,
                           activation=activation, interpret=interpret)


def _conv_blocks(xshape, wshape, dtype, interpret):
    N, H, W, C = xshape
    KH, KW, _, F = wshape
    backend = _backend_tag(interpret)

    def runner(cand):
        bh, bf = cand
        xz = jnp.ones(xshape, jnp.dtype(dtype))
        wz = jnp.ones(wshape, jnp.dtype(dtype))

        def run():
            jax.block_until_ready(_vwr_conv2d_jit(
                xz, wz, None, bh=bh, bf=bf, activation=None,
                interpret=interpret))
        return run

    return autotune.get_blocks(
        "conv", (N, H, W, C, KH, KW, F), dtype, backend,
        candidates=autotune.conv_candidates(N, H, W, C, KH, KW, F,
                                            dtype),
        prior=lambda c: autotune.conv_prior(N, H, W, C, KH, KW, F,
                                            dtype, c),
        runner=runner if autotune.enabled() else None)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def vwr_depthwise(x, w, *, bh=8, interpret=None):
    """x: (N,H,W,C); w: (KH,KW,C); stride 1, VALID."""
    interpret = _auto_interpret(interpret)
    KH = w.shape[0]
    H_out = x.shape[1] - KH + 1
    bh_ = min(bh, H_out)
    pad_h = (-H_out) % bh_
    xp = x if pad_h == 0 else jnp.pad(
        x, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    out = vwr_depthwise_p(xp, w, bh=bh_, interpret=interpret)
    return out[:, :H_out]


# ======================================================================
# attention (zero-copy GQA)
# ======================================================================

@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def _vwr_attention_jit(q, k, v, *, causal, bq, bkv, interpret):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    # zero-copy GQA: K/V keep their native KV-head count; the kernel's
    # BlockSpec index map (b // G) routes each query head to its
    # group's shared KV head — no jnp.repeat materialization, so the
    # staged / resident K/V bytes are 1/G of the head-expanded layout
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, D)

    bq_ = min(bq, S)
    bkv_ = min(bkv, S)
    big = max(bq_, bkv_)
    assert big % min(bq_, bkv_) == 0, "bq and bkv must nest"
    if not causal:
        assert S % big == 0, "non-causal path needs S % block == 0"
    # pad to a common block multiple; padded kv rows sit at positions
    # beyond every real query position, so causal masking removes them
    qf = _pad_dim(qf, 1, big)
    kf = _pad_dim(kf, 1, big)
    vf = _pad_dim(vf, 1, big)

    out = vwr_attention_p(qf, kf, vf, causal=causal, bq=bq_, bkv=bkv_,
                          g=G, interpret=interpret)
    out = out[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return out


def vwr_attention(q, k, v, *, causal=True, bq=None, bkv=None,
                  interpret=None):
    """q: (B,S,H,D); k,v: (B,S,KV,D) (GQA: KV divides H, served
    zero-copy).  Causal masks use true positions, so KV-padding to
    block multiples is masked out by construction for causal=True; for
    causal=False we require S % block == 0 (asserted).  With both
    bq/bkv unspecified the autotuner resolves them; pinning one keeps
    the pin and mirrors it onto the other (equal blocks always satisfy
    the nesting constraint, whatever S clamps them to)."""
    interpret = _auto_interpret(interpret)
    B, S, H, D = q.shape
    KV = k.shape[2]
    if bq is None and bkv is None:
        bq, bkv = _attention_blocks(B, S, H, KV, D, str(q.dtype), causal,
                                    interpret)
    elif bq is None:
        bq = bkv          # mirror the pin: equal blocks always nest,
    elif bkv is None:     # whatever S clamps them to
        bkv = bq
    return _vwr_attention_jit(q, k, v, causal=causal, bq=bq, bkv=bkv,
                              interpret=interpret)


# ======================================================================
# flash decode (one token vs a cache shard; unnormalized partials)
# ======================================================================

@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def _vwr_flash_decode_jit(q, k, v, lens, *, bkv, interpret):
    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # zero-copy GQA: the q "block" is the whole group sharing one KV
    # head (heads are kv-major: h = kv * G + g, matching
    # models.attention.flash_decode_partial)
    qf = q.reshape(B * KV, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    bkv_ = min(bkv, T)
    kf = _pad_dim(kf, 1, bkv_)
    vf = _pad_dim(vf, 1, bkv_)
    o_t, m, l = vwr_flash_decode_p(qf, kf, vf, lens, bkv=bkv_,
                                   t_valid=T, interpret=interpret)
    return (o_t.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def vwr_flash_decode(q, k, v, cur_len, pos0=0, *, bkv=None,
                     interpret=None):
    """Unnormalized flash-decode partials for one new token.

    q: (B, H, Dh); k, v: (B, T, KV, Dh) — a KV-cache (shard) whose
    first position has *global* index ``pos0``; ``cur_len`` counts the
    globally valid positions (both may be traced scalars: decode runs
    inside a jitted generation loop).  Returns fp32
    (o_tilde (B,H,Dh), m (B,H), l (B,H)) — the distributed-
    FlashDecoding combine contract (``dist.decode``); single-shard
    callers normalize with ``o_tilde / max(l, eps)``.  ``bkv``
    unspecified resolves via the autotuner."""
    interpret = _auto_interpret(interpret)
    B, T = q.shape[0], k.shape[1]
    H, KV, D = q.shape[1], k.shape[2], q.shape[2]
    if bkv is None:
        bkv = _decode_blocks(B, T, H, KV, D, str(q.dtype), interpret)[0]
    lens = jnp.stack([jnp.asarray(cur_len, jnp.int32).reshape(()),
                      jnp.asarray(pos0, jnp.int32).reshape(())]
                     ).reshape(1, 2)
    return _vwr_flash_decode_jit(q, k, v, lens, bkv=bkv,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vwr_paged_flash_decode_jit(q, k_pool, v_pool, table, counts, *,
                                interpret):
    B, H, D = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    qf = q.reshape(B * KV, G, D)
    # unallocated / foreign table entries carry count 0, so any legal
    # page index is safe to stage — clamp rather than branch
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_paged_flash_decode_p(
        qf, k_pool, v_pool, tbl, counts.astype(jnp.int32),
        interpret=interpret)
    return (o_t.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def vwr_paged_flash_decode(q, k_pool, v_pool, table, counts, *,
                           interpret=None):
    """Unnormalized flash-decode partials against a paged KV pool.

    q: (B, H, Dh); k_pool, v_pool: (n_pages, page_size, KV, Dh) — the
    shared page pool (possibly one shard's slab of it); table: (B,
    max_pages) int32 physical page per (slot, logical page); counts:
    (B, max_pages) int32 valid tokens per (slot, logical page) — 0
    masks a page completely.  Page size is the transaction width here
    (the engine owns it), so there is no block autotuning; the 'auto'
    dispatch backend still measures this wrapper against the XLA
    gather reference per shape.  Returns fp32 (o_tilde (B,H,Dh),
    m (B,H), l (B,H)), the ``dist.decode`` combine contract."""
    interpret = _auto_interpret(interpret)
    return _vwr_paged_flash_decode_jit(q, k_pool, v_pool, table, counts,
                                       interpret=interpret)


# ======================================================================
# split-operand MLA flash decode (latent + rope caches as separate
# operands; values taken from the latent block — no concat copies)
# ======================================================================

@functools.partial(jax.jit, static_argnames=("scale", "bkv", "interpret"))
def _vwr_mla_flash_decode_jit(q_abs, q_rope, c_kv, k_rope, lens, *,
                              scale, bkv, interpret):
    T = c_kv.shape[1]
    bkv_ = min(bkv, T)
    ckv = _pad_dim(c_kv, 1, bkv_)
    kr = _pad_dim(k_rope, 1, bkv_)
    return vwr_mla_flash_decode_p(q_abs, q_rope, ckv, kr, lens,
                                  scale=scale, bkv=bkv_, t_valid=T,
                                  interpret=interpret)


def vwr_mla_flash_decode(q_abs, q_rope, c_kv, k_rope, cur_len, pos0=0, *,
                         scale, bkv=None, interpret=None):
    """Unnormalized split-operand absorbed-MLA flash-decode partials.

    q_abs: (B, H, r) nope queries pre-folded through wk_b; q_rope:
    (B, H, rope); c_kv: (B, T, r) latent cache (shard); k_rope: (B, T,
    rope) rope-key cache; ``scale`` the absorbed 1/sqrt(nope+rope).
    The caches stay SEPARATE all the way into the kernel's BlockSpecs —
    staged cache bytes per token are (r + rope) features/position
    instead of the concatenated view's 2*(r + rope) (k_cat + zero-
    padded v_cat copies).  Returns fp32 (o_tilde (B, H, r), m (B, H),
    l (B, H)), the ``dist.decode`` combine contract.  ``bkv``
    unspecified resolves via the autotuner."""
    interpret = _auto_interpret(interpret)
    B, H, r = q_abs.shape
    T, rope = c_kv.shape[1], q_rope.shape[2]
    if bkv is None:
        bkv = _mla_decode_blocks(B, T, H, r, rope, str(c_kv.dtype),
                                 interpret)[0]
    lens = jnp.stack([jnp.asarray(cur_len, jnp.int32).reshape(()),
                      jnp.asarray(pos0, jnp.int32).reshape(())]
                     ).reshape(1, 2)
    return _vwr_mla_flash_decode_jit(q_abs, q_rope, c_kv, k_rope, lens,
                                     scale=scale, bkv=bkv,
                                     interpret=interpret)


def _mla_decode_blocks(B, T, H, r, rope, dtype, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bkv, = cand
        qa = jnp.ones((B, H, r), jnp.float32)
        qr = jnp.ones((B, H, rope), jnp.float32)
        ckv = jnp.ones((B, T, r), jnp.dtype(dtype))
        kr = jnp.ones((B, T, rope), jnp.dtype(dtype))
        lens = jnp.asarray([[T, 0]], jnp.int32)

        def run():
            jax.block_until_ready(_vwr_mla_flash_decode_jit(
                qa, qr, ckv, kr, lens, scale=1.0, bkv=bkv,
                interpret=interpret))
        return run

    # candidates/prior: the decode cost model with the staged feature
    # width r + rope (one latent + one rope block per grid step) and a
    # single shared KV head (the absorbed-MQA view)
    return autotune.get_blocks(
        "decode_mla", (B, T, H, r, rope), dtype, backend,
        candidates=autotune.decode_candidates(T, r + rope, dtype),
        prior=lambda c: autotune.decode_prior(B, T, H, 1, r + rope,
                                              dtype, c),
        runner=runner if autotune.enabled() else None)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _vwr_mla_paged_flash_decode_jit(q_abs, q_rope, ckv_pool, krope_pool,
                                    table, counts, *, scale, interpret):
    n_pages = ckv_pool.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    return vwr_mla_paged_flash_decode_p(
        q_abs, q_rope, ckv_pool, krope_pool, tbl,
        counts.astype(jnp.int32), scale=scale, interpret=interpret)


def vwr_mla_paged_flash_decode(q_abs, q_rope, ckv_pool, krope_pool,
                               table, counts, *, scale, interpret=None):
    """Unnormalized split-operand absorbed-MLA partials over paged
    latent pools.

    q_abs: (B, H, r); q_rope: (B, H, rope); ckv_pool: (n_pages,
    page_size, r); krope_pool: (n_pages, page_size, rope); table,
    counts: (B, max_pages) int32 (count 0 masks a page completely).
    The pools stay separate into the kernel — no pool-wide k_cat/v_cat
    copies.  Page size is the transaction width (the engine owns it),
    so there is no block autotuning; 'auto' dispatch still measures
    this wrapper against the XLA gather reference per shape/geometry.
    Returns fp32 (o_tilde (B, H, r), m (B, H), l (B, H))."""
    interpret = _auto_interpret(interpret)
    return _vwr_mla_paged_flash_decode_jit(
        q_abs, q_rope, ckv_pool, krope_pool, table, counts, scale=scale,
        interpret=interpret)


def _decode_blocks(B, T, H, KV, D, dtype, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bkv, = cand
        qz = jnp.ones((B, H, D), jnp.dtype(dtype))
        kz = jnp.ones((B, T, KV, D), jnp.dtype(dtype))
        lens = jnp.asarray([[T, 0]], jnp.int32)

        def run():
            jax.block_until_ready(_vwr_flash_decode_jit(
                qz, kz, kz, lens, bkv=bkv, interpret=interpret))
        return run

    return autotune.get_blocks(
        "decode", (B, T, H, KV, D), dtype, backend,
        candidates=autotune.decode_candidates(T, D, dtype),
        prior=lambda c: autotune.decode_prior(B, T, H, KV, D, dtype, c),
        runner=runner if autotune.enabled() else None)


# ======================================================================
# q8 flash decode: int8 caches / page pools, fp32 scale sidecars,
# dequantized in-kernel on the staged block
# ======================================================================

@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def _vwr_flash_decode_q8_jit(q, k, v, k_scale, v_scale, lens, *, bkv,
                             interpret):
    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B * KV, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    ksf = k_scale.astype(jnp.float32).reshape(B * KV)
    vsf = v_scale.astype(jnp.float32).reshape(B * KV)
    bkv_ = min(bkv, T)
    kf = _pad_dim(kf, 1, bkv_)
    vf = _pad_dim(vf, 1, bkv_)
    o_t, m, l = vwr_flash_decode_q8_p(qf, kf, vf, ksf, vsf, lens,
                                      bkv=bkv_, t_valid=T,
                                      interpret=interpret)
    return (o_t.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def vwr_flash_decode_q8(q, k, v, k_scale, v_scale, cur_len, pos0=0, *,
                        bkv=None, interpret=None):
    """``vwr_flash_decode`` over an int8 cache with per-(B, KV) fp32
    scales: the staged cache block is 1 byte/feature in HBM and is
    dequantized in-kernel (scores/values rescaled after the int8
    dots).  Same (o_tilde, m, l) fp32 combine contract."""
    interpret = _auto_interpret(interpret)
    B, T = q.shape[0], k.shape[1]
    H, KV, D = q.shape[1], k.shape[2], q.shape[2]
    if bkv is None:
        bkv = _decode_blocks_q8(B, T, H, KV, D, interpret)[0]
    lens = jnp.stack([jnp.asarray(cur_len, jnp.int32).reshape(()),
                      jnp.asarray(pos0, jnp.int32).reshape(())]
                     ).reshape(1, 2)
    return _vwr_flash_decode_q8_jit(q, k, v, k_scale, v_scale, lens,
                                    bkv=bkv, interpret=interpret)


def _decode_blocks_q8(B, T, H, KV, D, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bkv, = cand
        qz = jnp.ones((B, H, D), jnp.float32)
        kz = jnp.ones((B, T, KV, D), jnp.int8)
        sz = jnp.ones((B, KV), jnp.float32)
        lens = jnp.asarray([[T, 0]], jnp.int32)

        def run():
            jax.block_until_ready(_vwr_flash_decode_q8_jit(
                qz, kz, kz, sz, sz, lens, bkv=bkv, interpret=interpret))
        return run

    # same op name as the bf16 path: the cache key's dtype field
    # ("int8") separates the entries, and _dtype_bytes(int8) == 1 feeds
    # the staged-bytes prior the halved traffic
    return autotune.get_blocks(
        "decode", (B, T, H, KV, D), "int8", backend,
        candidates=autotune.decode_candidates(T, D, "int8"),
        prior=lambda c: autotune.decode_prior(B, T, H, KV, D, "int8", c),
        runner=runner if autotune.enabled() else None)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vwr_paged_flash_decode_q8_jit(q, k_pool, v_pool, k_scale, v_scale,
                                   table, counts, *, interpret):
    B, H, D = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    qf = q.reshape(B * KV, G, D)
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_paged_flash_decode_q8_p(
        qf, k_pool, v_pool, k_scale.astype(jnp.float32),
        v_scale.astype(jnp.float32), tbl, counts.astype(jnp.int32),
        interpret=interpret)
    return (o_t.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def vwr_paged_flash_decode_q8(q, k_pool, v_pool, k_scale, v_scale,
                              table, counts, *, interpret=None):
    """``vwr_paged_flash_decode`` over int8 page pools.

    k_pool, v_pool: int8 (n_pages, page_size, KV, Dh); k_scale,
    v_scale: fp32 (n_pages, KV) sidecars riding the same block-table
    indirection as the pages (scalar-prefetch, resolved per grid
    step).  Staged cache traffic per token is halved vs bf16 pools;
    softmax math stays fp32."""
    interpret = _auto_interpret(interpret)
    return _vwr_paged_flash_decode_q8_jit(
        q, k_pool, v_pool, k_scale, v_scale, table, counts,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "bkv", "interpret"))
def _vwr_mla_flash_decode_q8_jit(q_abs, q_rope, c_kv, k_rope, ckv_scale,
                                 krope_scale, lens, *, scale, bkv,
                                 interpret):
    T = c_kv.shape[1]
    bkv_ = min(bkv, T)
    ckv = _pad_dim(c_kv, 1, bkv_)
    kr = _pad_dim(k_rope, 1, bkv_)
    return vwr_mla_flash_decode_q8_p(
        q_abs, q_rope, ckv, kr, ckv_scale.astype(jnp.float32),
        krope_scale.astype(jnp.float32), lens, scale=scale, bkv=bkv_,
        t_valid=T, interpret=interpret)


def vwr_mla_flash_decode_q8(q_abs, q_rope, c_kv, k_rope, ckv_scale,
                            krope_scale, cur_len, pos0=0, *, scale,
                            bkv=None, interpret=None):
    """``vwr_mla_flash_decode`` over int8 latent/rope caches with
    per-(B,) fp32 scales.  Same combine contract."""
    interpret = _auto_interpret(interpret)
    B, H, r = q_abs.shape
    T, rope = c_kv.shape[1], q_rope.shape[2]
    if bkv is None:
        bkv = _mla_decode_blocks_q8(B, T, H, r, rope, interpret)[0]
    lens = jnp.stack([jnp.asarray(cur_len, jnp.int32).reshape(()),
                      jnp.asarray(pos0, jnp.int32).reshape(())]
                     ).reshape(1, 2)
    return _vwr_mla_flash_decode_q8_jit(
        q_abs, q_rope, c_kv, k_rope, ckv_scale, krope_scale, lens,
        scale=scale, bkv=bkv, interpret=interpret)


def _mla_decode_blocks_q8(B, T, H, r, rope, interpret):
    backend = _backend_tag(interpret)

    def runner(cand):
        bkv, = cand
        qa = jnp.ones((B, H, r), jnp.float32)
        qr = jnp.ones((B, H, rope), jnp.float32)
        ckv = jnp.ones((B, T, r), jnp.int8)
        kr = jnp.ones((B, T, rope), jnp.int8)
        sz = jnp.ones((B,), jnp.float32)
        lens = jnp.asarray([[T, 0]], jnp.int32)

        def run():
            jax.block_until_ready(_vwr_mla_flash_decode_q8_jit(
                qa, qr, ckv, kr, sz, sz, lens, scale=1.0, bkv=bkv,
                interpret=interpret))
        return run

    return autotune.get_blocks(
        "decode_mla", (B, T, H, r, rope), "int8", backend,
        candidates=autotune.decode_candidates(T, r + rope, "int8"),
        prior=lambda c: autotune.decode_prior(B, T, H, 1, r + rope,
                                              "int8", c),
        runner=runner if autotune.enabled() else None)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _vwr_mla_paged_flash_decode_q8_jit(q_abs, q_rope, ckv_pool,
                                       krope_pool, ckv_scale,
                                       krope_scale, table, counts, *,
                                       scale, interpret):
    n_pages = ckv_pool.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    return vwr_mla_paged_flash_decode_q8_p(
        q_abs, q_rope, ckv_pool, krope_pool,
        ckv_scale.astype(jnp.float32), krope_scale.astype(jnp.float32),
        tbl, counts.astype(jnp.int32), scale=scale, interpret=interpret)


def vwr_mla_paged_flash_decode_q8(q_abs, q_rope, ckv_pool, krope_pool,
                                  ckv_scale, krope_scale, table, counts,
                                  *, scale, interpret=None):
    """``vwr_mla_paged_flash_decode`` over int8 latent page pools with
    per-page fp32 scales riding the block-table indirection."""
    interpret = _auto_interpret(interpret)
    return _vwr_mla_paged_flash_decode_q8_jit(
        q_abs, q_rope, ckv_pool, krope_pool, ckv_scale, krope_scale,
        table, counts, scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vwr_chunk_prefix_attend_jit(q, k_pool, v_pool, table, counts, *,
                                 interpret):
    C, H, D = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    qf = jnp.transpose(q.reshape(C, KV, G, D),
                       (1, 0, 2, 3)).reshape(KV, C * G, D)
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_chunk_prefix_attend_p(
        qf, k_pool, v_pool, tbl, counts.astype(jnp.int32),
        interpret=interpret)
    o_t = jnp.transpose(o_t.reshape(KV, C, G, D),
                        (1, 0, 2, 3)).reshape(C, H, D)
    m = jnp.transpose(m.reshape(KV, C, G), (1, 0, 2)).reshape(C, H)
    l = jnp.transpose(l.reshape(KV, C, G), (1, 0, 2)).reshape(C, H)
    return o_t, m, l


def vwr_chunk_prefix_attend(q, k_pool, v_pool, table, counts, *,
                            interpret=None):
    """Chunked-prefill prefix attention: a (C, H, Dh) query chunk
    against its prompt's PRIOR pages (earlier chunks / prefix-cache
    hits), each page staged once for all C queries.  table/counts:
    (J,) page ids + per-page valid token counts (0 masks a page
    entirely — e.g. pages another sequence shard owns).  Returns fp32
    partials (o_tilde (C,H,Dh), m (C,H), l (C,H)); the within-chunk
    causal block is combined downstream via the flash merge.  No block
    autotuning: page size is the transaction width (the engine owns
    it)."""
    interpret = _auto_interpret(interpret)
    return _vwr_chunk_prefix_attend_jit(q, k_pool, v_pool, table,
                                        counts, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vwr_chunk_prefix_attend_q8_jit(q, k_pool, v_pool, k_scale, v_scale,
                                    table, counts, *, interpret):
    C, H, D = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    qf = jnp.transpose(q.reshape(C, KV, G, D),
                       (1, 0, 2, 3)).reshape(KV, C * G, D)
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_chunk_prefix_attend_q8_p(
        qf, k_pool, v_pool, k_scale.astype(jnp.float32),
        v_scale.astype(jnp.float32), tbl, counts.astype(jnp.int32),
        interpret=interpret)
    o_t = jnp.transpose(o_t.reshape(KV, C, G, D),
                        (1, 0, 2, 3)).reshape(C, H, D)
    m = jnp.transpose(m.reshape(KV, C, G), (1, 0, 2)).reshape(C, H)
    l = jnp.transpose(l.reshape(KV, C, G), (1, 0, 2)).reshape(C, H)
    return o_t, m, l


def vwr_chunk_prefix_attend_q8(q, k_pool, v_pool, k_scale, v_scale,
                               table, counts, *, interpret=None):
    """``vwr_chunk_prefix_attend`` over int8 page pools with fp32
    (n_pages, KV) scale sidecars dequantized on the staged block."""
    interpret = _auto_interpret(interpret)
    return _vwr_chunk_prefix_attend_q8_jit(
        q, k_pool, v_pool, k_scale, v_scale, table, counts,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _vwr_mla_chunk_prefix_attend_jit(q_abs, q_rope, ckv_pool,
                                     krope_pool, table, counts, *,
                                     scale, interpret):
    C, H, r = q_abs.shape
    rope = q_rope.shape[2]
    n_pages = ckv_pool.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_mla_chunk_prefix_attend_p(
        q_abs.reshape(C * H, r), q_rope.reshape(C * H, rope),
        ckv_pool, krope_pool, tbl, counts.astype(jnp.int32),
        scale=scale, interpret=interpret)
    return (o_t.reshape(C, H, r), m.reshape(C, H), l.reshape(C, H))


def vwr_mla_chunk_prefix_attend(q_abs, q_rope, ckv_pool, krope_pool,
                                table, counts, *, scale,
                                interpret=None):
    """Split-operand MLA chunk-prefix attention: absorbed chunk
    queries q_abs (C,H,r) + q_rope (C,H,rope) against the latent page
    pools over the chunk's prior pages.  Same partial contract as
    ``vwr_chunk_prefix_attend``."""
    interpret = _auto_interpret(interpret)
    return _vwr_mla_chunk_prefix_attend_jit(
        q_abs, q_rope, ckv_pool, krope_pool, table, counts,
        scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def _vwr_mla_chunk_prefix_attend_q8_jit(q_abs, q_rope, ckv_pool,
                                        krope_pool, ckv_scale,
                                        krope_scale, table, counts, *,
                                        scale, interpret):
    C, H, r = q_abs.shape
    rope = q_rope.shape[2]
    n_pages = ckv_pool.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1).astype(jnp.int32)
    o_t, m, l = vwr_mla_chunk_prefix_attend_q8_p(
        q_abs.reshape(C * H, r), q_rope.reshape(C * H, rope),
        ckv_pool, krope_pool, ckv_scale.astype(jnp.float32),
        krope_scale.astype(jnp.float32), tbl,
        counts.astype(jnp.int32), scale=scale, interpret=interpret)
    return (o_t.reshape(C, H, r), m.reshape(C, H), l.reshape(C, H))


def vwr_mla_chunk_prefix_attend_q8(q_abs, q_rope, ckv_pool, krope_pool,
                                   ckv_scale, krope_scale, table,
                                   counts, *, scale, interpret=None):
    """``vwr_mla_chunk_prefix_attend`` over int8 latent pools with
    fp32 per-page scale sidecars."""
    interpret = _auto_interpret(interpret)
    return _vwr_mla_chunk_prefix_attend_q8_jit(
        q_abs, q_rope, ckv_pool, krope_pool, ckv_scale, krope_scale,
        table, counts, scale=scale, interpret=interpret)


def _attention_blocks(B, S, H, KV, D, dtype, causal, interpret):
    backend = _backend_tag(interpret)
    op = "attention_causal" if causal else "attention_full"

    def runner(cand):
        bq, bkv = cand
        qz = jnp.ones((B, S, H, D), jnp.dtype(dtype))
        kz = jnp.ones((B, S, KV, D), jnp.dtype(dtype))

        def run():
            jax.block_until_ready(_vwr_attention_jit(
                qz, kz, kz, causal=causal, bq=bq, bkv=bkv,
                interpret=interpret))
        return run

    return autotune.get_blocks(
        op, (B, S, H, KV, D), dtype, backend,
        candidates=autotune.attention_candidates(S, D, dtype, causal),
        prior=lambda c: autotune.attention_prior(B, S, H, KV, D, dtype, c),
        runner=runner if autotune.enabled() else None)
