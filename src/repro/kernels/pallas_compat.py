"""Version compatibility shims for the Pallas TPU API.

The TPU compiler-params dataclass was renamed across jax releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``) and its
constructor signature has drifted; kernels only use it for
``dimension_semantics``, so a best-effort builder keeps every kernel
importable on any supported jax.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*dimension_semantics: str):
    """Returns a compiler-params object carrying ``dimension_semantics``,
    or None when no compatible constructor exists (interpret mode and
    older Mosaic lowerings accept None)."""
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        return cls(dimension_semantics=tuple(dimension_semantics))
    except TypeError:
        return None


def halo_block_spec(block_shape, index_map, halo_dim: int):
    """BlockSpec for overlapping (halo'd) input windows, where
    ``index_map`` returns ELEMENT offsets along ``halo_dim`` and the
    remaining dims are either size-1 or full-extent (so block index ==
    element offset for them).  Newer jax spells this ``pl.Element`` on
    the halo dim; older jax uses whole-spec unblocked indexing — the
    same index map is valid under both conventions."""
    elem = getattr(pl, "Element", None)
    if elem is not None:
        shape = list(block_shape)
        shape[halo_dim] = elem(block_shape[halo_dim])
        return pl.BlockSpec(tuple(shape), index_map)
    return pl.BlockSpec(block_shape, index_map,
                        indexing_mode=pl.unblocked)
