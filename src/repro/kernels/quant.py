"""Shared symmetric-int8 quantization idiom.

One int8 recipe for the whole repo: ``scale = max(amax, eps) / 127``,
``q = clip(round(x / scale), -127, 127)``.  Consumers:

- ``dist/compression.py`` — per-tensor wire payloads for the
  compressed all-reduce (error feedback on top);
- ``engine/paged_cache.py`` — per-page (per-head) KV page pools with
  fp32 scale sidecars, dequantized inside the flash-decode kernels.

The eps floor makes an all-zero reduction group safe (scale stays
strictly positive, roundtrip returns exact zeros) and symmetric
clipping at +-127 keeps ``q(x) == -q(-x)`` exactly.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

# amax floor: keeps the scale strictly positive for all-zero groups so
# x/scale never divides by zero and dequant(quant(0)) == 0 exactly
QEPS = 1e-12

Axis = Union[None, int, Tuple[int, ...]]


def int8_scale(amax: jax.Array) -> jax.Array:
    """fp32 scale for a symmetric int8 grid covering [-amax, amax]."""
    return jnp.maximum(amax.astype(jnp.float32), QEPS) / 127.0


def quantize_int8(x: jax.Array,
                  axis: Axis = None) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 over ``axis`` groups (None = per-tensor).

    Returns ``(q int8, scale fp32)``.  With ``axis=None`` the scale is
    a scalar (the wire format ``dist.compression`` ships); with an
    axis/tuple the reduced dims are kept as size-1 so the scale
    broadcasts straight back against ``q`` for dequantization.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=axis is not None)
    scale = int8_scale(amax)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """``q * scale`` in fp32 (optionally cast to ``dtype``)."""
    out = q.astype(jnp.float32) * scale
    return out if dtype is None else out.astype(dtype)
