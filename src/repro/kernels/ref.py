"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (N,H,W,C); w: (KH,KW,C,F); stride 1, VALID -> (N,H',W',F)."""
    KH, KW = w.shape[:2]
    H_out = x.shape[1] - KH + 1
    W_out = x.shape[2] - KW + 1
    acc = jnp.zeros((x.shape[0], H_out, W_out, w.shape[3]), jnp.float32)
    for kj in range(KH):
        for ki in range(KW):
            patch = x[:, kj: kj + H_out, ki: ki + W_out, :]
            acc = acc + jnp.einsum(
                "nhwc,cf->nhwf", patch.astype(jnp.float32),
                w[kj, ki].astype(jnp.float32))
    return acc.astype(x.dtype)


def depthwise_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (N,H,W,C); w: (KH,KW,C); stride 1, VALID -> (N,H',W',C)."""
    KH, KW = w.shape[:2]
    H_out = x.shape[1] - KH + 1
    W_out = x.shape[2] - KW + 1
    acc = jnp.zeros((x.shape[0], H_out, W_out, x.shape[3]), jnp.float32)
    for kj in range(KH):
        for ki in range(KW):
            patch = x[:, kj: kj + H_out, ki: ki + W_out, :]
            acc = acc + patch.astype(jnp.float32) * \
                w[kj, ki].astype(jnp.float32)
    return acc.astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q,k,v: (BH, S, D) flat heads."""
    S = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
