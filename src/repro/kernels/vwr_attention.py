"""Flash-attention kernel with VWR-style wide KV staging + zero-copy GQA.

Attention at long context is the LM-era version of the paper's
streaming workload: the KV cache is read once per query block with
near-zero reuse, so the HBM<->VMEM transaction width decides
throughput.  Each grid step stages one wide (bkv x D) K/V block (the
ultra-wide transaction), against which the resident query block runs
two MXU matmuls and a running-softmax update whose fp32 accumulators
(acc, m, l) live in VMEM scratch — the R1-R4 local registers of §4.3.5.

GQA is zero-copy: K/V stay at their native (B*KV, S, D) shape in HBM
and the K/V BlockSpec index map routes each of the G query heads in a
group to the one shared KV head (block index ``b // g``).  No
``jnp.repeat`` materialization — the HBM footprint and the staged
bytes per distinct KV element drop by the group factor G, which is
exactly the paper's access-ratio argument: one wide KV line serves G
narrow consumers.

q: (B*H, S, D); k, v: (B*KV, S, D) flattened heads; causal optional.
Grid: (B*H, q-blocks, kv-blocks), kv innermost (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale, causal, bq, bkv, n_kv):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))       # (bq,)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        pv = jnp.dot(p, v_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    if causal:
        # skip fully-masked kv blocks (above the causal diagonal)
        pl.when(j * bkv <= i * bq + bq - 1)(body)
    else:
        body()

    @pl.when(j == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def vwr_attention_p(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bkv: int = 512,
                    g: int = 1, interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k, v: (BH // g, S, D) — g query heads share each
    KV head (zero-copy GQA; g=1 is plain MHA).  S % bq == 0 and
    S % bkv == 0 (ops pads)."""
    BH, S, D = q.shape
    BKV = k.shape[0]
    assert BH == BKV * g and v.shape == k.shape
    assert S % bq == 0 and S % bkv == 0
    n_kv = S // bkv
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               bq=bq, bkv=bkv, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            # b // g: query head b reads its group's shared KV head —
            # since g divides the per-batch head count, the flattened
            # (batch*H + h) // g == batch*KV + h // g identity holds.
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            "parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v)
