"""Direct (im2col-free) conv2d kernel — the paper's §6.1 dataflow on TPU.

The paper's complaint (§3.3): mapping conv onto GEMM hardware needs
im2col, inflating a 7x7/256^2 conv by x46.  Its fix: a fine-grained
shuffler slides the data instead.  On TPU the same idea is a Pallas
kernel that stages one *halo'd* input row-block in VMEM (the ultra-wide
transaction; `pl.Element` indexing gives the K-1-row halo of §6.2.1's
duplication argument) and accumulates over kernel taps with *static
shifted slices* of that staged block — the VREG-level analogue of the
VFU shuffler's one-lane shifts.  Zero data inflation in HBM: each
input element is read exactly once per row-block.

Fused epilogue: ``bias`` add and ``activation`` (relu/gelu/silu) are
applied to the fp32 accumulator before the single store — a CNN's
conv -> bias -> relu chain costs exactly one HBM round-trip for the
output instead of write + re-read + re-write (the extra elementwise
pass the ProVet CNN demo used to pay).

x: (N, H, W, C), w: (KH, KW, C, F), stride 1, VALID.
Grid: (batch, row-blocks, F-blocks); taps unrolled inside the kernel
(KH*KW MXU calls per staged block — the N-reads-per-wide-transaction
ratio of §4.3.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import halo_block_spec, tpu_compiler_params
from repro.kernels.vwr_matmul import ACTIVATIONS


def _conv_kernel(x_ref, w_ref, *rest, KH, KW, bh, W_out, has_bias,
                 activation):
    o_ref = rest[-1]
    b_ref = rest[0] if has_bias else None
    x = x_ref[0]                                   # (bh+KH-1, W, C)
    C = x.shape[-1]
    bf = w_ref.shape[-1]
    acc = jnp.zeros((bh * W_out, bf), jnp.float32)
    for kj in range(KH):
        for ki in range(KW):
            xs = x[kj: kj + bh, ki: ki + W_out, :]          # lane shift
            acc += jnp.dot(xs.reshape(bh * W_out, C), w_ref[kj, ki],
                           preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[...].astype(jnp.float32)          # (1,bf) bcast
    if activation is not None:
        acc = ACTIVATIONS[activation](acc)
    o_ref[0] = acc.reshape(bh, W_out, bf).astype(o_ref.dtype)


def vwr_conv2d_p(x: jax.Array, w: jax.Array, bias=None, *, bh: int = 8,
                 bf: int = 128, activation: str = None,
                 interpret: bool = False) -> jax.Array:
    """x: (N, H, W, C) with (H-KH+1) % bh == 0; w: (KH, KW, C, F) with
    F % bf == 0 (ops.vwr_conv2d pads).  Optional fused epilogue: bias
    (1, F) and activation name applied on the fp32 accumulator before
    the store.  Returns (N, H', W', F)."""
    N, H, W, C = x.shape
    KH, KW, C2, F = w.shape
    assert C == C2
    H_out, W_out = H - KH + 1, W - KW + 1
    assert H_out % bh == 0 and F % bf == 0, (H_out, bh, F, bf)
    assert activation is None or activation in ACTIVATIONS, activation
    kernel = functools.partial(_conv_kernel, KH=KH, KW=KW, bh=bh,
                               W_out=W_out, has_bias=bias is not None,
                               activation=activation)
    in_specs = [
        halo_block_spec((1, bh + KH - 1, W, C),
                        lambda n, r, f: (n, r * bh, 0, 0),
                        halo_dim=1),
        pl.BlockSpec((KH, KW, C, bf), lambda n, r, f: (0, 0, 0, f)),
    ]
    operands = [x, w]
    if bias is not None:
        assert bias.shape == (1, F), bias.shape
        in_specs.append(pl.BlockSpec((1, bf), lambda n, r, f: (0, f)))
        operands.append(bias)
    params = tpu_compiler_params("parallel", "parallel", "parallel")
    return pl.pallas_call(
        kernel,
        grid=(N, H_out // bh, F // bf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, W_out, bf),
                               lambda n, r, f: (n, r, 0, f)),
        out_shape=jax.ShapeDtypeStruct((N, H_out, W_out, F), x.dtype),
        compiler_params=params,
        interpret=interpret,
    )(*operands)
