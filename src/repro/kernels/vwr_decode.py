"""Flash-decode kernel: one query token vs a (possibly sharded) cache.

Decode is the zero-reuse end of the paper's streaming spectrum — every
cached (T, Dh) K/V element is read exactly once per generated token, so
the only lever is transaction width: each grid step stages one wide
(bkv x Dh) cache block in VMEM (the ultra-wide transaction) and the
whole head group consumes it before the next fetch.  GQA is zero-copy
as in ``vwr_attention``: the q block is the *group* (G query heads that
share one KV head), so the staged cache bytes per group are 1/G of the
head-expanded layout.

Unlike the prefill kernel this one returns the **unnormalized** online-
softmax partials (o_tilde, m, l) rather than the normalized context:
that is the combine contract of distributed FlashDecoding
(``dist.decode``), where each model shard holds a slab of the cache
starting at global position ``pos0`` and only the (B, H) statistics
cross the interconnect.  Single-device callers normalize with
``o_tilde / max(l, eps)``.

q: (B*KV, G, Dh); k, v: (B*KV, Tp, Dh) flattened kv heads, Tp padded
to a bkv multiple; lens: (1, 2) int32 [cur_len, pos0] (dynamic —
decode runs inside a jitted generation loop).  Grid: (B*KV, kv-blocks),
kv innermost (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, lens_ref, ot_ref, m_ref, l_ref,
                   acc_ref, ms_ref, ls_ref, *, scale, bkv, t_valid,
                   n_kv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    cur = lens_ref[0, 0]
    pos0 = lens_ref[0, 1]
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    idx = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # idx < t_valid masks the block-multiple padding; pos0 + idx < cur
    # masks positions not yet written (and, sharded, positions owned by
    # other shards' slabs never appear here at all)
    valid = (idx < t_valid) & (pos0 + idx < cur)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def _paged_decode_kernel(tbl_ref, cnt_ref, q_ref, k_ref, v_ref,
                         ot_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref,
                         *, scale, page_size, n_logical, kv_heads):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[b // kv_heads, j]                   # tokens valid here
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (page_size, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # count == 0 masks the whole page: a logical page past the slot's
    # length, an unallocated table entry, or (sharded) a page owned by
    # another shard's slab — the caller folds all three into counts
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0, :, 0, :].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def _mla_decode_kernel(qa_ref, qr_ref, ckv_ref, kr_ref, lens_ref,
                       ot_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref,
                       *, scale, bkv, t_valid, n_kv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    cur = lens_ref[0, 0]
    pos0 = lens_ref[0, 1]
    qa = qa_ref[0].astype(jnp.float32) * scale          # (H, r)
    qr = qr_ref[0].astype(jnp.float32) * scale          # (H, rope)
    ckv = ckv_ref[0].astype(jnp.float32)                # (bkv, r)
    kr = kr_ref[0].astype(jnp.float32)                  # (bkv, rope)
    # split-operand score: the latent block carries BOTH the key's nope
    # part (absorbed) and the values, the rope block only its 64-ish
    # rope features — no k_cat/v_cat concat copies, no value zero-pad
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    idx = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (idx < t_valid) & (pos0 + idx < cur)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_flash_decode_p(q_abs: jax.Array, q_rope: jax.Array,
                           c_kv: jax.Array, k_rope: jax.Array,
                           lens: jax.Array, *, scale: float, bkv: int,
                           t_valid: int, interpret: bool = False):
    """Split-operand absorbed-MLA flash decode (the MQA KV=1 problem).

    The latent and rope-key caches ride in as SEPARATE BlockSpec
    operands: each grid step stages one (bkv x r) latent block and one
    (bkv x rope) rope block, computes ``s = q_abs.c_kv + q_rope.k_rope``
    and takes values directly from the latent block — so the staged
    cache bytes per token are exactly ``r + rope`` features/position,
    vs the concatenated-MQA view's ``2*(r + rope)`` (one k_cat copy +
    one zero-padded v_cat copy of the cache, rebuilt every step).

    q_abs: (B, H, r) nope queries folded through wk_b; q_rope: (B, H,
    rope); c_kv: (B, Tp, r); k_rope: (B, Tp, rope), Tp padded to a bkv
    multiple; lens: (1, 2) int32 [cur_len, pos0]; ``scale`` the
    absorbed-MLA 1/sqrt(nope+rope).  Returns fp32 (o_tilde (B, H, r),
    m (B, H), l (B, H)) — the same unnormalized combine contract as
    ``vwr_flash_decode_p``.
    """
    B, H, r = q_abs.shape
    rope = q_rope.shape[2]
    Tp = c_kv.shape[1]
    assert q_rope.shape == (B, H, rope)
    assert c_kv.shape == (B, Tp, r) and k_rope.shape == (B, Tp, rope)
    assert Tp % bkv == 0, (Tp, bkv)
    n_kv = Tp // bkv
    kernel = functools.partial(_mla_decode_kernel, scale=scale, bkv=bkv,
                               t_valid=t_valid, n_kv=n_kv)
    f32 = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=(B, n_kv),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H, rope), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bkv, r), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, rope), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 2), lambda b, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, r), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, r), f32),
            jax.ShapeDtypeStruct((B, H), f32),
            jax.ShapeDtypeStruct((B, H), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, r), f32),
            pltpu.VMEM((H, 1), f32),
            pltpu.VMEM((H, 1), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(q_abs, q_rope, c_kv, k_rope, lens)


def _mla_paged_decode_kernel(tbl_ref, cnt_ref, qa_ref, qr_ref, ckv_ref,
                             kr_ref, ot_ref, m_ref, l_ref, acc_ref,
                             ms_ref, ls_ref, *, scale, n_logical):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[b, j]                               # tokens valid here
    qa = qa_ref[0].astype(jnp.float32) * scale          # (H, r)
    qr = qr_ref[0].astype(jnp.float32) * scale          # (H, rope)
    ckv = ckv_ref[0].astype(jnp.float32)                # (ps, r)
    kr = kr_ref[0].astype(jnp.float32)                  # (ps, rope)
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_paged_flash_decode_p(q_abs: jax.Array, q_rope: jax.Array,
                                 ckv_pool: jax.Array,
                                 krope_pool: jax.Array,
                                 table: jax.Array, counts: jax.Array, *,
                                 scale: float, interpret: bool = False):
    """Split-operand absorbed-MLA flash decode over paged latent pools.

    The paged sibling of ``vwr_mla_flash_decode_p``: the block table
    rides in as a scalar-prefetch operand and each (slot, logical-page)
    grid step stages ONE physical latent page (page_size x r) plus its
    rope page (page_size x rope) — the concat-MQA view instead rebuilt
    k_cat/v_cat copies of the whole POOL every decode step.

    q_abs: (B, H, r); q_rope: (B, H, rope); ckv_pool: (n_pages,
    page_size, r); krope_pool: (n_pages, page_size, rope); table,
    counts: (B, max_pages) int32, table pre-clamped to [0, n_pages).
    Returns fp32 (o_tilde (B, H, r), m (B, H), l (B, H)).
    """
    B, H, r = q_abs.shape
    rope = q_rope.shape[2]
    n_pages, ps, _ = ckv_pool.shape
    assert krope_pool.shape == (n_pages, ps, rope)
    Bt, J = table.shape
    assert Bt == B and counts.shape == (B, J), (table.shape, B)
    kernel = functools.partial(_mla_paged_decode_kernel, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # table, counts
        grid=(B, J),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, H, rope), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, ps, r),
                         lambda b, j, tbl, cnt: (tbl[b, j], 0, 0)),
            pl.BlockSpec((1, ps, rope),
                         lambda b, j, tbl, cnt: (tbl[b, j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, r), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, tbl, cnt: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j, tbl, cnt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, r), f32),
            pltpu.VMEM((H, 1), f32),
            pltpu.VMEM((H, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, r), f32),
            jax.ShapeDtypeStruct((B, H), f32),
            jax.ShapeDtypeStruct((B, H), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, q_abs, q_rope, ckv_pool, krope_pool)


def vwr_paged_flash_decode_p(q: jax.Array, k_pool: jax.Array,
                             v_pool: jax.Array, table: jax.Array,
                             counts: jax.Array, *,
                             interpret: bool = False):
    """Flash-decode over a paged KV pool, one staged page per grid step.

    The block table IS the transaction schedule: it rides in as a
    scalar-prefetch operand, so each (slot, logical-page) grid step's
    BlockSpec index map resolves ``table[slot, j]`` *before* the DMA
    fires and stages exactly that physical (page_size x Dh) page in
    VMEM — the gather never materializes in HBM.  ``counts[slot, j]``
    is the number of valid tokens in that page (0 masks the page
    entirely — length overrun, unallocated entry, or a page owned by
    another shard's slab).

    q: (B*KV, G, Dh); k_pool, v_pool: (n_pages, page_size, KV, Dh);
    table, counts: (B, max_pages) int32, table pre-clamped to
    [0, n_pages).  Returns (o_tilde (BKV, G, Dh) f32, m (BKV, G) f32,
    l (BKV, G) f32) — the same unnormalized combine contract as
    ``vwr_flash_decode_p``.
    """
    BKV, G, D = q.shape
    n_pages, ps, KV, Dp = k_pool.shape
    assert v_pool.shape == k_pool.shape and Dp == D
    assert BKV % KV == 0, (BKV, KV)
    B, J = table.shape
    assert counts.shape == (B, J) and B * KV == BKV, (table.shape, BKV)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=ps, n_logical=J, kv_heads=KV)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # table, counts
        grid=(BKV, J),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt:
                         (tbl[b // KV, j], 0, b % KV, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt:
                         (tbl[b // KV, j], 0, b % KV, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, q, k_pool, v_pool)


# ======================================================================
# q8 variants: int8 caches/pools with fp32 scale sidecars
# ======================================================================
#
# The staged cache block stays int8 all the way into VMEM — HBM traffic
# per token is 1 byte/feature instead of 2 (bf16) — and dequantization
# happens INSIDE the kernel on the staged block.  Because every scale
# is constant over the staged block (per sequence for dense, per
# physical page for paged), the dequant multiplies hoist through the
# dots exactly: ``q.(k*s) == (q.k)*s`` and ``p@(v*s) == (p@v)*s``, so
# the int8 path adds one scalar multiply per staged block, not one per
# staged element.  Scales ride as scalar-prefetch operands next to the
# block table, resolved by the same index arithmetic as the page DMA.
# Softmax/accumulate math is fp32 throughout, as in the bf16 kernels.


def _decode_kernel_q8(ks_ref, vs_ref, q_ref, k_ref, v_ref, lens_ref,
                      ot_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref, *,
                      scale, bkv, t_valid, n_kv):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    cur = lens_ref[0, 0]
    pos0 = lens_ref[0, 1]
    ks = ks_ref[b]                                      # per-row scales
    vs = vs_ref[b]
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, Dh) int8
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * ks
    idx = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (idx < t_valid) & (pos0 + idx < cur)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0].astype(jnp.float32),
                 preferred_element_type=jnp.float32) * vs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_flash_decode_q8_p(q: jax.Array, k: jax.Array, v: jax.Array,
                          k_scale: jax.Array, v_scale: jax.Array,
                          lens: jax.Array, *, bkv: int, t_valid: int,
                          interpret: bool = False):
    """int8 dense flash decode: k, v int8 (BKV, Tp, Dh); k_scale,
    v_scale (BKV,) fp32 per flattened kv-head row.  Same unnormalized
    (o_tilde, m, l) fp32 contract as ``vwr_flash_decode_p``."""
    BKV, G, D = q.shape
    Tp = k.shape[1]
    assert k.shape == (BKV, Tp, D) and v.shape == k.shape
    assert k_scale.shape == (BKV,) and v_scale.shape == (BKV,)
    assert Tp % bkv == 0, (Tp, bkv)
    n_kv = Tp // bkv
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_decode_kernel_q8, scale=scale, bkv=bkv,
                               t_valid=t_valid, n_kv=n_kv)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # k_scale, v_scale
        grid=(BKV, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, ks, vs: (b, 0, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j, ks, vs: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j, ks, vs: (b, j, 0)),
            pl.BlockSpec((1, 2), lambda b, j, ks, vs: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, ks, vs: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j, ks, vs: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j, ks, vs: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(k_scale, v_scale, q, k, v, lens)


def _paged_decode_kernel_q8(tbl_ref, cnt_ref, ks_ref, vs_ref, q_ref,
                            k_ref, v_ref, ot_ref, m_ref, l_ref, acc_ref,
                            ms_ref, ls_ref, *, scale, page_size,
                            n_logical, kv_heads):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[b // kv_heads, j]                   # tokens valid here
    page = tbl_ref[b // kv_heads, j]
    ks = ks_ref[page, b % kv_heads]                     # per-page per-head
    vs = vs_ref[page, b % kv_heads]
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (ps, Dh) int8
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * ks
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0, :, 0, :].astype(jnp.float32),
                 preferred_element_type=jnp.float32) * vs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_paged_flash_decode_q8_p(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, k_scale: jax.Array,
                                v_scale: jax.Array, table: jax.Array,
                                counts: jax.Array, *,
                                interpret: bool = False):
    """Flash decode over int8 page pools with per-page per-head scales.

    k_pool, v_pool: int8 (n_pages, page_size, KV, Dh); k_scale,
    v_scale: fp32 (n_pages, KV) sidecars resolved through the SAME
    ``table[slot, j]`` scalar-prefetch indirection as the page DMA.
    Everything else matches ``vwr_paged_flash_decode_p``.
    """
    BKV, G, D = q.shape
    n_pages, ps, KV, Dp = k_pool.shape
    assert v_pool.shape == k_pool.shape and Dp == D
    assert k_scale.shape == (n_pages, KV), (k_scale.shape, k_pool.shape)
    assert v_scale.shape == (n_pages, KV)
    assert BKV % KV == 0, (BKV, KV)
    B, J = table.shape
    assert counts.shape == (B, J) and B * KV == BKV, (table.shape, BKV)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_paged_decode_kernel_q8, scale=scale,
                               page_size=ps, n_logical=J, kv_heads=KV)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,          # table, counts, k_scale, v_scale
        grid=(BKV, J),
        in_specs=[
            pl.BlockSpec((1, G, D),
                         lambda b, j, tbl, cnt, ks, vs: (b, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt, ks, vs:
                         (tbl[b // KV, j], 0, b % KV, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt, ks, vs:
                         (tbl[b // KV, j], 0, b % KV, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D),
                         lambda b, j, tbl, cnt, ks, vs: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt, ks, vs: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt, ks, vs: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, k_scale, v_scale, q, k_pool, v_pool)


def _mla_decode_kernel_q8(cs_ref, rs_ref, qa_ref, qr_ref, ckv_ref,
                          kr_ref, lens_ref, ot_ref, m_ref, l_ref,
                          acc_ref, ms_ref, ls_ref, *, scale, bkv,
                          t_valid, n_kv):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    cur = lens_ref[0, 0]
    pos0 = lens_ref[0, 1]
    cs = cs_ref[b]                                      # latent scale
    rs = rs_ref[b]                                      # rope-key scale
    qa = qa_ref[0].astype(jnp.float32) * scale          # (H, r)
    qr = qr_ref[0].astype(jnp.float32) * scale          # (H, rope)
    ckv = ckv_ref[0].astype(jnp.float32)                # (bkv, r) int8
    kr = kr_ref[0].astype(jnp.float32)                  # (bkv, rope) int8
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cs
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * rs
    idx = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (idx < t_valid) & (pos0 + idx < cur)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32) * cs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_flash_decode_q8_p(q_abs: jax.Array, q_rope: jax.Array,
                              c_kv: jax.Array, k_rope: jax.Array,
                              ckv_scale: jax.Array,
                              krope_scale: jax.Array, lens: jax.Array,
                              *, scale: float, bkv: int, t_valid: int,
                              interpret: bool = False):
    """int8 split-operand MLA flash decode: c_kv, k_rope int8
    (B, Tp, .); ckv_scale, krope_scale (B,) fp32.  Same contract as
    ``vwr_mla_flash_decode_p``."""
    B, H, r = q_abs.shape
    rope = q_rope.shape[2]
    Tp = c_kv.shape[1]
    assert q_rope.shape == (B, H, rope)
    assert c_kv.shape == (B, Tp, r) and k_rope.shape == (B, Tp, rope)
    assert ckv_scale.shape == (B,) and krope_scale.shape == (B,)
    assert Tp % bkv == 0, (Tp, bkv)
    n_kv = Tp // bkv
    kernel = functools.partial(_mla_decode_kernel_q8, scale=scale,
                               bkv=bkv, t_valid=t_valid, n_kv=n_kv)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # ckv_scale, krope_scale
        grid=(B, n_kv),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, j, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, H, rope), lambda b, j, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, bkv, r), lambda b, j, cs, rs: (b, j, 0)),
            pl.BlockSpec((1, bkv, rope), lambda b, j, cs, rs: (b, j, 0)),
            pl.BlockSpec((1, 2), lambda b, j, cs, rs: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, r), lambda b, j, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, cs, rs: (b, 0)),
            pl.BlockSpec((1, H), lambda b, j, cs, rs: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, r), f32),
            pltpu.VMEM((H, 1), f32),
            pltpu.VMEM((H, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, r), f32),
            jax.ShapeDtypeStruct((B, H), f32),
            jax.ShapeDtypeStruct((B, H), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(ckv_scale, krope_scale, q_abs, q_rope, c_kv, k_rope, lens)


def _mla_paged_decode_kernel_q8(tbl_ref, cnt_ref, cs_ref, rs_ref,
                                qa_ref, qr_ref, ckv_ref, kr_ref, ot_ref,
                                m_ref, l_ref, acc_ref, ms_ref, ls_ref,
                                *, scale, n_logical):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[b, j]                               # tokens valid here
    page = tbl_ref[b, j]
    cs = cs_ref[page]                                   # per-page scales
    rs = rs_ref[page]
    qa = qa_ref[0].astype(jnp.float32) * scale          # (H, r)
    qr = qr_ref[0].astype(jnp.float32) * scale          # (H, rope)
    ckv = ckv_ref[0].astype(jnp.float32)                # (ps, r) int8
    kr = kr_ref[0].astype(jnp.float32)                  # (ps, rope) int8
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cs
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * rs
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32) * cs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_paged_flash_decode_q8_p(q_abs: jax.Array, q_rope: jax.Array,
                                    ckv_pool: jax.Array,
                                    krope_pool: jax.Array,
                                    ckv_scale: jax.Array,
                                    krope_scale: jax.Array,
                                    table: jax.Array, counts: jax.Array,
                                    *, scale: float,
                                    interpret: bool = False):
    """Split-operand MLA flash decode over int8 latent page pools.

    ckv_pool: int8 (n_pages, page_size, r); krope_pool: int8 (n_pages,
    page_size, rope); ckv_scale, krope_scale: fp32 (n_pages,) sidecars
    resolved through ``table[b, j]``.  Same contract as
    ``vwr_mla_paged_flash_decode_p``.
    """
    B, H, r = q_abs.shape
    rope = q_rope.shape[2]
    n_pages, ps, _ = ckv_pool.shape
    assert krope_pool.shape == (n_pages, ps, rope)
    assert ckv_scale.shape == (n_pages,) and \
        krope_scale.shape == (n_pages,)
    Bt, J = table.shape
    assert Bt == B and counts.shape == (B, J), (table.shape, B)
    kernel = functools.partial(_mla_paged_decode_kernel_q8, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # table, counts, ckv_scale, kr_scale
        grid=(B, J),
        in_specs=[
            pl.BlockSpec((1, H, r),
                         lambda b, j, tbl, cnt, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, H, rope),
                         lambda b, j, tbl, cnt, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, ps, r),
                         lambda b, j, tbl, cnt, cs, rs:
                         (tbl[b, j], 0, 0)),
            pl.BlockSpec((1, ps, rope),
                         lambda b, j, tbl, cnt, cs, rs:
                         (tbl[b, j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, r),
                         lambda b, j, tbl, cnt, cs, rs: (b, 0, 0)),
            pl.BlockSpec((1, H),
                         lambda b, j, tbl, cnt, cs, rs: (b, 0)),
            pl.BlockSpec((1, H),
                         lambda b, j, tbl, cnt, cs, rs: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, r), f32),
            pltpu.VMEM((H, 1), f32),
            pltpu.VMEM((H, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, r), f32),
            jax.ShapeDtypeStruct((B, H), f32),
            jax.ShapeDtypeStruct((B, H), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, ckv_scale, krope_scale, q_abs, q_rope, ckv_pool,
      krope_pool)


# ----------------------------------------------------------------------
# chunked prefill: a (C, d) query chunk against the paged pool
# ----------------------------------------------------------------------
#
# Chunked prefill attends C chunk queries (one in-flight prompt's next
# slice) against the PRIOR pages of that prompt — earlier chunks and
# prefix-cache hits already resident in the pool via the block table.
# The payoff vs replaying the decode kernel C times: each prior page is
# staged from HBM ONCE for all C queries (C·G rows ride the VMEM
# resident block), so staged bytes per chunk are ~1/C of the per-row
# decode cost.  The within-chunk causal self-attention block is a tiny
# (C, C) problem handled outside (models.attention combines the two
# partials with the flash merge), so these kernels mask only by the
# per-page valid counts — which also lets dist.decode zero out pages a
# shard does not own.

def _chunk_prefix_kernel(tbl_ref, cnt_ref, q_ref, k_ref, v_ref,
                         ot_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref,
                         *, scale, n_logical):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[j]                                  # tokens valid here
    q = q_ref[0].astype(jnp.float32) * scale            # (C*G, D)
    k = k_ref[0, :, 0, :]                               # (ps, D)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (C*G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_chunk_prefix_attend_p(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, table: jax.Array,
                              counts: jax.Array, *,
                              interpret: bool = False):
    """Chunk-prefix flash attention over the paged pool.

    q: (KV, C*G, D) chunk queries flattened per KV head (C = chunk
    tokens, G = H // KV); table: (J,) physical page ids of the chunk's
    PRIOR pages in prefix order; counts: (J,) valid tokens per page
    (page_size for full prior pages, 0 for pages a shard does not
    own).  Returns fp32 partials (o_tilde (KV, C*G, D), m (KV, C*G),
    l (KV, C*G)) under the shared flash combine contract.
    """
    KV, CG, D = q.shape
    n_pages, ps, KVp, _ = k_pool.shape
    assert KVp == KV, (KVp, KV)
    J, = table.shape
    assert counts.shape == (J,), (counts.shape, J)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_chunk_prefix_kernel, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # table, counts
        grid=(KV, J),
        in_specs=[
            pl.BlockSpec((1, CG, D), lambda kv, j, tbl, cnt: (kv, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda kv, j, tbl, cnt: (tbl[j], 0, kv, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda kv, j, tbl, cnt: (tbl[j], 0, kv, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, CG, D), lambda kv, j, tbl, cnt: (kv, 0, 0)),
            pl.BlockSpec((1, CG), lambda kv, j, tbl, cnt: (kv, 0)),
            pl.BlockSpec((1, CG), lambda kv, j, tbl, cnt: (kv, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((CG, D), f32),
            pltpu.VMEM((CG, 1), f32),
            pltpu.VMEM((CG, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((KV, CG, D), f32),
            jax.ShapeDtypeStruct((KV, CG), f32),
            jax.ShapeDtypeStruct((KV, CG), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, q, k_pool, v_pool)


def _chunk_prefix_kernel_q8(tbl_ref, cnt_ref, ks_ref, vs_ref, q_ref,
                            k_ref, v_ref, ot_ref, m_ref, l_ref,
                            acc_ref, ms_ref, ls_ref, *, scale,
                            n_logical):
    kv = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[j]
    page = tbl_ref[j]
    ks = ks_ref[page, kv]                               # per-page scales
    vs = vs_ref[page, kv]
    q = q_ref[0].astype(jnp.float32) * scale            # (C*G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (ps, D) int8
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * ks
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v, preferred_element_type=jnp.float32) * vs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_chunk_prefix_attend_q8_p(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, k_scale: jax.Array,
                                 v_scale: jax.Array, table: jax.Array,
                                 counts: jax.Array, *,
                                 interpret: bool = False):
    """``vwr_chunk_prefix_attend_p`` over int8 page pools with fp32
    (n_pages, KV) scale sidecars, dequantized on the staged block."""
    KV, CG, D = q.shape
    n_pages, ps, KVp, _ = k_pool.shape
    assert KVp == KV, (KVp, KV)
    assert k_scale.shape == (n_pages, KV) and \
        v_scale.shape == (n_pages, KV)
    J, = table.shape
    assert counts.shape == (J,), (counts.shape, J)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_chunk_prefix_kernel_q8, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # table, counts, k_scale, v_scale
        grid=(KV, J),
        in_specs=[
            pl.BlockSpec((1, CG, D),
                         lambda kv, j, tbl, cnt, ks, vs: (kv, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda kv, j, tbl, cnt, ks, vs:
                         (tbl[j], 0, kv, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda kv, j, tbl, cnt, ks, vs:
                         (tbl[j], 0, kv, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, CG, D),
                         lambda kv, j, tbl, cnt, ks, vs: (kv, 0, 0)),
            pl.BlockSpec((1, CG),
                         lambda kv, j, tbl, cnt, ks, vs: (kv, 0)),
            pl.BlockSpec((1, CG),
                         lambda kv, j, tbl, cnt, ks, vs: (kv, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((CG, D), f32),
            pltpu.VMEM((CG, 1), f32),
            pltpu.VMEM((CG, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((KV, CG, D), f32),
            jax.ShapeDtypeStruct((KV, CG), f32),
            jax.ShapeDtypeStruct((KV, CG), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, k_scale, v_scale, q, k_pool, v_pool)


def _mla_chunk_prefix_kernel(tbl_ref, cnt_ref, qa_ref, qr_ref, ckv_ref,
                             kr_ref, ot_ref, m_ref, l_ref, acc_ref,
                             ms_ref, ls_ref, *, scale, n_logical):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[j]
    qa = qa_ref[...].astype(jnp.float32) * scale        # (C*H, r)
    qr = qr_ref[...].astype(jnp.float32) * scale        # (C*H, rope)
    ckv = ckv_ref[0].astype(jnp.float32)                # (ps, r)
    kr = kr_ref[0].astype(jnp.float32)                  # (ps, rope)
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (C*H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[...] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_chunk_prefix_attend_p(q_abs: jax.Array, q_rope: jax.Array,
                                  ckv_pool: jax.Array,
                                  krope_pool: jax.Array,
                                  table: jax.Array, counts: jax.Array,
                                  *, scale: float,
                                  interpret: bool = False):
    """Split-operand MLA chunk-prefix attention over latent page pools.

    q_abs: (C*H, r) absorbed chunk queries; q_rope: (C*H, rope);
    table/counts: (J,) prior pages + per-page valid counts.  Returns
    fp32 partials (o_tilde (C*H, r), m (1, C*H), l (1, C*H)).
    """
    CH, r = q_abs.shape
    rope = q_rope.shape[1]
    n_pages, ps, _ = ckv_pool.shape
    assert krope_pool.shape == (n_pages, ps, rope)
    J, = table.shape
    assert counts.shape == (J,), (counts.shape, J)
    kernel = functools.partial(_mla_chunk_prefix_kernel, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(J,),
        in_specs=[
            pl.BlockSpec((CH, r), lambda j, tbl, cnt: (0, 0)),
            pl.BlockSpec((CH, rope), lambda j, tbl, cnt: (0, 0)),
            pl.BlockSpec((1, ps, r), lambda j, tbl, cnt: (tbl[j], 0, 0)),
            pl.BlockSpec((1, ps, rope),
                         lambda j, tbl, cnt: (tbl[j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((CH, r), lambda j, tbl, cnt: (0, 0)),
            pl.BlockSpec((1, CH), lambda j, tbl, cnt: (0, 0)),
            pl.BlockSpec((1, CH), lambda j, tbl, cnt: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((CH, r), f32),
            pltpu.VMEM((CH, 1), f32),
            pltpu.VMEM((CH, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((CH, r), f32),
            jax.ShapeDtypeStruct((1, CH), f32),
            jax.ShapeDtypeStruct((1, CH), f32),
        ],
        compiler_params=tpu_compiler_params("arbitrary"),
        interpret=interpret,
    )(table, counts, q_abs, q_rope, ckv_pool, krope_pool)


def _mla_chunk_prefix_kernel_q8(tbl_ref, cnt_ref, cs_ref, rs_ref,
                                qa_ref, qr_ref, ckv_ref, kr_ref,
                                ot_ref, m_ref, l_ref, acc_ref, ms_ref,
                                ls_ref, *, scale, n_logical):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[j]
    page = tbl_ref[j]
    cs = cs_ref[page]                                   # per-page scales
    rs = rs_ref[page]
    qa = qa_ref[...].astype(jnp.float32) * scale
    qr = qr_ref[...].astype(jnp.float32) * scale
    ckv = ckv_ref[0].astype(jnp.float32)                # (ps, r) int8
    kr = kr_ref[0].astype(jnp.float32)                  # (ps, rope) int8
    s = jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cs
    s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * rs
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32) * cs
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[...] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_mla_chunk_prefix_attend_q8_p(q_abs: jax.Array,
                                     q_rope: jax.Array,
                                     ckv_pool: jax.Array,
                                     krope_pool: jax.Array,
                                     ckv_scale: jax.Array,
                                     krope_scale: jax.Array,
                                     table: jax.Array,
                                     counts: jax.Array, *,
                                     scale: float,
                                     interpret: bool = False):
    """``vwr_mla_chunk_prefix_attend_p`` over int8 latent pools with
    fp32 per-page scale sidecars."""
    CH, r = q_abs.shape
    rope = q_rope.shape[1]
    n_pages, ps, _ = ckv_pool.shape
    assert krope_pool.shape == (n_pages, ps, rope)
    assert ckv_scale.shape == (n_pages,) and \
        krope_scale.shape == (n_pages,)
    J, = table.shape
    assert counts.shape == (J,), (counts.shape, J)
    kernel = functools.partial(_mla_chunk_prefix_kernel_q8, scale=scale,
                               n_logical=J)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # table, counts, ckv_scale, kr_scale
        grid=(J,),
        in_specs=[
            pl.BlockSpec((CH, r), lambda j, tbl, cnt, cs, rs: (0, 0)),
            pl.BlockSpec((CH, rope),
                         lambda j, tbl, cnt, cs, rs: (0, 0)),
            pl.BlockSpec((1, ps, r),
                         lambda j, tbl, cnt, cs, rs: (tbl[j], 0, 0)),
            pl.BlockSpec((1, ps, rope),
                         lambda j, tbl, cnt, cs, rs: (tbl[j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((CH, r), lambda j, tbl, cnt, cs, rs: (0, 0)),
            pl.BlockSpec((1, CH), lambda j, tbl, cnt, cs, rs: (0, 0)),
            pl.BlockSpec((1, CH), lambda j, tbl, cnt, cs, rs: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((CH, r), f32),
            pltpu.VMEM((CH, 1), f32),
            pltpu.VMEM((CH, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((CH, r), f32),
            jax.ShapeDtypeStruct((1, CH), f32),
            jax.ShapeDtypeStruct((1, CH), f32),
        ],
        compiler_params=tpu_compiler_params("arbitrary"),
        interpret=interpret,
    )(table, counts, ckv_scale, krope_scale, q_abs, q_rope, ckv_pool,
      krope_pool)


def vwr_flash_decode_p(q: jax.Array, k: jax.Array, v: jax.Array,
                       lens: jax.Array, *, bkv: int, t_valid: int,
                       interpret: bool = False):
    """Returns (o_tilde (BKV, G, Dh) f32, m (BKV, G) f32,
    l (BKV, G) f32)."""
    BKV, G, D = q.shape
    Tp = k.shape[1]
    assert k.shape == (BKV, Tp, D) and v.shape == k.shape
    assert Tp % bkv == 0, (Tp, bkv)
    n_kv = Tp // bkv
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_decode_kernel, scale=scale, bkv=bkv,
                               t_valid=t_valid, n_kv=n_kv)
    f32 = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=(BKV, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 2), lambda b, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v, lens)
