"""Flash-decode kernel: one query token vs a (possibly sharded) cache.

Decode is the zero-reuse end of the paper's streaming spectrum — every
cached (T, Dh) K/V element is read exactly once per generated token, so
the only lever is transaction width: each grid step stages one wide
(bkv x Dh) cache block in VMEM (the ultra-wide transaction) and the
whole head group consumes it before the next fetch.  GQA is zero-copy
as in ``vwr_attention``: the q block is the *group* (G query heads that
share one KV head), so the staged cache bytes per group are 1/G of the
head-expanded layout.

Unlike the prefill kernel this one returns the **unnormalized** online-
softmax partials (o_tilde, m, l) rather than the normalized context:
that is the combine contract of distributed FlashDecoding
(``dist.decode``), where each model shard holds a slab of the cache
starting at global position ``pos0`` and only the (B, H) statistics
cross the interconnect.  Single-device callers normalize with
``o_tilde / max(l, eps)``.

q: (B*KV, G, Dh); k, v: (B*KV, Tp, Dh) flattened kv heads, Tp padded
to a bkv multiple; lens: (1, 2) int32 [cur_len, pos0] (dynamic —
decode runs inside a jitted generation loop).  Grid: (B*KV, kv-blocks),
kv innermost (sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, lens_ref, ot_ref, m_ref, l_ref,
                   acc_ref, ms_ref, ls_ref, *, scale, bkv, t_valid,
                   n_kv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    cur = lens_ref[0, 0]
    pos0 = lens_ref[0, 1]
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    idx = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # idx < t_valid masks the block-multiple padding; pos0 + idx < cur
    # masks positions not yet written (and, sharded, positions owned by
    # other shards' slabs never appear here at all)
    valid = (idx < t_valid) & (pos0 + idx < cur)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def _paged_decode_kernel(tbl_ref, cnt_ref, q_ref, k_ref, v_ref,
                         ot_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref,
                         *, scale, page_size, n_logical, kv_heads):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    count = cnt_ref[b // kv_heads, j]                   # tokens valid here
    q = q_ref[0].astype(jnp.float32) * scale            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (page_size, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # count == 0 masks the whole page: a logical page past the slot's
    # length, an unallocated table entry, or (sharded) a page owned by
    # another shard's slab — the caller folds all three into counts
    s = jnp.where(idx < count, s, NEG_INF)
    m_prev = ms_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))         # (G,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    ls_ref[:, 0] = ls_ref[:, 0] * corr + p.sum(axis=-1)
    pv = jnp.dot(p, v_ref[0, :, 0, :].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    ms_ref[:, 0] = m_new

    @pl.when(j == n_logical - 1)
    def _store():
        ot_ref[0] = acc_ref[...]
        m_ref[0] = ms_ref[:, 0]
        l_ref[0] = ls_ref[:, 0]


def vwr_paged_flash_decode_p(q: jax.Array, k_pool: jax.Array,
                             v_pool: jax.Array, table: jax.Array,
                             counts: jax.Array, *,
                             interpret: bool = False):
    """Flash-decode over a paged KV pool, one staged page per grid step.

    The block table IS the transaction schedule: it rides in as a
    scalar-prefetch operand, so each (slot, logical-page) grid step's
    BlockSpec index map resolves ``table[slot, j]`` *before* the DMA
    fires and stages exactly that physical (page_size x Dh) page in
    VMEM — the gather never materializes in HBM.  ``counts[slot, j]``
    is the number of valid tokens in that page (0 masks the page
    entirely — length overrun, unallocated entry, or a page owned by
    another shard's slab).

    q: (B*KV, G, Dh); k_pool, v_pool: (n_pages, page_size, KV, Dh);
    table, counts: (B, max_pages) int32, table pre-clamped to
    [0, n_pages).  Returns (o_tilde (BKV, G, Dh) f32, m (BKV, G) f32,
    l (BKV, G) f32) — the same unnormalized combine contract as
    ``vwr_flash_decode_p``.
    """
    BKV, G, D = q.shape
    n_pages, ps, KV, Dp = k_pool.shape
    assert v_pool.shape == k_pool.shape and Dp == D
    assert BKV % KV == 0, (BKV, KV)
    B, J = table.shape
    assert counts.shape == (B, J) and B * KV == BKV, (table.shape, BKV)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=ps, n_logical=J, kv_heads=KV)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # table, counts
        grid=(BKV, J),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt:
                         (tbl[b // KV, j], 0, b % KV, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, j, tbl, cnt:
                         (tbl[b // KV, j], 0, b % KV, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, tbl, cnt: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j, tbl, cnt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(table, counts, q, k_pool, v_pool)


def vwr_flash_decode_p(q: jax.Array, k: jax.Array, v: jax.Array,
                       lens: jax.Array, *, bkv: int, t_valid: int,
                       interpret: bool = False):
    """Returns (o_tilde (BKV, G, Dh) f32, m (BKV, G) f32,
    l (BKV, G) f32)."""
    BKV, G, D = q.shape
    Tp = k.shape[1]
    assert k.shape == (BKV, Tp, D) and v.shape == k.shape
    assert Tp % bkv == 0, (Tp, bkv)
    n_kv = Tp // bkv
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_decode_kernel, scale=scale, bkv=bkv,
                               t_valid=t_valid, n_kv=n_kv)
    f32 = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=(BKV, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 2), lambda b, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, G), lambda b, j: (b, 0)),
            pl.BlockSpec((1, G), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, D), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
            jax.ShapeDtypeStruct((BKV, G), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), f32),
            pltpu.VMEM((G, 1), f32),
            pltpu.VMEM((G, 1), f32),
        ],
        compiler_params=tpu_compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v, lens)
