"""Depthwise conv kernel — the paper's headline low-reuse case (§3.4).

MobileNet-style depthwise convolutions have K^2 reuse per activation
and no cross-channel reduction: systolic arrays idle (no GEMM K-dim to
fold), GPUs stall on bandwidth.  The VWR discipline keeps the VPU fed:
one wide HBM->VMEM stage per halo'd row block, K^2 shifted elementwise
multiply-accumulates per staged block (VPU, not MXU — there is no
matmul here, exactly why SAs collapse).

x: (N, H, W, C), w: (KH, KW, C), stride 1, VALID.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import halo_block_spec, tpu_compiler_params


def _dw_kernel(x_ref, w_ref, o_ref, *, KH, KW, bh, W_out):
    x = x_ref[0]                                   # (bh+KH-1, W, C)
    C = x.shape[-1]
    acc = jnp.zeros((bh, W_out, C), jnp.float32)
    for kj in range(KH):
        for ki in range(KW):
            xs = x[kj: kj + bh, ki: ki + W_out, :]
            acc += xs.astype(jnp.float32) * w_ref[kj, ki][None, None, :]
    o_ref[0] = acc.astype(o_ref.dtype)


def vwr_depthwise_p(x: jax.Array, w: jax.Array, *, bh: int = 8,
                    interpret: bool = False) -> jax.Array:
    """x: (N, H, W, C) with (H-KH+1) % bh == 0; w: (KH, KW, C)."""
    N, H, W, C = x.shape
    KH, KW, C2 = w.shape
    assert C == C2
    H_out, W_out = H - KH + 1, W - KW + 1
    assert H_out % bh == 0
    kernel = functools.partial(_dw_kernel, KH=KH, KW=KW, bh=bh,
                               W_out=W_out)
    params = tpu_compiler_params("parallel", "parallel")
    return pl.pallas_call(
        kernel,
        grid=(N, H_out // bh),
        in_specs=[
            halo_block_spec((1, bh + KH - 1, W, C),
                            lambda n, r: (n, r * bh, 0, 0),
                            halo_dim=1),
            pl.BlockSpec((KH, KW, C), lambda n, r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, W_out, C), lambda n, r: (n, r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, H_out, W_out, C), x.dtype),
        compiler_params=params,
        interpret=interpret,
    )(x, w)
