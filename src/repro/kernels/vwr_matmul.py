"""VWR-streamed matmul kernel (Pallas TPU) with fused epilogues.

The TPU realization of the paper's asymmetric-port VWR (§4.1/§4.3.4):
one HBM->VMEM DMA stages an ultra-wide (bm x bk) LHS block and a
(bk x bn) RHS block; the MXU then consumes that staged data in many
128x128 substeps before the next wide transaction.  The width ratio
N = (bm*bk + bk*bn) staged bytes per (bm*bk*bn) MACs is the tunable
analogue of the paper's SRAM/VFU width ratio — raising the block sizes
raises arithmetic intensity exactly the way widening the VWR raises
the paper's access ratio.

fp32 accumulation in a VMEM scratch across the K grid dimension
(sequential innermost), bf16/fp32 inputs.

Fused epilogue: ``bias`` add, ``activation`` (relu/gelu/silu), and a
``residual`` add are applied to the fp32 accumulator inside the
final-K store, so ``act(x @ w + bias) + residual`` costs exactly one
HBM round-trip for the output — the won access-ratio is not thrown
away on a second elementwise pass (the paper's §4.1 argument applied
to the epilogue instead of the GEMM body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

ACTIVATIONS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _matmul_kernel(x_ref, w_ref, *rest, n_k: int, has_bias: bool,
                   has_res: bool, activation):
    o_ref, acc_ref = rest[-2], rest[-1]
    b_ref = rest[0] if has_bias else None
    r_ref = rest[1 if has_bias else 0] if has_res else None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        out = acc_ref[...]
        if has_bias:
            out = out + b_ref[...].astype(jnp.float32)       # (1,bn) bcast
        if activation is not None:
            out = ACTIVATIONS[activation](out)
        if has_res:
            out = out + r_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _dual_matmul_kernel(x_ref, wg_ref, wi_ref, o_ref, accg_ref, acci_ref,
                        *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    x = x_ref[...]
    accg_ref[...] += jnp.dot(x, wg_ref[...],
                             preferred_element_type=jnp.float32)
    acci_ref[...] += jnp.dot(x, wi_ref[...],
                             preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        out = jax.nn.silu(accg_ref[...]) * acci_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


def vwr_swiglu_p(x: jax.Array, wg: jax.Array, wi: jax.Array, *,
                 bm: int = 256, bk: int = 512, bn: int = 256,
                 interpret: bool = False) -> jax.Array:
    """``silu(x @ wg) * (x @ wi)`` in one kernel pass (dual-matmul
    fused-swiglu epilogue).

    x: (M, K); wg, wi: (K, N); dims must divide the block sizes
    (``ops.vwr_swiglu`` pads).  One staged (bm x bk) x block feeds BOTH
    matmuls' MXU substeps — the gate's and the up-projection's — so the
    LHS wide transaction is paid once, and the ``silu(g) * h`` product
    happens on the two fp32 accumulators inside the final-K store: the
    gate and up activations never round-trip HBM and the elementwise
    pass that used to follow the two separate matmuls disappears."""
    M, K = x.shape
    K2, N = wg.shape
    assert K == K2 and wi.shape == (K, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_dual_matmul_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            "parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, wg, wi)


def vwr_matmul_p(x: jax.Array, w: jax.Array, bias=None, residual=None, *,
                 bm: int = 256, bk: int = 512, bn: int = 256,
                 activation: str = None,
                 interpret: bool = False) -> jax.Array:
    """x: (M, K), w: (K, N) — M, K, N must divide the block sizes
    (ops.vwr_matmul pads).  Optional fused epilogue on the final-K
    store: bias (1, N), activation name, residual (M, N).  Returns
    ``act(x @ w + bias) + residual`` as (M, N) in x.dtype."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    assert activation is None or activation in ACTIVATIONS, activation
    n_k = K // bk
    kernel = functools.partial(
        _matmul_kernel, n_k=n_k, has_bias=bias is not None,
        has_res=residual is not None, activation=activation)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [x, w]
    if bias is not None:
        assert bias.shape == (1, N), bias.shape
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias)
    if residual is not None:
        assert residual.shape == (M, N), residual.shape
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.append(residual)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            "parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(*operands)
