"""VWR-streamed matmul kernel (Pallas TPU).

The TPU realization of the paper's asymmetric-port VWR (§4.1/§4.3.4):
one HBM->VMEM DMA stages an ultra-wide (bm x bk) LHS block and a
(bk x bn) RHS block; the MXU then consumes that staged data in many
128x128 substeps before the next wide transaction.  The width ratio
N = (bm*bk + bk*bn) staged bytes per (bm*bk*bn) MACs is the tunable
analogue of the paper's SRAM/VFU width ratio — raising the block sizes
raises arithmetic intensity exactly the way widening the VWR raises
the paper's access ratio.

fp32 accumulation in a VMEM scratch across the K grid dimension
(sequential innermost), bf16/fp32 inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def vwr_matmul_p(x: jax.Array, w: jax.Array, *, bm: int = 256,
                 bk: int = 512, bn: int = 256,
                 interpret: bool = False) -> jax.Array:
    """x: (M, K), w: (K, N) — M, K, N must divide the block sizes
    (ops.vwr_matmul pads).  Returns (M, N) in x.dtype."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    n_k = K // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except TypeError:          # older signature
        params = None
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(x, w)
