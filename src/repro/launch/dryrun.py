import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the
# device count on first init).  512 host devices exist ONLY here.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(**input_specs(...))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # fits?
        print(compiled.cost_analysis())     # flops/bytes for roofline
plus the HLO collective-bytes parse (hlo_analysis.py).  Results land in
artifacts/dryrun/<arch>.<shape>.<mesh>.json for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax

from repro.common.config import SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.configs import ARCHS, get_config
from repro.dist import sharding as SH
from repro.launch import hlo_analysis, steps
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw

from jax.sharding import NamedSharding, PartitionSpec as PS


def cell_shardings(cfg, mesh, shape):
    """in_shardings tree matching input_specs / step signature."""
    strategy = ("serve" if shape.kind == "decode"
                and cfg.sharding_strategy == "fsdp_tp"
                else cfg.sharding_strategy)
    pspecs = {"params": SH.param_pspecs(cfg, mesh, strategy)}
    if shape.kind == "train":
        bs = steps.batch_specs(cfg, shape)
        pspecs["batch"] = SH.train_batch_pspecs(cfg, mesh, bs)
        params_abs = __import__("repro.models.lm", fromlist=["lm"]) \
            .abstract_init(cfg)
        opt_cfg = adamw.OptConfig()
        pspecs["opt_state"] = adamw.opt_state_pspecs(
            opt_cfg, pspecs["params"], params_abs, mesh)
    elif shape.kind == "prefill":
        bs = steps.batch_specs(cfg, shape)
        pspecs["batch"] = SH.train_batch_pspecs(cfg, mesh, bs)
    else:
        pspecs["batch"] = SH.decode_batch_pspecs(cfg, mesh,
                                                 shape.global_batch)
    return pspecs


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = "artifacts/dryrun", save_hlo: bool = False,
             cfg=None, mesh=None, shape=None):
    cfg = cfg if cfg is not None else get_config(arch)
    shape = shape if shape is not None else SHAPES_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_kind}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        print(f"[dryrun] {arch} x {shape.name}: SKIP ({reason})")
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}.{shape.name}.{mesh_kind}.json"),
                "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    specs = steps.input_specs(cfg, shape)
    fn = steps.step_fn_for(cfg, shape)
    pspecs = cell_shardings(cfg, mesh, shape)
    shardings = SH.to_shardings(mesh, pspecs)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            fn,
            in_shardings=tuple(shardings[k] for k in
                               ("params", "opt_state", "batch")
                               if k in shardings),
        )
        args = tuple(specs[k] for k in ("params", "opt_state", "batch")
                     if k in specs)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # older jax: [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    ana = hlo_analysis.analyze(hlo)

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # per-device, while-bodies-once (raw XLA numbers)
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        # per-device, trip-count scaled (our HLO walk)
        "collective_bytes": ana["collective_bytes"],
        "collective_kinds": ana["collective_kinds"],
        "major_bytes": ana["major_bytes"],
        "major_kinds": ana["major_kinds"],
        "n_devices": mesh.size,
    })

    # accounting pass: unrolled scan-free lowering (single-device,
    # global shapes, no compile) -> exact global FLOPs with every layer
    # and chunk counted (cost_analysis counts while bodies once)
    try:
        # kernel_impl is forced back to the dense XLA formulation: the
        # accounting premise is exact cost_analysis FLOP/byte counts,
        # which interpret-mode pallas_call loop machinery would skew
        acfg = cfg.replace(scan_layers=False, accounting=True,
                           kernel_impl="xla")
        aspecs = steps.input_specs(acfg, shape)
        afn = steps.step_fn_for(acfg, shape)
        aargs = tuple(aspecs[k] for k in ("params", "opt_state", "batch")
                      if k in aspecs)
        t0 = time.time()
        acost = jax.jit(afn).lower(*aargs).cost_analysis()
        rec["flops_accounted_global"] = acost.get("flops", 0.0)
        rec["transcendentals_accounted"] = acost.get("transcendentals",
                                                     0.0)
        rec["accounting_s"] = round(time.time() - t0, 1)
    except Exception as e:                                 # noqa: BLE001
        rec["accounting_error"] = f"{type(e).__name__}: {e}"[:300]
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    print(f"[dryrun] {arch} x {shape.name} x {mesh_kind}: "
          f"compile {t_compile:.1f}s  flops={rec['flops']:.3e}  "
          f"bytes={rec['bytes_accessed']:.3e}  "
          f"coll={rec['collective_bytes']:.3e}  "
          f"major={rec['major_bytes']:.3e}  "
          f"acct_flops={rec.get('flops_accounted_global', -1):.3e}")
    print("  memory_analysis:", {k: rec.get(k) for k in
                                 ("temp_size_in_bytes",
                                  "argument_size_in_bytes",
                                  "output_size_in_bytes")})

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}.{shape.name}.{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in SHAPES] if (args.all or not args.shape)
              else [args.shape])
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                try:
                    run_cell(arch, shape, mesh_kind, out_dir=args.out,
                             save_hlo=args.save_hlo)
                except Exception:
                    failures.append((arch, shape, mesh_kind))
                    traceback.print_exc()
    if failures:
        print("FAILED CELLS:", failures)
        raise SystemExit(1)
    print("dry-run complete: all cells compiled")


if __name__ == "__main__":
    main()
