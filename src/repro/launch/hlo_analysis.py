"""HLO text analysis: collective bytes + major-op bytes, trip-scaled.

``compiled.cost_analysis()`` has no collective term, counts while
bodies once, and its 'bytes accessed' on the CPU backend is inflated
~200x by unfused elementwise chains (all measured; DESIGN.md §8).  So
we parse the optimized HLO ourselves:

  * collective bytes: operand/result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * major-op bytes: operand+result bytes of dot / convolution / gather /
    scatter / dynamic(-update)-slice / sort / reduce ops and fusion
    roots — a fusion-optimistic estimate of real HBM traffic (TPUs fuse
    elementwise chains into these anchors);
  * both are scaled by while-loop trip counts, recovered from the
    `s32[] constant(N)` compare in each loop condition (our loops are
    counted scans, so this is exact), walking the computation call
    graph so nested scans multiply.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")
_MAJOR = ("dot", "convolution", "gather", "scatter",
          "dynamic-update-slice", "dynamic-slice", "sort", "fusion",
          "reduce", "cholesky", "triangular-solve")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"=\s*((?:\([^)]*\)|[\w\[\],{}: ]+?))\s*"
                    r"([a-z][a-z0-9\-]*)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur_name is None:
            if stripped.endswith("{") and "->" in stripped:
                m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
                if m:
                    cur_name = m.group(1)
                    cur_lines = []
                    depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        cur_lines.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    consts = re.findall(r"s(?:32|64)\[\]\s+constant\((\d+)\)", cond_body)
    if consts:
        return max(int(c) for c in consts)
    return 1


def analyze(hlo: str) -> Dict[str, object]:
    """Returns {'collective_bytes', 'collective_kinds', 'major_bytes',
    'major_kinds'} — all trip-count scaled, per-device (SPMD module)."""
    comps = _split_computations(hlo)

    calls: Dict[str, list] = defaultdict(list)
    for name, body in comps.items():
        for line in body.splitlines():
            m = re.search(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,"
                          r"\s*body=%?([\w\.\-]+)", line)
            if m:
                tc = _trip_count(comps.get(m.group(1), ""))
                calls[name].append((m.group(2), tc))
                calls[name].append((m.group(1), tc))
            for cm in re.finditer(r"to_apply=%?([\w\.\-]+)", line):
                # calls, reduces, sorts, fusions reference computations;
                # those inner computations carry no collectives/majors
                # we haven't already counted at the call site
                pass
            m2 = re.search(r"(?:call)\(.*?to_apply=%?([\w\.\-]+)", line)
            if m2:
                calls[name].append((m2.group(1), 1))
            m3 = re.findall(
                r"conditional\(.*?branch_computations=\{([^}]*)\}", line)
            for branches in m3:
                for b in branches.split(","):
                    calls[name].append((b.strip().lstrip("%"), 1))
            m4 = re.search(r"conditional\(.*?true_computation=%?([\w\.\-]+)"
                           r".*?false_computation=%?([\w\.\-]+)", line)
            if m4:
                calls[name].append((m4.group(1), 1))
                calls[name].append((m4.group(2), 1))

    called = {c for lst in calls.values() for c, _ in lst}
    roots = [n for n in comps if n not in called]
    roots.sort(key=lambda n: ("main" not in n, -len(comps[n])))
    root = roots[0] if roots else next(iter(comps))

    coll_kinds: Dict[str, float] = defaultdict(float)
    major_kinds: Dict[str, float] = defaultdict(float)

    def scan_comp(name: str, mult: float):
        for line in comps.get(name, "").splitlines():
            m = _OP_RE.search(line)
            if not m:
                continue
            result_shape, op = m.group(1), m.group(2)
            base = op.replace("-start", "").replace("-done", "")
            if op.endswith("-done"):
                continue                      # avoid double count
            if base in _COLLECTIVES:
                coll_kinds[base] += _shape_bytes(result_shape) * mult
            elif base in _MAJOR or base.startswith("all-"):
                # result + operand bytes: operands are the shapes in
                # the argument list of this line
                args = line[m.end():]
                b = _shape_bytes(result_shape) + _shape_bytes(args)
                major_kinds[base] += b * mult

    seen_stack = []

    def walk(name: str, mult: float):
        if name in seen_stack:
            return
        scan_comp(name, mult)
        seen_stack.append(name)
        for callee, tc in calls.get(name, []):
            walk(callee, mult * tc)
        seen_stack.pop()

    walk(root, 1.0)
    return {
        "collective_bytes": float(sum(coll_kinds.values())),
        "collective_kinds": dict(coll_kinds),
        "major_bytes": float(sum(major_kinds.values())),
        "major_kinds": dict(major_kinds),
    }


def collective_bytes(hlo: str) -> Tuple[float, Dict[str, float]]:
    r = analyze(hlo)
    return r["collective_bytes"], r["collective_kinds"]
