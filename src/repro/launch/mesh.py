"""Mesh construction (never touches jax device state at import time)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
