"""Roofline analysis from dry-run artifacts (TPU v5e constants).

Terms per (arch x shape) on the single-pod mesh:

    t_comp = HLO_FLOPs_corrected / (chips * 197e12)     [bf16 peak]
    t_mem  = HLO_bytes_corrected / (chips * 819e9)      [HBM]
    t_coll = collective_bytes / (chips * 50e9)          [ICI per link]

``cost_analysis`` counts while bodies once (measured, DESIGN.md §8),
so the dry-run records BOTH the raw compiled numbers and a scan-
corrected estimate: the correction lowers each cell twice — once as
the real scanned program, once with a single-layer stack — and scales
the difference by the layer count:

    corrected ~= base + (L - 1) * (base_L - base_{L=1}) / (L_small - 1)

In practice we lower with L and with 2L' layers... simpler and exact
for our uniform stacks: lower the SAME program with scan trip count 1
and with the true count; the delta per trip is their difference.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) sanity-checks how
much compiled compute is useful (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

V5E_HBM_BYTES = 16 * 1024 ** 3


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    flops: float             # global, per step (corrected)
    bytes_hbm: float         # global, per step (corrected)
    bytes_coll: float        # global, per step
    model_flops: float       # analytic useful flops
    t_comp: float = 0.0
    t_mem: float = 0.0
    t_coll: float = 0.0

    def finalize(self):
        self.t_comp = self.flops / (self.chips * PEAK_FLOPS)
        self.t_mem = self.bytes_hbm / (self.chips * HBM_BW)
        self.t_coll = self.bytes_coll / (self.chips * ICI_BW)
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound is sum; perfect overlap is max.
        We report max (the roofline optimum a perf loop drives toward)."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def useful_frac(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the dominant-roofline optimum that is useful
        model compute: MODEL_FLOPS/peak vs achieved step time."""
        t_model = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_model / max(self.step_time, 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.chips,
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_coll": self.bytes_coll,
            "t_comp_s": self.t_comp, "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_frac": self.useful_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*D_step (decode); MoE uses
    active params.  D = tokens processed in the step."""
    n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence; params touched ~ active set
    return 2.0 * n * shape.global_batch


def attention_flops(cfg, shape) -> float:
    """Quadratic attention term excluded from 6ND (reported separately)."""
    if cfg.family in ("ssm",):
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    if cfg.family == "hybrid":
        from repro.models.lm import _hybrid_groups
        L = _hybrid_groups(cfg)[3]
    dh = cfg.d_head if cfg.mla is None else (
        cfg.mla.nope_head_dim + cfg.mla.rope_head_dim)
    per_tok_pair = 2 * cfg.n_heads * dh * 2          # qk + pv
    if shape.kind == "train":
        return 3.0 * L * B * S * S / 2 * per_tok_pair
    if shape.kind == "prefill":
        return L * B * S * S / 2 * per_tok_pair
    return L * B * S * per_tok_pair


def load_cell(artifact_dir: str, arch: str, shape: str,
              mesh: str = "single") -> Optional[Dict]:
    path = os.path.join(artifact_dir, f"{arch}.{shape}.{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def build_roofline(cfg, shape, rec: Dict, corrected: Optional[Dict] = None
                   ) -> Roofline:
    flops = (corrected or rec).get("flops", rec.get("flops", 0.0))
    bts = (corrected or rec).get("bytes_accessed",
                                 rec.get("bytes_accessed", 0.0))
    mf = model_flops(cfg, shape) + attention_flops(cfg, shape)
    return Roofline(
        arch=cfg.name, shape=shape.name, chips=rec.get("n_devices", 256),
        flops=flops, bytes_hbm=bts,
        bytes_coll=rec.get("collective_bytes", 0.0),
        model_flops=mf,
    ).finalize()


# ======================================================================
# analytic HBM-traffic model (primary t_mem source)
# ======================================================================
# The HLO-derived byte counts on the CPU backend carry two opposing
# biases (DESIGN.md §8): 'bytes accessed' counts scan bodies once
# (undercount ~L x) but counts unfused elementwise chains (overcount
# ~5-10x on CPU, which fuses far less than TPU); the dot-anchored parse
# multiplies trip counts but re-counts block-resident operands per use.
# So the dominant-term analysis uses this transparent per-family model
# (global bytes per step), and EXPERIMENTS.md reports all three.

def analytic_traffic(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    dt = 2 if cfg.dtype == "bfloat16" else 4
    d = cfg.d_model
    L = cfg.n_layers
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    tokens = B * S

    ff_ratio = (cfg.d_ff / d) if cfg.d_ff else 2.0
    # per-token per-layer activation words flowing through HBM
    # (residual, qkv, attn out, mlp hidden x2 gates)
    act_width = (4 + 2 * ff_ratio) * d

    if shape.kind == "train":
        # params: fsdp all-gather fwd+bwd (2x2B) + grad reduce-scatter
        # (4B) + adam m/v rw (bf16: 4x2B) + master rw (8B)
        p_bytes = n_params * (2 * dt + 4 + 8 + 8)
        # activations: fwd write+bwd read of layer boundaries + remat
        # recompute traffic (~3 passes over act_width)
        a_bytes = L * tokens * (2 * d * dt + 3 * act_width * dt)
        # attention KV streaming: fwd + bwd + remat-recompute passes
        kv_w = (cfg.n_kv_heads * cfg.d_head if cfg.mla is None else
                cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
        n_attn = L if cfg.family not in ("hybrid", "ssm") else (
            0 if cfg.family == "ssm" else
            (L // cfg.mamba2.attn_every + 1))
        bq = max(cfg.attn_block_q, 1)
        att_bytes = 3 * n_attn * B * (S / bq) * S * kv_w * 2 * dt
        # logits fwd+bwd (fp32)
        lg_bytes = tokens * cfg.vocab_padded * 4 * 2
        moe_bytes = 0.0
        if cfg.moe is not None:
            m = cfg.moe
            moe_bytes = 3 * tokens * m.top_k * m.capacity_factor * d \
                * dt * 2
        return p_bytes + a_bytes + att_bytes + lg_bytes + moe_bytes

    if shape.kind == "prefill":
        p_bytes = n_params * 2 * dt
        a_bytes = L * tokens * (d * dt + act_width * dt)
        kv_w = (cfg.n_kv_heads * cfg.d_head if cfg.mla is None else
                cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
        n_attn = L if cfg.family not in ("hybrid", "ssm") else (
            0 if cfg.family == "ssm" else
            (L // cfg.mamba2.attn_every + 1))
        bq = max(cfg.attn_block_q, 1)
        att_bytes = n_attn * B * (S / bq) * S * kv_w * 2 * dt
        lg_bytes = B * cfg.vocab_padded * 4
        moe_bytes = 0.0
        if cfg.moe is not None:
            m = cfg.moe
            moe_bytes = tokens * m.top_k * m.capacity_factor * d * dt * 2
        return p_bytes + a_bytes + att_bytes + lg_bytes + moe_bytes

    # decode: active params once (MoE: every expert slot that can be
    # hit; with B*k assignments >= E the whole expert set is touched)
    if cfg.moe is not None:
        m = cfg.moe
        hit = min(m.n_experts, B * m.top_k)
        per_layer_expert = 3 * d * m.d_expert
        n_moe_layers = L - m.first_k_dense
        p_bytes = (n_active - n_moe_layers * m.top_k * per_layer_expert
                   ) * dt + n_moe_layers * hit * per_layer_expert * dt
    else:
        p_bytes = n_params * dt
    # cache read (+1 token write)
    kv_w = (cfg.n_kv_heads * cfg.d_head * 2 if cfg.mla is None else
            cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
    if cfg.family == "ssm":
        xc = cfg.xlstm
        d_inner = int(xc.proj_factor * d)
        P = d_inner // cfg.n_heads
        cache_bytes = L * B * cfg.n_heads * P * P * 4 * 2
    elif cfg.family == "hybrid":
        mc = cfg.mamba2
        d_inner = mc.expand * d
        H = d_inner // mc.head_dim
        n_attn = L // mc.attn_every + 1
        cache_bytes = (L * B * H * mc.d_state * mc.head_dim * 4 * 2
                       + n_attn * B * S * kv_w * dt)
    elif cfg.family == "audio":
        cache_bytes = L * B * S * kv_w * dt * 2     # self + cross
    else:
        n_attn = L
        cache_bytes = n_attn * B * S * kv_w * dt
    act_bytes = L * B * act_width * dt * 3
    lg_bytes = B * cfg.vocab_padded * 4
    return p_bytes + cache_bytes + act_bytes + lg_bytes
