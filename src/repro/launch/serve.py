"""Serving driver: prefill a batch of prompts, decode with a KV cache.

CPU example (small model, batched requests):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduce width --batch 4 --prompt-len 64 --gen 32

Sharded serving: ``--data-model D M`` lays the mesh out explicitly and
routes everything through the ``repro.dist`` sharding vocabulary —
params TP-sharded with the 'serve' strategy, the decode cache batch-
sharded over 'data', and (with ``--shard seq``) sequence-sharded over
'model' so decode attention runs distributed FlashDecoding
(``dist.decode``: per-shard online-softmax partials, one (B, H)-sized
combine on the wire per token).  ``--kernel-impl pallas`` additionally
stages each shard's cache slab through the VWR flash-decode kernel.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.dist import sharding as SH
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.launch.train import width_reduce
from repro.models import lm


def pad_cache_from_prefill(cfg, caches, batch, max_len, prefill_len,
                           enc_len=0):
    """Place prefill KV stacks into fixed-size decode cache buffers."""
    cache = lm.init_cache(cfg, batch, max_len, enc_len=enc_len)
    fam = cfg.family

    def put(buf, kv):           # buf (L,B,T,...) <- kv (L,B,S,...)
        return jax.lax.dynamic_update_slice(
            buf, kv.astype(buf.dtype), (0,) * buf.ndim)

    if fam in ("dense", "vlm"):
        if cfg.mla is not None:
            ckv, krope = caches
            cache = {"ckv": put(cache["ckv"], ckv),
                     "krope": put(cache["krope"], krope)}
        else:
            k, v = caches
            cache = {"k": put(cache["k"], k), "v": put(cache["v"], v)}
    elif fam == "moe":
        kv_d, kv_m = caches
        if cfg.mla is not None:
            if cfg.moe.first_k_dense and kv_d is not None:
                cache["dense"] = {
                    "ckv": put(cache["dense"]["ckv"], kv_d[0]),
                    "krope": put(cache["dense"]["krope"], kv_d[1])}
            cache["moe"] = {"ckv": put(cache["moe"]["ckv"], kv_m[0]),
                            "krope": put(cache["moe"]["krope"], kv_m[1])}
        else:
            if cfg.moe.first_k_dense and kv_d is not None:
                cache["dense"] = {"k": put(cache["dense"]["k"], kv_d[0]),
                                  "v": put(cache["dense"]["v"], kv_d[1])}
            cache["moe"] = {"k": put(cache["moe"]["k"], kv_m[0]),
                            "v": put(cache["moe"]["v"], kv_m[1])}
    elif fam == "hybrid":
        (st_main, kv_main), (st_tail, kv_tail) = caches
        cache["mamba_main"] = st_main
        if st_tail is not None:
            cache["mamba_tail"] = st_tail
        ks = [kv_main[0]] if kv_tail is None else [kv_main[0],
                                                   kv_tail[0][None]]
        vs = [kv_main[1]] if kv_tail is None else [kv_main[1],
                                                   kv_tail[1][None]]
        cache["attn_k"] = put(cache["attn_k"], jnp.concatenate(ks, 0))
        cache["attn_v"] = put(cache["attn_v"], jnp.concatenate(vs, 0))
    elif fam == "ssm":
        m_sts, s_st = caches
        cache = {"mlstm": m_sts, "slstm": s_st}
    elif fam == "audio":
        kv, cross = caches
        cache["self_k"] = put(cache["self_k"], kv[0])
        cache["self_v"] = put(cache["self_v"], kv[1])
        cache["cross_k"] = put(cache["cross_k"], cross[0])
        cache["cross_v"] = put(cache["cross_v"], cross[1])
    return cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", choices=["smoke", "width"], default="width")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data-model", type=int, nargs=2, default=None,
                    help="mesh shape (data, model)")
    ap.add_argument("--shard", choices=["none", "seq"], default="none",
                    help="'seq' = sequence-shard the KV cache over "
                         "'model' (distributed FlashDecoding)")
    ap.add_argument("--kernel-impl", choices=["xla", "pallas"],
                    default="xla")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    cfg = reduced(cfg) if args.reduce == "smoke" else width_reduce(cfg)
    cfg = cfg.replace(kernel_impl=args.kernel_impl,
                      decode_shard=args.shard)
    if cfg.mamba2 is not None or cfg.xlstm is not None:
        chunk = (cfg.mamba2 or cfg.xlstm).chunk
        assert args.prompt_len % chunk == 0

    dm = args.data_model or (jax.device_count(), 1)
    mesh = make_local_mesh(*dm)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    if args.shard == "seq":
        msize = mesh.shape.get("model", 1)
        assert max_len % msize == 0, (
            f"--shard seq needs (prompt+gen)={max_len} divisible by the "
            f"model axis ({msize})")

    params = lm.init(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(
        params, SH.to_shardings(mesh, SH.param_pspecs(cfg, mesh,
                                                      "serve")))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["frontend_emb"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "audio":
        batch["frontend_emb"] = jnp.asarray(rng.standard_normal(
            (B, P, cfg.frontend_dim)), jnp.float32)

    with mesh:
        t0 = time.time()
        logits, caches = jax.jit(steps.build_prefill(cfg))(
            params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        prefill_tokens = P + (cfg.frontend_tokens
                              if cfg.family == "vlm" else 0)
        cache = pad_cache_from_prefill(cfg, caches, B, max_len, P,
                                       enc_len=P)
        cache = jax.device_put(cache, SH.to_shardings(
            mesh, SH.cache_pspecs(cfg, mesh, B,
                                  seq_shard=(args.shard == "seq"))))
        decode = jax.jit(steps.build_decode(cfg, mesh))

        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.time()
        for i in range(G - 1):
            dbatch = {"token": tok, "cur_len": jnp.int32(prefill_tokens + i),
                      "cache": cache}
            logits, cache = decode(params, dbatch)
            if args.temperature > 0:
                key = jax.random.PRNGKey(i)
                tok = jax.random.categorical(
                    key, logits / args.temperature, -1).astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = jnp.stack(out_tokens, 1)
    print(f"[serve] {cfg.name}: prefill {B}x{P} in {t_prefill:.2f}s "
          f"({B*P/t_prefill:.0f} tok/s); decode {G-1} steps in "
          f"{t_decode:.2f}s ({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("[serve] sample generations (token ids):")
    for b in range(min(B, 2)):
        print("   ", np.asarray(gen[b])[:16])
    return gen


if __name__ == "__main__":
    main()
