"""Serving driver: prefill a batch of prompts, decode with a KV cache.

A thin CLI over ``repro.engine.DecodeEngine`` — the engine owns the
mesh (explicitly, no ambient ``with mesh:`` context), the TP-sharded
params, the cache layouts, and the jitted prefill/decode steps.

CPU example (small model, batched requests):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduce width --batch 4 --prompt-len 64 --gen 32

Sharded serving: ``--data-model D M`` lays the mesh out explicitly and
routes everything through the ``repro.dist`` sharding vocabulary —
params TP-sharded with the 'serve' strategy, the decode cache batch-
sharded over 'data', and (with ``--shard seq``) sequence-sharded over
'model' so decode attention runs distributed FlashDecoding
(``dist.decode``: per-shard online-softmax partials, one (B, H)-sized
combine on the wire per token).  ``--kernel-impl`` picks the dispatch-
registry backend per op: ``pallas`` stages each shard's cache slab
through the VWR flash-decode kernel, ``auto`` lets the autotuner cache
decide per shape.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.engine import DecodeEngine, EngineConfig
from repro.engine import pad_cache_from_prefill  # noqa: F401  (compat)
from repro.launch.train import width_reduce


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", choices=["smoke", "width"], default="width")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data-model", type=int, nargs=2, default=None,
                    help="mesh shape (data, model); default "
                         "(device_count, 1)")
    ap.add_argument("--shard", choices=["none", "seq"], default="none",
                    help="'seq' = sequence-shard the KV cache over "
                         "'model' (distributed FlashDecoding)")
    ap.add_argument("--kernel-impl", choices=["xla", "pallas", "auto"],
                    default="xla")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page pool + per-slot block "
                         "tables instead of the dense (B, max_len) "
                         "cache")
    ap.add_argument("--page-size", type=int, default=16,
                    help="positions per page (with --paged)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size; default sizes a full "
                         "dense-equivalent batch")
    ap.add_argument("--kv-dtype", choices=["bf16", "int8"],
                    default="bf16",
                    help="page-pool storage dtype (with --paged): "
                         "'int8' quantizes pages with fp32 per-page "
                         "scale sidecars, dequantized in-kernel")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="request-stream mode: continuously batch N "
                         "staggered requests of varying lengths "
                         "through the scheduler (implies --paged; "
                         "--batch is the slot count)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="with --stream: chunked prefill + unified "
                         "mixed prefill/decode steps — admission "
                         "grants pages and enqueues chunks, and each "
                         "step packs decode slots plus up to "
                         "--chunk-tokens of the head prompt under a "
                         "token budget (decode is never stalled by a "
                         "long prompt's prefill)")
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="prompt tokens per mixed-step chunk (with "
                         "--chunked-prefill); must be a multiple of "
                         "--page-size")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="with --stream: prefix-sharing radix cache "
                         "over the page pool (engine.prefix_cache) — "
                         "admission aliases the longest cached whole-"
                         "page prefix into the slot's block table and "
                         "prefills only the suffix; some stream "
                         "prompts share a common system prefix so the "
                         "hit counters are exercised")
    ap.add_argument("--inject", action="store_true",
                    help="with --stream: run a deterministic chaos "
                         "schedule (engine.faults) through the stream "
                         "— NaN logits, a transient step exception, "
                         "pool pressure and a slow step — and report "
                         "the lifecycle counters (the stream must "
                         "still complete)")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the injected chaos schedule")
    ap.add_argument("--heartbeat", default=None, metavar="PATH",
                    help="with --stream: touch PATH each decode step "
                         "(runtime.resilience.Heartbeat) so an "
                         "external supervisor can detect a hang")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="with --stream: durable serving — every "
                         "request event write-ahead journaled and the "
                         "full serving state snapshotted under DIR, "
                         "the drain supervised by runtime.resilience."
                         "serve_with_recovery (requests submitted up "
                         "front; a crash resumes from the latest "
                         "snapshot + journal replay, finished results "
                         "recovered verbatim)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    metavar="N",
                    help="with --snapshot-dir: snapshot the serving "
                         "state every N steps (written async, off the "
                         "step path); 0 = journal-only durability")
    ap.add_argument("--crash-at", type=int, default=0, metavar="K",
                    help="with --snapshot-dir: inject engine.faults."
                         "CrashFault at step K of the first attempt — "
                         "deterministic simulated process death the "
                         "restart loop must recover from")
    return ap


def engine_config_from_args(args, cfg=None) -> EngineConfig:
    """CLI namespace -> EngineConfig (the mapping tests pin).

    ``cfg`` (when given) corrects the cache budget for families whose
    prefill occupies more positions than --prompt-len: the vlm frontend
    prefix counts against max_len too."""
    dm = tuple(args.data_model) if args.data_model \
        else (jax.device_count(), 1)
    extra = (cfg.frontend_tokens
             if cfg is not None and cfg.family == "vlm" else 0)
    return EngineConfig(
        batch=args.batch,
        max_len=args.prompt_len + extra + args.gen,
        mesh_shape=dm,
        decode_shard=args.shard,
        kernel_impl=args.kernel_impl,
        paged=bool(args.paged or args.stream),
        page_size=args.page_size,
        n_pages=args.n_pages,
        kv_dtype=getattr(args, "kv_dtype", "bf16"),
        prefix_cache=bool(getattr(args, "prefix_cache", False)),
        chunked_prefill=bool(getattr(args, "chunked_prefill", False)),
        chunk_tokens=getattr(args, "chunk_tokens", 32),
    )


def _stream_requests(engine, args):
    """The stream workload both modes share: n requests of varying
    prompt/gen lengths, half of them opening with a common whole-page
    "system prompt" when --prefix-cache is on.  Deterministic in the
    args (seeded rng), which is what lets a durable run be compared
    bit-for-bit against a crash-free reference."""
    from repro.engine import Request

    cfg = engine.cfg
    rng = np.random.default_rng(0)
    n, P, G = args.stream, args.prompt_len, args.gen
    shared = None
    if getattr(args, "prefix_cache", False):
        sys_pages = max(1, (P // 2) // engine.page_size)
        shared = rng.integers(
            2, cfg.vocab, (sys_pages * engine.page_size,)
        ).astype(np.int32)

    def _prompt(i):
        body = rng.integers(
            2, cfg.vocab,
            (int(rng.integers(max(P // 2, 1), P + 1)),)).astype(np.int32)
        if shared is not None and i % 2 == 0:
            return np.concatenate([shared, body])[:P].astype(np.int32)
        return body

    return [Request(rid=i, tokens=_prompt(i),
                    gen=int(rng.integers(max(G // 2, 1), G + 1)),
                    temperature=args.temperature, seed=i)
            for i in range(n)]


def _serve_durable(engine, args):
    """Durable request-stream mode (--snapshot-dir): the whole stream
    submitted up front into a journaled, snapshot-cadenced scheduler
    drained under ``serve_with_recovery``.  With --crash-at K the
    first attempt dies deterministically at step K (CrashFault); the
    restart loop restores the latest snapshot, replays the journal and
    finishes the stream — results the crashed process already produced
    are recovered verbatim, never recomputed."""
    import time

    from repro.engine import faults
    from repro.runtime.resilience import (RestartPolicy,
                                          serve_with_recovery)

    n = args.stream
    reqs = _stream_requests(engine, args)
    attempts = []

    def on_start(sched, fresh):
        attempts.append(fresh)
        if fresh and args.crash_at:
            faults.inject(sched, decode_faults=[
                faults.CrashFault(step=args.crash_at)])

    def submit(sched):
        for r in reqs:
            sched.submit(r)

    t0 = time.time()
    sched = serve_with_recovery(
        engine, args.snapshot_dir, submit,
        snapshot_every=args.snapshot_every,
        policy=RestartPolicy(max_restarts=5, backoff_s=0.0),
        on_start=on_start)
    dt = time.time() - t0
    assert len(sched.finished) == n, "durable stream lost results"

    st = sched.stats
    toks = sum(len(v) for v in sched.finished.values())
    print(f"[serve] {engine.cfg.name} durable stream: {n} requests, "
          f"{toks} tokens in {dt:.2f}s; attempts "
          f"{len(attempts)} (crash-at {args.crash_at or '-'}), "
          f"snapshots {sched.snapshotter.saved} "
          f"(every {args.snapshot_every or '-'} steps), journal "
          f"{sched.journal.appended} events appended this process")
    print(f"[serve] lifecycle: finished "
          f"{sum(1 for v in sched.finished.values() if v.ok)}, "
          f"failed {st['failed']}, cancelled {st['cancelled']}, "
          f"timed_out {st['timed_out']}, rejected {st['rejected']}; "
          f"steps {st['steps']} (post-recovery process)")
    for i in range(min(n, 3)):
        res = sched.finished[i]
        print(f"    req {i} ({len(reqs[i].tokens)} prompt -> "
              f"{reqs[i].gen} gen, {res.status.value}):", res[:12])
    return sched.finished


def _serve_stream(engine, args):
    """Request-stream mode: N staggered requests of varying prompt/gen
    lengths continuously batched through ``engine.Scheduler`` — short
    requests retire and free pages mid-stream while long ones keep
    decoding, and freed slots admit pending requests without touching
    (or re-prefilling) the survivors.

    With ``--inject`` a deterministic chaos schedule rides along (NaN
    logits in one slot, a transient decode exception, a slow step, and
    artificial page-pool pressure plus one mid-flight cancel): the
    stream must still complete, with only the poisoned request FAILED
    and every fault accounted for in the lifecycle counters."""
    import time

    from repro.engine import Scheduler
    from repro.runtime.resilience import Heartbeat, StragglerMonitor

    cfg = engine.cfg
    n = args.stream
    straggler = StragglerMonitor(window=32, threshold=4.0, warmup=3)
    heartbeat = (Heartbeat(args.heartbeat, interval_s=0.0)
                 if args.heartbeat else None)
    sched = Scheduler(engine, straggler=straggler, heartbeat=heartbeat)
    release = None
    if args.inject:
        from repro.engine import faults
        s0 = args.inject_seed
        plan = [faults.NonFiniteLogits(step=3 + s0 % 3, slot=0),
                faults.TransientError(step=6 + s0 % 3),
                faults.SlowStep(step=9 + s0 % 3, delay_s=0.05)]
        faults.inject(sched, decode_faults=plan)
        release = faults.hold_pages(sched, max(1, engine.n_pages // 8))
    # varying lengths: prompts in [P/2, P], gens in [G/2, G].  With
    # --prefix-cache, half the stream shares a common "system prompt"
    # prefix (a whole number of pages) so the radix cache actually hits.
    reqs = _stream_requests(engine, args)
    # staggered arrival: one new request every 2 decode steps
    t0 = time.time()
    arrivals = {i: 2 * i for i in range(n)}
    step = 0
    while len(sched.finished) < n:
        for i, at in arrivals.items():
            if at <= step:
                sched.submit(reqs[i])
        arrivals = {i: a for i, a in arrivals.items() if a > step}
        if args.inject and step == 5 and n > 1:
            sched.cancel(1)  # arrived at step 2 — a mid-flight cancel
        if release is not None and step == 8:
            release()
            release = None
        sched.admit()
        if sched.n_active:
            sched.step()
        step += 1
        if not sched.n_active and not sched.pending and not arrivals:
            # everything terminal (parked requests drain via run())
            sched.run()
    if release is not None:
        release()
    dt = time.time() - t0
    toks = sum(len(v) for v in sched.finished.values())
    print(f"[serve] {cfg.name} request-stream: {n} requests, "
          f"{sched.stats['steps']} decode steps, {toks} tokens in "
          f"{dt:.2f}s; peak pages {sched.stats['peak_pages']}/"
          f"{engine.n_pages} (page_size {engine.page_size}); "
          f"prefills {sched.stats['prefills']} (one per request — "
          "survivors never re-prefill)")
    st = sched.stats
    lat = sched.latency_percentiles()
    print(f"[serve] lifecycle: finished "
          f"{sum(1 for v in sched.finished.values() if v.ok)}, "
          f"failed {st['failed']}, cancelled {st['cancelled']}, "
          f"timed_out {st['timed_out']}, rejected {st['rejected']}; "
          f"retries: step {st['step_retries']} / prefill "
          f"{st['prefill_retries']}; preempted {st['preempted']}, "
          f"parked {st['parked']}, straggler flags "
          f"{st['straggler_flags']}")
    if lat:
        print(f"[serve] request latency: p50 {lat['p50']:.3f}s "
              f"p90 {lat['p90']:.3f}s p99 {lat['p99']:.3f}s")
    itl = sched.itl_percentiles()
    if itl:
        print(f"[serve] inter-token latency: p50 {itl['p50']*1e3:.1f}ms "
              f"p90 {itl['p90']*1e3:.1f}ms p99 {itl['p99']*1e3:.1f}ms")
    if sched.chunked:
        print(f"[serve] chunked prefill: {st['chunks']} chunks / "
              f"{st['chunked_tokens']} prompt tokens over "
              f"{st['mixed_steps']} mixed steps (chunk_tokens "
              f"{sched.chunk_tokens}, token budget "
              f"{sched.token_budget})")
    if sched.prefix is not None:
        print(f"[serve] prefix cache: hits {st['prefix_hits']} / "
              f"misses {st['prefix_misses']}, "
              f"{st['prefix_hit_tokens']} prompt tokens served from "
              f"cache; evictions {st['prefix_evictions']}, peak shared "
              f"pages {st['shared_pages']}, cow forks "
              f"{st['cow_forks']}; {sched.prefix.cached_pages} pages "
              "still cached")
    if args.inject:
        bad = {i: v for i, v in sched.finished.items() if not v.ok}
        for i, v in sorted(bad.items()):
            print(f"    req {i} {v.status.value}: {v.error}")
        assert len(sched.finished) == n, "injected stream lost results"
    for i in range(min(n, 3)):
        res = sched.finished[i]
        print(f"    req {i} ({len(reqs[i].tokens)} prompt -> "
              f"{reqs[i].gen} gen, {res.status.value}):", res[:12])
    return sched.finished


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    cfg = reduced(cfg) if args.reduce == "smoke" else width_reduce(cfg)
    if cfg.mamba2 is not None or cfg.xlstm is not None:
        chunk = (cfg.mamba2 or cfg.xlstm).chunk
        assert args.prompt_len % chunk == 0

    engine = DecodeEngine(cfg, engine_config_from_args(args, cfg))
    cfg = engine.cfg

    if args.stream:
        if args.snapshot_dir:
            return _serve_durable(engine, args)
        return _serve_stream(engine, args)

    B, P, G = args.batch, args.prompt_len, args.gen
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(2, cfg.vocab, (B, P)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["frontend_emb"] = jnp.asarray(rng.standard_normal(
            (B, cfg.frontend_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "audio":
        batch["frontend_emb"] = jnp.asarray(rng.standard_normal(
            (B, P, cfg.frontend_dim)), jnp.float32)

    gen, stats = engine.generate(batch, G, temperature=args.temperature)

    print(f"[serve] {cfg.name}: prefill {B}x{P} in "
          f"{stats['t_prefill_s']:.2f}s ({stats['prefill_tok_s']:.0f} "
          f"tok/s); decode {G-1} steps in {stats['t_decode_s']:.2f}s "
          f"({stats['decode_tok_s']:.0f} tok/s)")
    print("[serve] sample generations (token ids):")
    for b in range(min(B, 2)):
        print("   ", np.asarray(gen[b])[:16])
    return gen


if __name__ == "__main__":
    main()
