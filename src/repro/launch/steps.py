"""Step functions + abstract input specs for every (arch x shape) cell.

``build_train_step`` returns the full production step: microbatched
grad accumulation (scan) -> AdamW update -> metrics; ``input_specs``
returns weak-type-correct ShapeDtypeStructs for everything the step
takes, so the multi-pod dry-run lowers with zero allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, ShapeSpec, SHAPES_BY_NAME
from repro.models import lm
from repro.optim import adamw


# ======================================================================
# train step
# ======================================================================

def build_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return lm.train_loss(params, batch, cfg)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        nm = cfg.n_microbatches
        if nm > 1:
            # microbatch accumulation: scan over batch splits; XLA
            # overlaps each microbatch's grad reduce with the next
            # microbatch's compute (compute/comm overlap)
            def split(x):
                B = x.shape[0]
                return x.reshape(nm, B // nm, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def mb_step(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                return (acc_g, acc_l + loss), metrics

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                mb_step, (zero_g, jnp.zeros(())), mbatch)
            grads = jax.tree.map(lambda g: g / nm, grads)
            # average the stacked (nm, ...) aux metrics like the loss —
            # taking m[-1] would log only the final microbatch's view
            metrics = jax.tree.map(lambda m: m.mean(axis=0), metrics)
            metrics["loss"] = loss_sum / nm
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, {**metrics, **om}

    return train_step


def build_prefill(cfg: ModelConfig, mesh=None):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, mesh=mesh)
    return prefill_step


def build_suffix_prefill(cfg: ModelConfig, mesh=None):
    """Suffix-only prefill against a prefix already resident in the
    page pools (prefix-cache hit): batch carries the suffix ``tokens``,
    the matched ``pages`` and the live ``cache``; the matched length
    rides the pages operand's shape, so jit compiles once per
    (suffix_len, prefix_len) pair — the same per-shape discipline as
    whole-prompt prefill."""
    def suffix_prefill_step(params, batch):
        return lm.prefill_suffix(params, batch, cfg, mesh=mesh)
    return suffix_prefill_step


def build_decode(cfg: ModelConfig, mesh=None):
    """One-token serve step with the mesh passed explicitly through
    ``lm.decode_step`` (no ambient-mesh lookup on the decode hot path).

    With a mesh, the step pins the returned logits/cache to the decode
    sharding vocabulary (dist.sharding), so chained decode calls under
    jit never drift layouts — ``engine.DecodeEngine`` runs this end to
    end (sequence-sharded caches when cfg.decode_shard == 'seq')."""
    if mesh is None:
        def serve_step(params, batch):
            return lm.decode_step(params, batch, cfg)
        return serve_step

    from repro.dist import sharding as SH

    def _paged_n_pages(cache):
        """Pool page count, read off the family's paged KV leaf."""
        if cfg.family == "audio":
            return cache["self_k"].shape[1]
        sub = cache["moe"] if cfg.family == "moe" else cache
        leaf = sub["ckv"] if cfg.mla is not None else sub["k"]
        return leaf.shape[1]

    def _paged_quantized(cache):
        """int8 pools carry fp32 scale sidecars in the cache tree."""
        sub = cache["moe"] if cfg.family == "moe" else cache
        return ("ckv_scale" if cfg.mla is not None else "k_scale") in sub

    def sharded_serve_step(params, batch):
        logits, cache = lm.decode_step(params, batch, cfg, mesh=mesh)
        B = logits.shape[0]
        if "block_table" in batch:
            pspecs = SH.paged_cache_pspecs(
                cfg, mesh, B, seq_shard=(cfg.decode_shard == "seq"),
                n_pages=_paged_n_pages(cache),
                quantized=_paged_quantized(cache))
        else:
            pspecs = SH.decode_batch_pspecs(
                cfg, mesh, B, seq_shard=(cfg.decode_shard == "seq"))["cache"]
        shardings = SH.to_shardings(mesh, pspecs)
        cache = jax.tree.map(jax.lax.with_sharding_constraint,
                             cache, shardings)
        return logits, cache

    return sharded_serve_step


def build_mixed_step(cfg: ModelConfig, mesh=None):
    """Unified mixed prefill/decode step: one jitted call runs a
    prompt chunk AND the whole decode batch against the shared pools.

    batch carries the decode operands (``token`` (B,), ``cur_len``
    (B,), ``block_table`` (B, W), ``cache``) plus the chunk operands
    (``chunk_tokens`` (1, C), ``chunk_pages`` (J_p,) — the chunk's
    prior pages, ``chunk_write_pages`` (J_w,) — the pages the chunk's
    KV lands in).  The chunk prefills first (``lm.prefill_chunk``
    computes + scatters its KV), then the decode batch steps over the
    updated cache — the ordering is value-neutral for the decoding
    slots (their block tables never alias the chunk's pages) and the
    chunk's own slot rides the decode batch inactive (cur_len == 0:
    write dropped, attention masked, logits discarded).

    Shapes are static per (C, J_p, J_w, decode-bucket) combination and
    ride the existing bucketing machinery; the scheduler keeps C at
    ``chunk_tokens`` for every non-final chunk so steady-state traffic
    reuses one compiled step.

    Returns (decode logits (B, V) fp32, chunk logits (1, V) fp32,
    updated cache).
    """
    def mixed_step(params, batch):
        chunk_logits, cache = lm.prefill_chunk(
            params, {"tokens": batch["chunk_tokens"],
                     "pages": batch["chunk_pages"],
                     "write_pages": batch["chunk_write_pages"],
                     "cache": batch["cache"]}, cfg, mesh=mesh)
        dbatch = {"token": batch["token"], "cur_len": batch["cur_len"],
                  "block_table": batch["block_table"], "cache": cache}
        logits, cache = lm.decode_step(params, dbatch, cfg, mesh=mesh) \
            if mesh is not None else lm.decode_step(params, dbatch, cfg)
        if mesh is not None:
            from repro.dist import sharding as SH
            sub = cache["moe"] if cfg.family == "moe" else cache
            leaf = sub["ckv"] if cfg.mla is not None else sub["k"]
            pspecs = SH.paged_cache_pspecs(
                cfg, mesh, logits.shape[0],
                seq_shard=(cfg.decode_shard == "seq"),
                n_pages=leaf.shape[1],
                quantized=(("ckv_scale" if cfg.mla is not None
                            else "k_scale") in sub))
            shardings = SH.to_shardings(mesh, pspecs)
            cache = jax.tree.map(jax.lax.with_sharding_constraint,
                                 cache, shardings)
        return logits, chunk_logits, cache

    return mixed_step


# ======================================================================
# abstract input specs (dry-run)
# ======================================================================

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract batch for one cell (the modality frontend is a stub:
    precomputed frame/patch embeddings appear directly as inputs)."""
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    bf = jnp.dtype(cfg.dtype)

    if shape.kind in ("train",):
        if cfg.family == "vlm":
            S_txt = S - cfg.frontend_tokens
            return {
                "tokens": _sds((B, S_txt), i32),
                "labels": _sds((B, S_txt), i32),
                "loss_mask": _sds((B, S_txt), f32),
                "frontend_emb": _sds((B, cfg.frontend_tokens,
                                      cfg.frontend_dim), bf),
            }
        out = {
            "tokens": _sds((B, S), i32),
            "labels": _sds((B, S), i32),
            "loss_mask": _sds((B, S), f32),
        }
        if cfg.family == "audio":
            out["frontend_emb"] = _sds((B, S, cfg.frontend_dim), bf)
        return out

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            S_txt = S - cfg.frontend_tokens
            return {"tokens": _sds((B, S_txt), i32),
                    "frontend_emb": _sds((B, cfg.frontend_tokens,
                                          cfg.frontend_dim), bf)}
        out = {"tokens": _sds((B, S), i32)}
        if cfg.family == "audio":
            out["frontend_emb"] = _sds((B, S, cfg.frontend_dim), bf)
        return out

    if shape.kind == "decode":
        return {
            "token": _sds((B,), i32),
            "cur_len": _sds((), i32),
            "cache": lm.cache_spec(cfg, B, S, enc_len=S),
        }

    raise ValueError(shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                opt_cfg: Optional[adamw.OptConfig] = None) -> Dict[str, Any]:
    """Everything the cell's step function takes, as abstract values."""
    params = lm.abstract_init(cfg)
    out: Dict[str, Any] = {"params": params,
                           "batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.OptConfig(
            moment_dtype="bfloat16" if cfg.dtype == "bfloat16"
            else "float32")
        mdt = jnp.dtype(opt_cfg.moment_dtype)
        needs_master = cfg.dtype != "float32"
        out["opt_state"] = adamw.OptState(
            step=_sds((), jnp.int32),
            m=jax.tree.map(lambda a: _sds(a.shape, mdt), params),
            v=jax.tree.map(lambda a: _sds(a.shape, mdt), params),
            master=(jax.tree.map(
                lambda a: _sds(a.shape, jnp.float32), params)
                if needs_master else None),
        )
    return out


def step_fn_for(cfg: ModelConfig, shape: ShapeSpec,
                opt_cfg: Optional[adamw.OptConfig] = None):
    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.OptConfig(
            moment_dtype="bfloat16" if cfg.dtype == "bfloat16"
            else "float32")
        return build_train_step(cfg, opt_cfg)
    if shape.kind == "prefill":
        return build_prefill(cfg)
    return build_decode(cfg)
