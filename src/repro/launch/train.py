"""End-to-end training driver.

Runs on whatever devices exist (1 CPU device for the examples; the same
code path jit-compiles for the production mesh — the dry-run proves
those lowerings).  Wires together: config registry, sharded init,
synthetic/memmap data, AdamW(+ZeRO-1), checkpoint/restart, straggler
monitor, heartbeat, optional int8 gradient compression.

Example (CPU, ~100M model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduce width --steps 200 --batch 8 --seq 512 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.common.config import ModelConfig
from repro.configs import get_config, reduced
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.dist import sharding as SH
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.optim import adamw
from repro.runtime import Heartbeat, StragglerMonitor


def width_reduce(cfg: ModelConfig, d_model: int = 512, layers: int = 8
                 ) -> ModelConfig:
    """~100M-class shrink that keeps the family structure."""
    kw = dict(name=cfg.name + "-100m", n_layers=layers, d_model=d_model,
              n_heads=8, n_kv_heads=max(1, 8 * cfg.n_kv_heads
                                        // max(cfg.n_heads, 1)),
              d_head=64, d_ff=(4 * d_model if cfg.d_ff else 0),
              vocab=8192, dtype="float32", logits_chunk=0)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=d_model,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            d_ff_dense=4 * d_model if cfg.moe.d_ff_dense else 0)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=128, kv_lora_rank=64, rope_head_dim=32,
            nope_head_dim=32, v_head_dim=64)
    if cfg.mamba2 is not None:
        kw["mamba2"] = dataclasses.replace(cfg.mamba2, head_dim=64,
                                           chunk=64, attn_every=3)
        kw["n_layers"] = 9
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, slstm_every=4,
                                          chunk=64)
    if cfg.frontend:
        kw["frontend_tokens"] = min(cfg.frontend_tokens, 32) or 32
        kw["frontend_dim"] = 64
    if cfg.enc_layers:
        kw["enc_layers"] = 4
    return cfg.replace(**kw)


def make_batch_fn(cfg: ModelConfig, batch: int, seq: int, seed=0):
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=seed))

    def get(step: int):
        b = data.batch(step)
        if cfg.family == "vlm":
            nf = cfg.frontend_tokens
            rng = np.random.default_rng(step)
            b = {"tokens": b["tokens"][:, : seq - nf],
                 "labels": b["labels"][:, : seq - nf],
                 "loss_mask": b["loss_mask"][:, : seq - nf],
                 "frontend_emb": rng.standard_normal(
                     (batch, nf, cfg.frontend_dim)).astype(np.float32)}
        elif cfg.family == "audio":
            rng = np.random.default_rng(step)
            b["frontend_emb"] = rng.standard_normal(
                (batch, seq, cfg.frontend_dim)).astype(np.float32)
        return b

    return get


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", choices=["none", "smoke", "width"],
                    default="width")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-model", type=int, nargs=2, default=None,
                    help="mesh shape (data, model)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce == "smoke":
        cfg = reduced(cfg)
    elif args.reduce == "width":
        cfg = width_reduce(cfg)
    cfg = cfg.replace(n_microbatches=args.microbatches,
                      remat="none" if args.reduce != "none" else cfg.remat)
    if cfg.mamba2 is not None or cfg.xlstm is not None:
        chunk = (cfg.mamba2 or cfg.xlstm).chunk
        assert args.seq % chunk == 0, (args.seq, chunk)

    dm = args.data_model or (jax.device_count(), 1)
    mesh = make_local_mesh(*dm)
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}, devices={jax.device_count()}")

    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    train_step = build_train_step(cfg, opt_cfg)

    pspec_params = SH.param_pspecs(cfg, mesh)
    shardings = SH.to_shardings(mesh, pspec_params)
    with mesh:
        params = jax.jit(
            lambda k: lm.init(cfg, k), out_shardings=shardings
        )(jax.random.PRNGKey(0))
        opt_state = adamw.init(opt_cfg, params)
        step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    store = CheckpointStore(args.ckpt) if args.ckpt else None
    start = 0
    if store is not None and store.latest_step() is not None:
        start = store.latest_step()
        tpl = {"params": params, "opt": opt_state}
        restored = store.restore(start, jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tpl))
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start}")

    get_batch = make_batch_fn(cfg, args.batch, args.seq)
    mon = StragglerMonitor()
    hb = Heartbeat(os.path.join(args.ckpt or "/tmp", "heartbeat.json"))
    losses = []

    t_start = time.time()
    with mesh:
        for step in range(start, args.steps):
            mon.start_step()
            batch = get_batch(step)
            params, opt_state, metrics = step_jit(params, opt_state,
                                                  batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                losses.append((step, m["loss"]))
                print(f"  step {step:5d} loss={m['loss']:.4f} "
                      f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f} "
                      f"lr={m['lr']:.2e}")
            flag = mon.end_step()
            if flag:
                print(f"  [straggler] step {flag['step']} took "
                      f"{flag['dt']:.2f}s (median {flag['median']:.2f}s)")
            hb.beat(step)
            if store is not None and (step + 1) % args.ckpt_every == 0:
                store.save(step + 1, {"params": params, "opt": opt_state},
                           async_=True)
    if store is not None:
        store.save(args.steps, {"params": params, "opt": opt_state})
        store.wait()

    dt = time.time() - t_start
    toks = args.steps * args.batch * args.seq
    print(f"[train] done: {dt:.1f}s, {toks/dt:.0f} tok/s, "
          f"first loss {losses[0][1]:.4f} -> last {losses[-1][1]:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": losses, "tok_per_s": toks / dt}, f)
    return losses


if __name__ == "__main__":
    main()
