from repro.models import attention, layers, lm, mla, moe, ssm, xlstm  # noqa: F401
