"""GQA attention with blockwise (flash-style) streaming + KV caches.

The blockwise kernel is the pure-JAX realization of the paper's VWR
streaming discipline applied to attention: KV is consumed in wide blocks
(one "wide transaction"), each block feeding many MXU steps, with fp32
running-softmax accumulators in registers (the R1..R4 analogue).  The
Pallas TPU version lives in ``repro.kernels.vwr_attention``; this module
is the XLA reference path the dry-run lowers.

Decode attention returns *unnormalized* partial results (o_tilde, lse) so
the distribution layer can combine sequence-sharded cache shards with a
psum — distributed FlashDecoding (see dist/decode.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.common.hints import shard_hint
from repro.common.module import ParamDef, zeros_init
from repro.kernels import dispatch as D
from repro.models.layers import apply_rope

NEG_INF = -1e30


# ---------------- projections ----------------

def gqa_spec(cfg):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dtype = jnp.dtype(cfg.dtype)
    spec = {
        "wq": ParamDef((d, H, Dh), dtype, ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, KV, Dh), dtype, ("embed", "kv", "head_dim")),
        "wv": ParamDef((d, KV, Dh), dtype, ("embed", "kv", "head_dim")),
        "wo": ParamDef((H, Dh, d), dtype, ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamDef((H, Dh), dtype, ("heads", "head_dim"), zeros_init)
        spec["bk"] = ParamDef((KV, Dh), dtype, ("kv", "head_dim"), zeros_init)
        spec["bv"] = ParamDef((KV, Dh), dtype, ("kv", "head_dim"), zeros_init)
    return spec


@D.register("qkv_proj", "xla")
def _qkv_proj_xla(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


@D.register("qkv_proj", "pallas")
def _qkv_proj_pallas(p, x):
    from repro.kernels import ops
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)

    def proj(w, b):
        nh, dh = w.shape[1], w.shape[2]
        bias = None if b is None else b.reshape(1, nh * dh)
        out = ops.vwr_matmul(x2, w.reshape(d, nh * dh), bias)
        return out.reshape(B, S, nh, dh)

    return (proj(p["wq"], p.get("bq")),    # qkv bias fused in-kernel
            proj(p["wk"], p.get("bk")),
            proj(p["wv"], p.get("bv")))


def qkv_proj(p, x, positions, rope_theta, backend="xla", *,
             kernel_impl=None):
    """QKV projection (+rope) via the dispatch registry.  ``backend``
    is a backend string or a ModelConfig; the legacy ``kernel_impl=``
    kwarg still works but is deprecated."""
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("attention.qkv_proj")
        backend = kernel_impl
    q, k, v = D.dispatch("qkv_proj", backend, p, x)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


@D.register("o_proj", "xla")
def _o_proj_xla(p, o, residual=None):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out if residual is None else residual + out


@D.register("o_proj", "pallas")
def _o_proj_pallas(p, o, residual=None):
    from repro.kernels import ops
    B, S, H, Dh = o.shape
    d = p["wo"].shape[-1]
    r2 = None if residual is None else residual.reshape(B * S, d)
    out = ops.vwr_matmul(o.reshape(B * S, H * Dh),
                         p["wo"].reshape(H * Dh, d), residual=r2)
    return out.reshape(B, S, d)


def o_proj(p, o, backend="xla", residual=None, *, kernel_impl=None):
    """Output projection; with ``residual`` returns residual + o@wo —
    fused into the matmul's final-K store on the pallas path.  The
    legacy ``kernel_impl=`` kwarg still works but is deprecated."""
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("attention.o_proj")
        backend = kernel_impl
    return D.dispatch("o_proj", backend, p, o, residual=residual)


# ---------------- blockwise flash attention (training / prefill) ----------------

def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def blockwise_attn(
    q: jax.Array,                 # (B, Sq, H, Dh)
    k: jax.Array,                 # (B, Skv, KV, Dh)
    v: jax.Array,                 # (B, Skv, KV, Dh)
    *,
    causal: bool,
    q_positions: Optional[jax.Array] = None,    # (Sq,) global positions
    kv_positions: Optional[jax.Array] = None,   # (Skv,)
    kv_valid: Optional[jax.Array] = None,       # (Skv,) bool padding mask
    block_q: int = 512,
    block_kv: int = 1024,
    head_axis=None,
    mesh=None,
) -> jax.Array:
    """Streaming softmax attention; peak memory O(block_q * block_kv).

    head_axis: mesh axis carrying the kv-head dim.  GSPMD loses the
    head sharding through the block reshapes and then ALL-REDUCES the
    fp32 score tensor per (q,kv) block pair in the remat'd backward
    (measured 825 GB/device/step on qwen train_4k — EXPERIMENTS.md
    §Perf H2a); explicit hints on the blocked operands and the running
    stats keep every block head-sharded."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    orig_dtype = q.dtype
    scale = 1.0 / (Dh ** 0.5)

    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1])
    if kv_valid is None:
        kv_valid = jnp.ones((k.shape[1],), jnp.bool_)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, k.shape[1])

    q, _ = _pad_to(q, block_q, 1)
    qpos, _ = _pad_to(q_positions, block_q, 0)
    k, _ = _pad_to(k, block_kv, 1)
    v, _ = _pad_to(v, block_kv, 1)
    kpos, _ = _pad_to(kv_positions, block_kv, 0)
    kval, _ = _pad_to(kv_valid, block_kv, 0)

    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv

    qb = q.reshape(B, nq, block_q, KV, G, Dh)
    kb = k.reshape(B, nk, block_kv, KV, Dh)
    vb = v.reshape(B, nk, block_kv, KV, Dh)
    if head_axis is not None:
        qb = shard_hint(qb, PS(None, None, None, head_axis, None, None),
                        mesh=mesh)
        kb = shard_hint(kb, PS(None, None, None, head_axis, None),
                        mesh=mesh)
        vb = shard_hint(vb, PS(None, None, None, head_axis, None),
                        mesh=mesh)
    qposb = qpos.reshape(nq, block_q)
    kposb = kpos.reshape(nk, block_kv)
    kvalb = kval.reshape(nk, block_kv)

    def q_step(_, qi):
        q_i, qp_i = qi                                  # (B,bq,KV,G,Dh),(bq,)
        q_i = (q_i.astype(jnp.float32) * scale)

        def kv_step(carry, ki):
            acc, m, l = carry
            k_j, v_j, kp_j, km_j = ki
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_j.astype(jnp.float32)
            )                                            # (B,KV,G,bq,bkv)
            if head_axis is not None:
                s = shard_hint(s, PS(None, head_axis, None, None, None),
                               mesh=mesh)
            mask = km_j[None, None, None, None, :]
            if causal:
                mask = mask & (kp_j[None, :] <= qp_i[:, None])[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))       # (B,KV,G,bq)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_j.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KV, G, block_q, Dh), jnp.float32)
        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kposb, kvalb),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4)               # (B,bq,KV,G,Dh)
        return None, out.astype(orig_dtype)

    _, ob = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1), qposb))
    out = ob.swapaxes(0, 1).reshape(B, nq * block_q, H, Dh)
    return out[:, :Sq]


@D.register("attention", "xla")
def _attention_xla(q, k, v, *, causal, q_positions=None, kv_positions=None,
                   block_q=512, block_kv=1024, head_axis=None, mesh=None):
    return blockwise_attn(q, k, v, causal=causal, q_positions=q_positions,
                          kv_positions=kv_positions, block_q=block_q,
                          block_kv=block_kv, head_axis=head_axis,
                          mesh=mesh)


@D.register("attention", "pallas")
def _attention_pallas(q, k, v, *, causal, q_positions=None,
                      kv_positions=None, block_q=512, block_kv=1024,
                      head_axis=None, mesh=None):
    """Zero-copy GQA flash kernel (blocks autotuned).  The non-causal
    (encoder) path keeps the blockwise formulation, whose kv-padding
    masks don't require S % block == 0."""
    if causal:
        from repro.kernels import ops
        return ops.vwr_attention(q, k, v, causal=True)
    return _attention_xla(q, k, v, causal=causal, q_positions=q_positions,
                          kv_positions=kv_positions, block_q=block_q,
                          block_kv=block_kv, head_axis=head_axis,
                          mesh=mesh)


def full_attn_ref(q, k, v, *, causal, q_positions=None, kv_positions=None,
                  kv_valid=None):
    """Dense oracle used by tests."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1])
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, Dh) / (Dh ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    mask = jnp.ones((Sq, k.shape[1]), jnp.bool_)
    if causal:
        mask = kv_positions[None, :] <= q_positions[:, None]
    if kv_valid is not None:
        mask = mask & kv_valid[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------- decode (single new token against a cache) ----------------

def flash_decode_partial(
    q: jax.Array,          # (B, H, Dh) — one new token
    cache_k: jax.Array,    # (B, T, KV, Dh) — local shard of the cache
    cache_v: jax.Array,
    kv_positions: jax.Array,  # (T,) global positions of the shard
    cur_len: jax.Array,       # scalar: tokens valid so far (global)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (o_tilde, m, l) with o_tilde = sum(exp(s - m) * v).

    Combining shards i (distributed FlashDecoding, dist/decode.py):
        m* = max_i m_i            (pmax over the cache-sharded axis)
        o  = sum_i o_tilde_i * exp(m_i - m*) / sum_i l_i * exp(m_i - m*)
    """
    B, H, Dh = q.shape
    KV = cache_k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Dh) / (Dh ** 0.5)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, cache_k.astype(jnp.float32))
    valid = kv_positions < cur_len                           # (T,)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                       # (B,KV,G)
    p = jnp.exp(s - m[..., None])
    # rows with no valid key (m == NEG_INF) contribute l = 0
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = p.sum(axis=-1)
    o_t = jnp.einsum("bhgt,bthd->bhgd", p, cache_v.astype(jnp.float32))
    return (o_t.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


def decode_attend_local(q, cache_k, cache_v, kv_positions, cur_len):
    """Single-shard decode attention (normalized)."""
    o_t, m, l = flash_decode_partial(q, cache_k, cache_v, kv_positions, cur_len)
    return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


# Registered decode-partial contract (shared by GQA, MLA-absorbed and
# cross-attention decode — dist.decode combines the partials across
# sequence shards): (q (B,H,Dh), k/v (B,T,KV,Dh) slab starting at
# global position pos0, cur_len) -> fp32 (o_tilde, m, l).

@D.register("decode_partial", "xla")
def _decode_partial_xla(q, k, v, cur_len, pos0=0, *, tune=True):
    T = k.shape[1]
    return flash_decode_partial(q, k, v, pos0 + jnp.arange(T), cur_len)


# ---------------- paged decode (block-table-indexed page pool) ----------------

def paged_flash_decode_partial(
    q: jax.Array,            # (B, H, Dh) — one new token per slot
    k_pool: jax.Array,       # (n_pages, page_size, KV, Dh) shared pool
    v_pool: jax.Array,
    block_table: jax.Array,  # (B, max_pages) int32 physical page ids
    page_counts: jax.Array,  # (B, max_pages) int32 valid tokens per page
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """XLA gather reference for the paged decode contract.

    Gathers each slot's pages from the pool (``pool[block_table]``) and
    runs the same online-softmax partial as ``flash_decode_partial``,
    masked per (slot, page) by ``page_counts`` (0 = page fully masked:
    past the slot's length, unallocated, or owned by another shard).
    Returns fp32 (o_tilde (B,H,Dh), m (B,H), l (B,H)).
    """
    B, H, Dh = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    J = block_table.shape[1]
    tbl = jnp.clip(block_table, 0, n_pages - 1)
    k = k_pool[tbl]                              # (B, J, ps, KV, Dh)
    v = v_pool[tbl]
    valid = (jnp.arange(ps)[None, None, :]
             < page_counts[..., None]).reshape(B, J * ps)
    k = k.reshape(B, J * ps, KV, Dh)
    v = v.reshape(B, J * ps, KV, Dh)
    qf = q.astype(jnp.float32).reshape(B, KV, G, Dh) / (Dh ** 0.5)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = p.sum(axis=-1)
    o_t = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return (o_t.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


# page_size/max_pages ride as STATIC kwargs folded into the dispatch
# cache key (dispatch._arg_signature).  Today they duplicate the
# pool/table dims already keyed via the operand shapes; carrying them
# explicitly pins the geometry even for a future caller that reshapes
# or pads operands before dispatching, and makes the persisted cache
# entries self-describing.

@D.register("decode_partial_paged", "xla")
def _decode_partial_paged_xla(q, k_pool, v_pool, table, counts, *,
                              page_size=None, max_pages=None,
                              tune=True):
    return paged_flash_decode_partial(q, k_pool, v_pool, table, counts)


@D.register("decode_partial_paged", "pallas")
def _decode_partial_paged_pallas(q, k_pool, v_pool, table, counts, *,
                                 page_size=None, max_pages=None,
                                 tune=True):
    from repro.kernels import ops
    return ops.vwr_paged_flash_decode(q, k_pool, v_pool, table, counts)


@D.register("decode_partial", "pallas")
def _decode_partial_pallas(q, k, v, cur_len, pos0=0, *, tune=True):
    from repro.kernels import autotune, ops
    if tune:
        return ops.vwr_flash_decode(q, k, v, cur_len, pos0=pos0)
    # tune=False (shard_map tracing): block size from the cost-model
    # prior only — the measuring tuner must not fire inside shard_map
    T = k.shape[1]
    cands = autotune.decode_candidates(T, q.shape[-1], str(q.dtype))
    bkv = min(cands, key=lambda c: autotune.decode_prior(
        q.shape[0], T, q.shape[1], k.shape[2], q.shape[-1],
        str(q.dtype), c))[0]
    return ops.vwr_flash_decode(q, k, v, cur_len, pos0=pos0, bkv=bkv)


# ---------------- q8 decode (int8 caches, fp32 scale sidecars) ----------------
#
# Same partial contracts with the cache/pool operands stored int8 and
# fp32 scales alongside: per flattened (B, KV) row for the dense cache,
# per (page, KV head) for the pool.  The XLA references dequantize up
# front (reference clarity); the pallas backends stage the int8 block
# and dequantize in-kernel, which is the whole point — staged HBM
# bytes per token drop 2x vs bf16.  The pool dtype is folded into the
# dispatch cache key (all operand dtypes are), so a bf16-pool 'auto'
# winner never replays for an int8 pool of the same geometry.

@D.register("decode_partial_q8", "xla")
def _decode_partial_q8_xla(q, k, v, k_scale, v_scale, cur_len, pos0=0,
                           *, tune=True):
    T = k.shape[1]
    kf = k.astype(jnp.float32) * k_scale[:, None, :, None]
    vf = v.astype(jnp.float32) * v_scale[:, None, :, None]
    return flash_decode_partial(q, kf, vf, pos0 + jnp.arange(T), cur_len)


@D.register("decode_partial_q8", "pallas")
def _decode_partial_q8_pallas(q, k, v, k_scale, v_scale, cur_len,
                              pos0=0, *, tune=True):
    from repro.kernels import autotune, ops
    if tune:
        return ops.vwr_flash_decode_q8(q, k, v, k_scale, v_scale,
                                       cur_len, pos0=pos0)
    T = k.shape[1]
    cands = autotune.decode_candidates(T, q.shape[-1], "int8")
    bkv = min(cands, key=lambda c: autotune.decode_prior(
        q.shape[0], T, q.shape[1], k.shape[2], q.shape[-1],
        "int8", c))[0]
    return ops.vwr_flash_decode_q8(q, k, v, k_scale, v_scale, cur_len,
                                   pos0=pos0, bkv=bkv)


@D.register("decode_partial_paged_q8", "xla")
def _decode_partial_paged_q8_xla(q, k_pool, v_pool, k_scale, v_scale,
                                 table, counts, *, page_size=None,
                                 max_pages=None, tune=True):
    # dequantize the whole pool: honest reference semantics (and the
    # honest cost of NOT dequantizing in-kernel)
    kf = k_pool.astype(jnp.float32) * k_scale[:, None, :, None]
    vf = v_pool.astype(jnp.float32) * v_scale[:, None, :, None]
    return paged_flash_decode_partial(q, kf, vf, table, counts)


@D.register("decode_partial_paged_q8", "pallas")
def _decode_partial_paged_q8_pallas(q, k_pool, v_pool, k_scale, v_scale,
                                    table, counts, *, page_size=None,
                                    max_pages=None, tune=True):
    from repro.kernels import ops
    return ops.vwr_paged_flash_decode_q8(q, k_pool, v_pool, k_scale,
                                         v_scale, table, counts)


# ---------------- chunked prefill (query chunk vs the paged pool) -------------
#
# Chunked prefill splits a prompt into fixed-token slices that ride
# inside the shared decode step.  Chunk k's attention decomposes into
# two partials under the flash combine contract:
#
#   * a PREFIX partial — the (C, d) query chunk against the prompt's
#     prior pages (earlier chunks + prefix-cache hits resident via the
#     block table), masked per page by valid counts.  This is the
#     registered op below: the pallas backend stages each prior page
#     once for all C queries.
#   * a SELF partial — the C x C causal block over the chunk's own
#     freshly computed KV (``chunk_self_attn_partial``).
#
# ``merge_partials`` folds the two (and, in dist.decode, per-shard
# prefix partials) into one normalized output.

def merge_partials(a, b):
    """Flash-combine two (o_tilde, m, l) partials over the same
    queries.  Exact: a fully masked partial (m = NEG_INF, l = 0)
    contributes nothing."""
    o1, m1, l1 = a
    o2, m2, l2 = b
    m = jnp.maximum(m1, m2)
    s1 = jnp.where(m1 > NEG_INF / 2, jnp.exp(m1 - m), 0.0)
    s2 = jnp.where(m2 > NEG_INF / 2, jnp.exp(m2 - m), 0.0)
    return (o1 * s1[..., None] + o2 * s2[..., None], m,
            l1 * s1 + l2 * s2)


def normalize_partial(o_t, l, dtype):
    return (o_t / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def chunk_prefix_attend_partial(
    q: jax.Array,            # (C, H, Dh) — one prompt's query chunk
    k_pool: jax.Array,       # (n_pages, page_size, KV, Dh) shared pool
    v_pool: jax.Array,
    table: jax.Array,        # (J,) int32 the chunk's PRIOR pages
    counts: jax.Array,       # (J,) int32 valid tokens per page
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """XLA gather reference for the chunk-prefix contract.  Returns
    fp32 (o_tilde (C,H,Dh), m (C,H), l (C,H))."""
    C, H, Dh = q.shape
    n_pages, ps, KV, _ = k_pool.shape
    G = H // KV
    J = table.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1)
    k = k_pool[tbl].reshape(J * ps, KV, Dh)
    v = v_pool[tbl].reshape(J * ps, KV, Dh)
    valid = (jnp.arange(ps)[None, :] < counts[:, None]).reshape(J * ps)
    qf = q.astype(jnp.float32).reshape(C, KV, G, Dh) / (Dh ** 0.5)
    s = jnp.einsum("chgd,thd->chgt", qf, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = p.sum(axis=-1)
    o_t = jnp.einsum("chgt,thd->chgd", p, v.astype(jnp.float32))
    return (o_t.reshape(C, H, Dh), m.reshape(C, H), l.reshape(C, H))


def chunk_self_attn_partial(q, k, v):
    """Causal partial over the chunk's OWN KV: q (C,H,Dh) against
    k/v (C,KV,Dh), position i attending keys [0, i].  A small dense
    (C, C) block — stays XLA."""
    C, H, Dh = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(C, KV, G, Dh) / (Dh ** 0.5)
    s = jnp.einsum("chgd,thd->chgt", qf, k.astype(jnp.float32))
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]
    s = jnp.where(causal[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = p.sum(axis=-1)
    o_t = jnp.einsum("chgt,thd->chgd", p, v.astype(jnp.float32))
    return (o_t.reshape(C, H, Dh), m.reshape(C, H), l.reshape(C, H))


def chunk_prefill_attend(q, chunk_k, chunk_v, k_pool, v_pool, table,
                         counts, *, backend="xla"):
    """Full chunked-prefill attention for one chunk: prefix partial
    (registered op ``chunk_prefix_paged``, q8 routed by the pool's
    scale sidecars being passed as ``k_pool``/``v_pool`` dequantized
    upstream) merged with the within-chunk causal partial, normalized.
    Returns (C, H, Dh) in q's dtype."""
    ps = k_pool.shape[1]
    J = table.shape[0]
    prefix = D.dispatch("chunk_prefix_paged", backend, q, k_pool,
                        v_pool, table, counts, page_size=ps,
                        max_pages=J)
    self_p = chunk_self_attn_partial(q, chunk_k, chunk_v)
    o_t, _, l = merge_partials(prefix, self_p)
    return normalize_partial(o_t, l, q.dtype)


@D.register("chunk_prefix_paged", "xla")
def _chunk_prefix_paged_xla(q, k_pool, v_pool, table, counts, *,
                            page_size=None, max_pages=None, tune=True):
    return chunk_prefix_attend_partial(q, k_pool, v_pool, table, counts)


@D.register("chunk_prefix_paged", "pallas")
def _chunk_prefix_paged_pallas(q, k_pool, v_pool, table, counts, *,
                               page_size=None, max_pages=None,
                               tune=True):
    from repro.kernels import ops
    return ops.vwr_chunk_prefix_attend(q, k_pool, v_pool, table, counts)


@D.register("chunk_prefix_paged_q8", "xla")
def _chunk_prefix_paged_q8_xla(q, k_pool, v_pool, k_scale, v_scale,
                               table, counts, *, page_size=None,
                               max_pages=None, tune=True):
    kf = k_pool.astype(jnp.float32) * k_scale[:, None, :, None]
    vf = v_pool.astype(jnp.float32) * v_scale[:, None, :, None]
    return chunk_prefix_attend_partial(q, kf, vf, table, counts)


@D.register("chunk_prefix_paged_q8", "pallas")
def _chunk_prefix_paged_q8_pallas(q, k_pool, v_pool, k_scale, v_scale,
                                  table, counts, *, page_size=None,
                                  max_pages=None, tune=True):
    from repro.kernels import ops
    return ops.vwr_chunk_prefix_attend_q8(q, k_pool, v_pool, k_scale,
                                          v_scale, table, counts)
