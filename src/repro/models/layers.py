"""Core layers: norms, rotary embeddings, MLPs, embeddings.

Logical axis vocabulary (resolved to mesh axes by dist.sharding.rules):
  embed    d_model dims                (replicated by default)
  vocab    vocabulary dim              -> 'model'
  heads    query-head dim              -> 'model' when divisible
  kv       kv-head dim                 -> 'model' when divisible
  ffn      feed-forward hidden dim     -> 'model'
  experts  routed-expert dim           -> ('data','model') or 'model'
  layers   scan-stacked layer dim      (never sharded)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.module import ParamDef, embed_init, ones_init, zeros_init
from repro.kernels import dispatch as D


def dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------- norms ----------------

def rmsnorm_spec(d, dtype):
    return {"scale": ParamDef((d,), dtype, ("embed",), ones_init)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d, dtype):
    return {
        "scale": ParamDef((d,), dtype, ("embed",), ones_init),
        "bias": ParamDef((d,), dtype, ("embed",), zeros_init),
    }


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------- rotary ----------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------- embedding / unembedding ----------------

def embedding_spec(vocab, d, dtype):
    return {"table": ParamDef((vocab, d), dtype, ("vocab", "embed"), embed_init)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed_spec(vocab, d, dtype):
    return {"w": ParamDef((d, vocab), dtype, ("embed", "vocab"))}


def unembed(p, x):
    return jnp.einsum("...d,dv->...v", x, p["w"])


# ---------------- MLP ----------------

def mlp_spec(d, d_ff, act, dtype):
    if act == "swiglu":
        return {
            "wi": ParamDef((d, d_ff), dtype, ("embed", "ffn")),
            "wg": ParamDef((d, d_ff), dtype, ("embed", "ffn")),
            "wo": ParamDef((d_ff, d), dtype, ("ffn", "embed")),
        }
    return {
        "wi": ParamDef((d, d_ff), dtype, ("embed", "ffn")),
        "wo": ParamDef((d_ff, d), dtype, ("ffn", "embed")),
    }


@D.register("swiglu", "xla")
def _swiglu_xla(x2, wg, wi):
    h = jnp.einsum("md,df->mf", x2, wi)
    g = jnp.einsum("md,df->mf", x2, wg)
    return jax.nn.silu(g.astype(jnp.float32)).astype(x2.dtype) * h


@D.register("swiglu", "pallas")
def _swiglu_pallas(x2, wg, wi):
    from repro.kernels import ops
    return ops.vwr_swiglu(x2, wg, wi)


@D.register("mlp", "xla")
def _mlp_xla(p, x, act, residual=None):
    if act == "swiglu":
        lead, d = x.shape[:-1], x.shape[-1]
        h = D.dispatch("swiglu", "xla", x.reshape(-1, d),
                       p["wg"], p["wi"]).reshape(*lead, -1)
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        fn = jax.nn.gelu if act == "gelu" else jax.nn.relu
        h = fn(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, p["wo"])
    return out if residual is None else residual + out


@D.register("mlp", "pallas")
def _mlp_pallas(p, x, act, residual=None):
    from repro.kernels import ops
    lead, d = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, d)
    r2 = None if residual is None else residual.reshape(
        -1, residual.shape[-1])
    if act == "swiglu":
        # dual-matmul fused swiglu: one staged x block feeds both
        # projections and silu(g) * h happens on the fp32 accumulators
        # in the final-K store — no separate elementwise pass
        h = D.dispatch("swiglu", "pallas", x2,
                       p["wg"], p["wi"]).astype(x.dtype)
    else:
        h = ops.vwr_matmul(x2, p["wi"],
                           activation="gelu" if act == "gelu" else "relu")
    out = ops.vwr_matmul(h, p["wo"], residual=r2)
    return out.reshape(*lead, out.shape[-1])


def mlp(p, x, act: str, *, backend="xla", residual=None,
        kernel_impl=None):
    """FFN block via the dispatch registry.  With ``residual`` the
    residual add is part of the block (``residual + mlp(x)``); on the
    pallas path it is fused into the down-projection's final-K store
    (one HBM round-trip), the non-gated activation into the
    up-projection, and swiglu runs the dual-matmul fused kernel.
    ``backend`` is a backend string or a ModelConfig; the legacy
    ``kernel_impl=`` kwarg still works but is deprecated."""
    if kernel_impl is not None:
        D.warn_kernel_impl_kwarg("layers.mlp")
        backend = kernel_impl
    return D.dispatch("mlp", backend, p, x, act, residual=residual)


# ---------------- frontends (stubs per brief) ----------------

def frontend_proj_spec(raw_dim, d, dtype):
    """Projects precomputed frame/patch embeddings into d_model."""
    return {"w": ParamDef((raw_dim, d), dtype, ("frontend_in", "embed"))}


def frontend_proj(p, emb):
    return jnp.einsum("...r,rd->...d", emb, p["w"])
