"""Model assembly: spec + train/prefill/decode for every assigned family.

Families:
  dense   — pre-norm GQA attn + MLP                      (tinyllama, granite,
                                                          qwen, deepseek-coder)
  moe     — attn (GQA or MLA) + top-k MoE FFN            (olmoe, deepseek-v3)
  hybrid  — Mamba2 stack + one *shared* attn block every
            k layers (Zamba2)                            (zamba2)
  ssm     — mLSTM stack with 1-in-k sLSTM layers         (xlstm)
  vlm     — dense decoder + vision-frontend stub prefix  (internvl2)
  audio   — encoder-decoder, audio-frontend stub         (seamless-m4t)

Conventions:
  * attn/mlp sub-blocks take the residual stream as ``residual=`` and
    return the updated stream (the add is fused into the Pallas
    epilogue on the 'pallas' dispatch backend); SSM/MoE sub-blocks
    still return the residual *delta*.  Pre-norms are applied by the
    caller (exception: sLSTM blocks norm internally).
  * layer stacks are stored stacked (L, ...) and iterated with lax.scan
    (cfg.scan_layers=False unrolls — used by the roofline accounting pass,
    since XLA cost_analysis counts while bodies once; DESIGN.md §8).
  * decode caches ride through the layer scan as xs/ys so a step touches
    each layer's cache exactly once.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import module as M
from repro.common.hints import shard_batch  # noqa: F401  (re-export)
from repro.kernels import dispatch as D
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL


# ======================================================================
# norms
# ======================================================================

def _norm_spec(cfg):
    if cfg.norm == "layernorm":
        return L.layernorm_spec(cfg.d_model, jnp.dtype(cfg.dtype))
    return L.rmsnorm_spec(cfg.d_model, jnp.dtype(cfg.dtype))


def _norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return L.layernorm(p, x, cfg.norm_eps)
    return L.rmsnorm(p, x, cfg.norm_eps)


# ======================================================================
# per-family layer specs
# ======================================================================

def _attn_spec(cfg):
    return MLA.mla_spec(cfg) if cfg.mla is not None else A.gqa_spec(cfg)


def _dense_layer_spec(cfg, d_ff=None):
    return {
        "attn_norm": _norm_spec(cfg),
        "attn": _attn_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "mlp": L.mlp_spec(cfg.d_model, d_ff or cfg.d_ff, cfg.act,
                          jnp.dtype(cfg.dtype)),
    }


def _moe_layer_spec(cfg):
    return {
        "attn_norm": _norm_spec(cfg),
        "attn": _attn_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "moe": MOE.moe_spec(cfg),
    }


def _encoder_layer_spec(cfg):
    return {
        "attn_norm": _norm_spec(cfg),
        "attn": A.gqa_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, jnp.dtype(cfg.dtype)),
    }


def _decoder_xattn_layer_spec(cfg):
    return {
        "self_norm": _norm_spec(cfg),
        "self": A.gqa_spec(cfg),
        "cross_norm": _norm_spec(cfg),
        "cross": A.gqa_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, jnp.dtype(cfg.dtype)),
    }


def _shared_attn_block_spec(cfg):
    """Zamba2 shared block: attn + MLP, one set of weights for the stack."""
    return {
        "attn_norm": _norm_spec(cfg),
        "attn": A.gqa_spec(cfg),
        "mlp_norm": _norm_spec(cfg),
        "mlp": L.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, jnp.dtype(cfg.dtype)),
    }


# hybrid (zamba2) group structure: n_layers mamba blocks in groups of
# `attn_every`, a shared-attn invocation after each group.
def _hybrid_groups(cfg):
    k = cfg.mamba2.attn_every
    n_main_groups = cfg.n_layers // k
    tail = cfg.n_layers - n_main_groups * k
    n_invocations = n_main_groups + (1 if tail else 0)
    return k, n_main_groups, tail, n_invocations


# ssm (xlstm) group structure: groups of (slstm_every-1 mLSTM + 1 sLSTM)
def _ssm_groups(cfg):
    k = cfg.xlstm.slstm_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    n_groups = cfg.n_layers // k
    return k - 1, n_groups           # mlstm per group, group count


# ======================================================================
# model spec
# ======================================================================

def model_spec(cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    spec: Dict[str, Any] = {
        "embed": L.embedding_spec(cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = L.unembed_spec(cfg.vocab_padded, cfg.d_model,
                                         dtype)

    if cfg.frontend:
        spec["frontend"] = L.frontend_proj_spec(cfg.frontend_dim, cfg.d_model,
                                                dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        spec["layers"] = M.stack_specs(_dense_layer_spec(cfg), cfg.n_layers)
    elif fam == "moe":
        m = cfg.moe
        if m.first_k_dense:
            spec["dense_layers"] = M.stack_specs(
                _dense_layer_spec(cfg, d_ff=m.d_ff_dense or cfg.d_ff),
                m.first_k_dense)
        spec["layers"] = M.stack_specs(_moe_layer_spec(cfg),
                                       cfg.n_layers - m.first_k_dense)
    elif fam == "hybrid":
        k, n_main, tail, _ = _hybrid_groups(cfg)
        spec["mamba_main"] = M.stack_specs(
            M.stack_specs(SSM.mamba2_spec(cfg), k), n_main)
        spec["mamba_norms"] = M.stack_specs(
            M.stack_specs(_norm_spec(cfg), k), n_main)
        if tail:
            spec["mamba_tail"] = M.stack_specs(SSM.mamba2_spec(cfg), tail)
            spec["tail_norms"] = M.stack_specs(_norm_spec(cfg), tail)
        spec["shared_attn"] = _shared_attn_block_spec(cfg)
    elif fam == "ssm":
        m_per, n_groups = _ssm_groups(cfg)
        spec["mlstm"] = M.stack_specs(
            M.stack_specs(XL.mlstm_spec(cfg), m_per), n_groups)
        spec["mlstm_norms"] = M.stack_specs(
            M.stack_specs(_norm_spec(cfg), m_per), n_groups)
        spec["slstm"] = M.stack_specs(XL.slstm_spec(cfg), n_groups)
    elif fam == "audio":
        spec["enc_layers"] = M.stack_specs(_encoder_layer_spec(cfg),
                                           cfg.enc_layers)
        spec["enc_norm"] = _norm_spec(cfg)
        spec["layers"] = M.stack_specs(_decoder_xattn_layer_spec(cfg),
                                       cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return spec


def abstract_init(cfg):
    return M.abstract_params(model_spec(cfg))


def init(cfg, key):
    return M.init_params(model_spec(cfg), key)


# ======================================================================
# remat / scan plumbing
# ======================================================================

def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_stack(cfg, body, x, stacked, extra_xs=None, length=None):
    """Run `body(x, layer_params, extra) -> (x, y)` over a stacked tree.

    (H8, measured: per-layer batch pins fix the backward batch-
    sharding loss but force 560 GB of re-gathers — refuted; the
    single entry pin in `backbone` is the kept variant.)"""
    body = _maybe_remat(cfg, body)
    if cfg.scan_layers:
        def f(c, xs):
            lp, ex = xs
            return body(c, lp, ex)
        xs = (stacked, extra_xs)
        return jax.lax.scan(f, x, xs, length=length)
    # unrolled (accounting / debugging)
    n = length
    if n is None:
        n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked)
        ex = None if extra_xs is None else jax.tree.map(
            lambda a: a[i], extra_xs)
        x, y = body(x, lp, ex)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return x, ys


# ======================================================================
# layer bodies (training / prefill)
# ======================================================================

def _attn_delta(cfg, ap, h, positions, *, causal=True, residual=None,
                mesh=None):
    """h already normed; ap = attention param subtree.

    Returns (residual + attn(h) if residual is given else attn(h),
    (k, v)) for cache building.  The residual add is fused into the
    output projection's final-K store on the pallas kernel path.  All
    implementation choice goes through the dispatch registry
    (cfg.kernel_impl selects the backend: 'xla' | 'pallas' | 'auto')."""
    if cfg.mla is not None:
        out, cache = MLA.mla_attention(ap, h, positions, cfg, causal=causal,
                                       dense=cfg.accounting,
                                       head_axis=_head_axis(cfg),
                                       mesh=mesh)
        return (out if residual is None else residual + out), cache
    q, k, v = A.qkv_proj(ap, h, positions, cfg.rope_theta, cfg)
    if cfg.accounting:
        o = A.full_attn_ref(q, k, v, causal=causal, q_positions=positions,
                            kv_positions=positions)
    else:
        o = D.dispatch("attention", cfg, q, k, v, causal=causal,
                       q_positions=positions, kv_positions=positions,
                       block_q=cfg.attn_block_q,
                       block_kv=cfg.attn_block_kv,
                       head_axis=_head_axis(cfg), mesh=mesh)
    return A.o_proj(ap, o, cfg, residual=residual), (k, v)


def _head_axis(cfg):
    """Mesh axis carrying kv heads in the activation layout (None when
    heads are replicated, e.g. the 'ddp' strategy)."""
    if cfg.sharding_strategy == "ddp":
        return None
    return "model"


def _dense_body(cfg, positions, x, lp, _ex, *, causal=True, collect=False,
                mesh=None):
    x, kv = _attn_delta(cfg, lp["attn"], _norm(cfg, lp["attn_norm"], x),
                        positions, causal=causal, residual=x, mesh=mesh)
    x = L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
              backend=cfg, residual=x)
    return x, (kv if collect else None)


def _moe_body(cfg, positions, x, lp, _ex, *, collect=False, mesh=None):
    x, kv = _attn_delta(cfg, lp["attn"], _norm(cfg, lp["attn_norm"], x),
                        positions, residual=x, mesh=mesh)
    y, aux = MOE.moe_ffn(lp["moe"], _norm(cfg, lp["mlp_norm"], x), cfg,
                          mesh=mesh)
    return x + y, ((kv if collect else None), aux)


def _xattn_body(cfg, positions, enc_out, enc_valid, x, lp, _ex, *,
                collect=False, mesh=None):
    """Encoder-decoder decoder layer (training/prefill)."""
    x, kv = _attn_delta(cfg, lp["self"], _norm(cfg, lp["self_norm"], x),
                        positions, residual=x, mesh=mesh)
    h = _norm(cfg, lp["cross_norm"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
    if cfg.accounting:
        o = A.full_attn_ref(q, k, v, causal=False, kv_valid=enc_valid)
    else:
        o = A.blockwise_attn(q, k, v, causal=False, kv_valid=enc_valid,
                             block_q=cfg.attn_block_q,
                             block_kv=cfg.attn_block_kv, mesh=mesh)
    x = A.o_proj(lp["cross"], o, cfg, residual=x)
    x = L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
              backend=cfg, residual=x)
    return x, ((kv, (k, v)) if collect else None)


def _shared_attn_apply(cfg, sp, x, positions, *, collect=False, mesh=None):
    x, kv = _attn_delta(cfg, sp["attn"], _norm(cfg, sp["attn_norm"], x),
                        positions, residual=x, mesh=mesh)
    x = L.mlp(sp["mlp"], _norm(cfg, sp["mlp_norm"], x), cfg.act,
              backend=cfg, residual=x)
    return x, (kv if collect else None)


# ======================================================================
# backbone forward (training / prefill): tokens -> final hidden states
# ======================================================================

class ForwardOut(NamedTuple):
    h: jax.Array                      # (B, S, D) final hidden (post-norm)
    aux: Dict[str, jax.Array]         # scalar aux metrics (moe losses, ...)
    caches: Any                       # per-family cache material (prefill)


def backbone(params, tokens, cfg, *, frontend_emb=None,
             enc_tokens_valid=None, collect_cache=False,
             mesh=None) -> ForwardOut:
    """tokens: (B, S_text) int32. frontend_emb: (B, S_f, fe_dim) or None.

    For 'audio', frontend_emb is the ENCODER input sequence and tokens are
    decoder tokens.  For 'vlm', frontend embeddings are projected and
    prepended to the token embeddings (sequence = S_f + S_text).
    ``collect_cache=True`` (prefill) additionally returns the per-layer
    cache material (KV stacks / recurrent final states).  ``mesh`` (the
    engine passes it) resolves the internal sharding hints explicitly
    instead of through the deprecated ambient-mesh lookup.
    """
    fam = cfg.family
    cc = collect_cache
    aux: Dict[str, jax.Array] = {}
    caches: Any = None

    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if fam == "vlm":
        pre = L.frontend_proj(params["frontend"], frontend_emb)
        x = jnp.concatenate([pre.astype(x.dtype), x], axis=1)
    # NOTE: batch-pinning x here (H8b) trades -183 GB all-reduce for
    # +495 GB all-gather on the fixed (16,16) mesh — net worse on the
    # ICI roofline, big HBM win (bytes_accessed -73%); kept OFF, see
    # EXPERIMENTS.md §Perf H8.
    S = x.shape[1]
    positions = jnp.arange(S)

    if fam in ("dense", "vlm"):
        body = functools.partial(_dense_body, cfg, positions, collect=cc,
                                 mesh=mesh)
        x, kvs = _scan_stack(cfg, body, x, params["layers"])
        caches = kvs

    elif fam == "moe":
        m = cfg.moe
        kv_d = None
        if m.first_k_dense:
            body = functools.partial(_dense_body, cfg, positions,
                                     collect=cc, mesh=mesh)
            x, kv_d = _scan_stack(cfg, body, x, params["dense_layers"])
        body = functools.partial(_moe_body, cfg, positions, collect=cc,
                                 mesh=mesh)
        x, (kv_m, moe_aux) = _scan_stack(cfg, body, x, params["layers"])
        aux["lb_loss"] = jnp.mean(moe_aux["lb_loss"])
        aux["z_loss_router"] = jnp.mean(moe_aux["z_loss"])
        aux["drop_frac"] = jnp.mean(moe_aux["drop_frac"])
        caches = (kv_d, kv_m)

    elif fam == "hybrid":
        k, n_main, tail, _ = _hybrid_groups(cfg)
        sp = params["shared_attn"]

        def mamba_body(x, lp, ex):
            d, st = SSM.mamba2_forward(lp, _norm(cfg, ex, x), cfg)
            return x + d, (st if cc else None)

        def group_body(x, gp, gn):
            x, sts = _scan_stack(cfg, mamba_body, x, gp, extra_xs=gn)
            x, kv = _shared_attn_apply(cfg, sp, x, positions, collect=cc,
                                       mesh=mesh)
            return x, (sts, kv)

        x, (st_main, kv_main) = _scan_stack(
            cfg, group_body, x, params["mamba_main"],
            extra_xs=params["mamba_norms"])
        st_tail = kv_tail = None
        if tail:
            x, st_tail = _scan_stack(cfg, mamba_body, x, params["mamba_tail"],
                                     extra_xs=params["tail_norms"])
            x, kv_tail = _shared_attn_apply(cfg, sp, x, positions,
                                            collect=cc, mesh=mesh)
        caches = ((st_main, kv_main), (st_tail, kv_tail))

    elif fam == "ssm":
        def ml_body(x, lp, ex):
            d, st = XL.mlstm_forward(lp, _norm(cfg, ex, x), cfg)
            return x + d, (st if cc else None)

        def group_body(x, gp, _ex):
            x, m_sts = _scan_stack(cfg, ml_body, x, gp["m"], extra_xs=gp["n"])
            d, s_st = XL.slstm_forward(gp["s"], x, cfg)
            return x + d, ((m_sts, s_st) if cc else None)

        stacked = {"m": params["mlstm"], "n": params["mlstm_norms"],
                   "s": params["slstm"]}
        x, caches = _scan_stack(cfg, group_body, x, stacked)

    elif fam == "audio":
        enc = L.frontend_proj(params["frontend"], frontend_emb)
        enc = enc.astype(jnp.dtype(cfg.dtype))
        enc_pos = jnp.arange(enc.shape[1])
        body = functools.partial(_dense_body, cfg, enc_pos, causal=False,
                                 mesh=mesh)
        enc, _ = _scan_stack(cfg, body, enc, params["enc_layers"])
        enc = _norm(cfg, params["enc_norm"], enc)

        body = functools.partial(_xattn_body, cfg, positions, enc,
                                 enc_tokens_valid, collect=cc, mesh=mesh)
        x, caches = _scan_stack(cfg, body, x, params["layers"])

    else:
        raise ValueError(fam)

    x = _norm(cfg, params["final_norm"], x)
    return ForwardOut(h=x, aux=aux, caches=caches)


# ======================================================================
# loss
# ======================================================================

def _logits(params, h, cfg):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, params["embed"]["table"])
    else:
        logits = L.unembed(params["unembed"], h)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32)
                           ).astype(logits.dtype)
    return logits


def ce_loss(params, h, labels, mask, cfg) -> Tuple[jax.Array, Dict]:
    """Cross-entropy over (B,S,D) hiddens, optionally chunked along S.

    The unembedding is vocab-sharded ('model' axis); logsumexp and the
    label-logit gather over the sharded vocab dim lower to partial
    reductions + a small all-reduce under GSPMD (vocab-parallel CE).
    """
    B, S, D = h.shape
    C = cfg.logits_chunk or S
    C = min(C, S)
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nchunk = h.shape[1] // C

    def one_chunk(hc, lc, mc):
        logits = _logits(params, hc, cfg).astype(jnp.float32)
        lz = jax.nn.logsumexp(logits, axis=-1)                 # (B,C)
        # label pick via a masked sum over the (model-sharded) vocab
        # dim: GSPMD reduces fp32 (B,C) partials with a tiny psum.
        # (take_along_axis over a sharded dim lowers to an all-reduce
        # of the FULL fp32 logits — measured 8-40 GB/device/step;
        # EXPERIMENTS.md §Perf H1.)
        hit = jnp.arange(logits.shape[-1]) == lc[..., None]
        ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        loss = (lz - ll) * mc
        zl = (lz * lz) * mc
        return loss.sum(), zl.sum()

    if nchunk == 1:
        loss_sum, z_sum = one_chunk(h, labels, mask)
    else:
        hs = h.reshape(B, nchunk, C, D).swapaxes(0, 1)
        ls = labels.reshape(B, nchunk, C).swapaxes(0, 1)
        ms = mask.reshape(B, nchunk, C).swapaxes(0, 1)
        if cfg.scan_layers:
            def step(acc, xs):
                a, b = one_chunk(*xs)
                return (acc[0] + a, acc[1] + b), None
            (loss_sum, z_sum), _ = jax.lax.scan(
                step, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
        else:
            loss_sum = z_sum = jnp.zeros(())
            for i in range(nchunk):
                a, b = one_chunk(hs[i], ls[i], ms[i])
                loss_sum, z_sum = loss_sum + a, z_sum + b

    denom = jnp.maximum(mask.sum(), 1.0)
    return loss_sum / denom, {"z_loss": z_sum / denom}


def train_loss(params, batch, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens (B,S), labels (B,S), loss_mask (B,S) [+ frontend_emb]."""
    # the registry knows which backends differentiate: 'auto' narrows
    # to the differentiable set, a forward-only pin ('pallas') raises
    cfg = cfg.replace(kernel_impl=D.training_backend(cfg))
    out = backbone(params, batch["tokens"], cfg,
                   frontend_emb=batch.get("frontend_emb"))
    labels, mask = batch["labels"], batch["loss_mask"].astype(jnp.float32)
    if cfg.family == "vlm":
        # hidden seq = frontend prefix + text; loss only on text part
        nf = batch["frontend_emb"].shape[1]
        h = out.h[:, nf:, :]
    else:
        h = out.h
    loss, lmx = ce_loss(params, h, labels, mask, cfg)
    metrics = {"ce": loss, **lmx, **out.aux}
    total = loss + cfg.z_loss_coef * lmx["z_loss"]
    if "lb_loss" in out.aux:
        total = total + cfg.lb_coef * out.aux["lb_loss"] \
            + cfg.router_z_coef * out.aux["z_loss_router"]
    metrics["loss"] = total
    return total, metrics


# ======================================================================
# prefill / decode (serving)
# ======================================================================

def _gqa_cache_shape(cfg, B, T):
    return (B, T, cfg.n_kv_heads, cfg.d_head)


def cache_spec(cfg, batch: int, max_len: int, enc_len: int = 0):
    """ShapeDtypeStruct tree for the decode cache (dry-run / allocation)."""
    fam = cfg.family
    dt_ = jnp.dtype(cfg.dtype)
    f32 = jnp.float32

    def sds(shape, dtype=dt_):
        return jax.ShapeDtypeStruct(shape, dtype)

    if fam in ("dense", "vlm"):
        if cfg.mla is not None:
            m = cfg.mla
            return {"ckv": sds((cfg.n_layers, batch, max_len, m.kv_lora_rank)),
                    "krope": sds((cfg.n_layers, batch, max_len,
                                  m.rope_head_dim))}
        sh = _gqa_cache_shape(cfg, batch, max_len)
        return {"k": sds((cfg.n_layers, *sh)), "v": sds((cfg.n_layers, *sh))}

    if fam == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        if cfg.mla is not None:
            ml = cfg.mla

            def mla_c(L):
                return {"ckv": sds((L, batch, max_len, ml.kv_lora_rank)),
                        "krope": sds((L, batch, max_len, ml.rope_head_dim))}
            return {"dense": mla_c(m.first_k_dense) if m.first_k_dense else None,
                    "moe": mla_c(n_moe)}
        sh = _gqa_cache_shape(cfg, batch, max_len)

        def gqa_c(L):
            return {"k": sds((L, *sh)), "v": sds((L, *sh))}
        return {"dense": gqa_c(m.first_k_dense) if m.first_k_dense else None,
                "moe": gqa_c(n_moe)}

    if fam == "hybrid":
        mc = cfg.mamba2
        k, n_main, tail, n_inv = _hybrid_groups(cfg)
        d_inner = mc.expand * cfg.d_model
        H = d_inner // mc.head_dim
        d_xbc = d_inner + 2 * mc.n_groups * mc.d_state
        sh = _gqa_cache_shape(cfg, batch, max_len)

        def mstate(*lead):
            return SSM.Mamba2State(
                ssm=sds((*lead, batch, H, mc.d_state, mc.head_dim), f32),
                conv=sds((*lead, batch, mc.d_conv - 1, d_xbc)))
        return {
            "mamba_main": mstate(n_main, k),
            "mamba_tail": mstate(tail) if tail else None,
            "attn_k": sds((n_inv, *sh)), "attn_v": sds((n_inv, *sh)),
        }

    if fam == "ssm":
        xc = cfg.xlstm
        m_per, n_groups = _ssm_groups(cfg)
        d_inner = int(xc.proj_factor * cfg.d_model)
        H = cfg.n_heads
        P = d_inner // H
        return {
            "mlstm": XL.MLSTMState(
                C=sds((n_groups, m_per, batch, H, P, P), f32),
                n=sds((n_groups, m_per, batch, H, P), f32),
                m=sds((n_groups, m_per, batch, H), f32),
                conv=sds((n_groups, m_per, batch, xc.conv1d_kernel - 1,
                          d_inner))),
            "slstm": XL.SLSTMState(
                c=sds((n_groups, batch, cfg.d_model), f32),
                n=sds((n_groups, batch, cfg.d_model), f32),
                h=sds((n_groups, batch, cfg.d_model), f32),
                m=sds((n_groups, batch, cfg.d_model), f32)),
        }

    if fam == "audio":
        sh = _gqa_cache_shape(cfg, batch, max_len)
        xh = _gqa_cache_shape(cfg, batch, enc_len or max_len)
        return {"self_k": sds((cfg.n_layers, *sh)),
                "self_v": sds((cfg.n_layers, *sh)),
                "cross_k": sds((cfg.n_layers, *xh)),
                "cross_v": sds((cfg.n_layers, *xh))}

    raise ValueError(fam)


def init_cache(cfg, batch: int, max_len: int, enc_len: int = 0):
    spec = cache_spec(cfg, batch, max_len, enc_len)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # mLSTM / sLSTM stabilizers start at -inf-ish
    if cfg.family == "ssm":
        cache["mlstm"] = cache["mlstm"]._replace(
            m=jnp.full_like(cache["mlstm"].m, -1e30))
        cache["slstm"] = cache["slstm"]._replace(
            m=jnp.full_like(cache["slstm"].m, -1e30))
    return cache


# ---------------- decode attention helpers ----------------

def _rope1(x, pos, theta):
    """x: (B,H,Dh) one token at scalar position pos."""
    return L.apply_rope(x[:, None], jnp.asarray(pos)[None], theta)[:, 0]


def _decode_attend(cfg, q, ck, cv, n_valid, mesh=None):
    """Decode attention: GQA, absorbed MLA and cross-attention all pass
    through here, and from here through ``dist.decode`` — distributed
    FlashDecoding when the cache is sequence-sharded
    (cfg.decode_shard == 'seq' and a mesh was passed), the shard-local
    ``decode_partial`` registry op (cfg.kernel_impl selects 'xla' |
    'pallas' | 'auto') otherwise."""
    from repro.dist import decode as DD
    return DD.decode_attend(q, ck, cv, n_valid, backend=cfg.kernel_impl,
                            mesh=mesh,
                            seq_shard=(cfg.decode_shard == "seq"))


def _decode_gqa(cfg, lp, h, ck, cv, cur_len, mesh=None):
    """h: (B,D) normed. ck/cv: (B,T,KV,Dh). Returns (delta, ck, cv)."""
    B = h.shape[0]
    q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
    k = jnp.einsum("bd,dhk->bhk", h, lp["wk"])
    v = jnp.einsum("bd,dhk->bhk", h, lp["wv"])
    if "bq" in lp:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = _rope1(q, cur_len, cfg.rope_theta)
    k = _rope1(k, cur_len, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(ck, k[:, None], (0, cur_len, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v[:, None], (0, cur_len, 0, 0))
    o = _decode_attend(cfg, q, ck, cv, cur_len + 1, mesh)
    delta = jnp.einsum("bhk,hkd->bd", o, lp["wo"])
    return delta, ck, cv


def _decode_mla(cfg, lp, h, cckv, ckr, cur_len, mesh=None):
    """MLA absorbed decode. cckv: (B,T,r); ckr: (B,T,rope).

    Split-operand path: q_nope is folded through wk_b
    (``MLA.mla_absorbed_queries``) and the latent + rope caches ride
    as SEPARATE operands through ``dist.decode.mla_decode_attend`` —
    the ``decode_partial_mla`` registry op locally (VWR split-operand
    flash-decode kernel, 'auto' dispatch) and the same pmax/psum
    combine sequence-sharded.  No per-step k_cat/v_cat cache copies,
    no rope zero-pad in the value stream: staged cache bytes per token
    drop from 2*(r+rope) to r+rope features/position."""
    from repro.dist import decode as DD
    h3 = h[:, None, :]
    pos = jnp.asarray(cur_len)[None]
    q_nope, q_rope = MLA.mla_queries(lp, h3, pos, cfg)
    c_kv, k_rope = MLA.mla_latent(lp, h3, pos, cfg)
    cckv = jax.lax.dynamic_update_slice(cckv, c_kv, (0, cur_len, 0))
    ckr = jax.lax.dynamic_update_slice(ckr, k_rope, (0, cur_len, 0))
    q_abs, q_rope_f, scale = MLA.mla_absorbed_queries(
        lp, q_nope[:, 0], q_rope[:, 0], cfg)
    o = DD.mla_decode_attend(q_abs, q_rope_f, cckv, ckr, cur_len + 1,
                             scale=scale, backend=cfg.kernel_impl,
                             mesh=mesh,
                             seq_shard=(cfg.decode_shard == "seq"))
    delta = MLA.mla_decode_finish(lp, o.astype(jnp.float32), cfg)
    return delta.astype(h.dtype), cckv, ckr


def _decode_cross(cfg, lp, h, xk, xv, mesh=None):
    """Cross-attention against the (static) encoder KV cache."""
    q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
    T = xk.shape[1]
    o = _decode_attend(cfg, q, xk, xv, jnp.int32(T), mesh)
    return jnp.einsum("bhk,hkd->bd", o, lp["wo"])


# ---------------- paged decode (block-table + per-slot lengths) -------

def _rope_slots(x, pos, theta):
    """x: (B,H,Dh) one token per slot at per-slot positions pos (B,)."""
    return L.apply_rope(x[:, None], pos[:, None], theta)[:, 0]


def _paged_attend(cfg, q, k_pool, v_pool, table, n_valid, mesh=None,
                  k_scale=None, v_scale=None):
    """Paged decode attention: GQA, absorbed MLA and (identity-paged)
    cross-attention all route through ``dist.decode.paged_decode_attend``
    — the pool-sharded FlashDecoding combine when
    cfg.decode_shard == 'seq', the shard-local ``decode_partial_paged``
    registry op otherwise.  ``n_valid`` (B,) counts valid positions per
    slot (0 = inactive slot); ``k_scale``/``v_scale`` ((n_pages, KV)
    fp32) select the q8 route over int8 pools."""
    from repro.dist import decode as DD
    return DD.paged_decode_attend(q, k_pool, v_pool, table, n_valid,
                                  k_scale=k_scale, v_scale=v_scale,
                                  backend=cfg.kernel_impl, mesh=mesh,
                                  seq_shard=(cfg.decode_shard == "seq"))


def _page_write_ids(table, lens, page_size, n_pages):
    """Physical (page, offset) each slot's new token writes to; inactive
    slots (lens == 0) get page id ``n_pages`` so mode='drop' scatters
    discard the write instead of corrupting page table[b, 0]."""
    active = lens > 0
    pages = jnp.take_along_axis(table, (lens // page_size)[:, None],
                                axis=1)[:, 0]
    pages = jnp.where(active, pages, n_pages)
    return pages, lens % page_size, lens + active.astype(lens.dtype)


def _decode_gqa_paged(cfg, lp, h, kp, vp, table, lens, mesh=None,
                      kscale=None, vscale=None):
    """h: (B,D) normed; kp/vp: (n_pages, ps, KV, Dh) pools; lens: (B,)
    per-slot valid positions (the new token writes at position lens).
    With ``kscale``/``vscale`` ((n_pages, KV) fp32 sidecars) the pools
    are int8: the token quantizes on write and attention dequantizes
    in-kernel.  Returns (delta, kp, vp, kscale, vscale)."""
    n_pages, ps = kp.shape[0], kp.shape[1]
    q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
    k = jnp.einsum("bd,dhk->bhk", h, lp["wk"])
    v = jnp.einsum("bd,dhk->bhk", h, lp["wv"])
    if "bq" in lp:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = _rope_slots(q, lens, cfg.rope_theta)
    k = _rope_slots(k, lens, cfg.rope_theta)
    pages, offs, n_valid = _page_write_ids(table, lens, ps, n_pages)
    if kscale is not None:
        from repro.engine import paged_cache as PC
        kp, kscale = PC.quantized_page_write(kp, kscale, pages, offs, k)
        vp, vscale = PC.quantized_page_write(vp, vscale, pages, offs, v)
    else:
        kp = kp.at[pages, offs].set(k.astype(kp.dtype), mode="drop")
        vp = vp.at[pages, offs].set(v.astype(vp.dtype), mode="drop")
    o = _paged_attend(cfg, q, kp, vp, table, n_valid, mesh,
                      k_scale=kscale, v_scale=vscale)
    delta = jnp.einsum("bhk,hkd->bd", o, lp["wo"])
    return delta, kp, vp, kscale, vscale


def _decode_mla_paged(cfg, lp, h, ckv_pool, krope_pool, table, lens,
                      mesh=None, ckv_scale=None, krope_scale=None):
    """MLA absorbed decode against paged latent pools: ckv_pool
    (n_pages, ps, r); krope_pool (n_pages, ps, rope).

    Split-operand path: the two pools ride SEPARATELY through
    ``dist.decode.mla_paged_decode_attend`` — the
    ``decode_partial_mla_paged`` registry op stages only the block
    table's pages (scalar-prefetch on the pallas backend), where the
    concat view used to copy the whole POOL into k_cat/v_cat every
    step.  With ``ckv_scale``/``krope_scale`` ((n_pages,) fp32) the
    pools are int8, quantized on write and dequantized in-kernel."""
    from repro.dist import decode as DD
    n_pages, ps = ckv_pool.shape[0], ckv_pool.shape[1]
    h3 = h[:, None, :]
    pos = lens[:, None]
    q_nope, q_rope = MLA.mla_queries(lp, h3, pos, cfg)
    c_kv, k_rope = MLA.mla_latent(lp, h3, pos, cfg)
    pages, offs, n_valid = _page_write_ids(table, lens, ps, n_pages)
    if ckv_scale is not None:
        from repro.engine import paged_cache as PC
        ckv_pool, ckv_scale = PC.quantized_page_write(
            ckv_pool, ckv_scale, pages, offs, c_kv[:, 0])
        krope_pool, krope_scale = PC.quantized_page_write(
            krope_pool, krope_scale, pages, offs, k_rope[:, 0])
    else:
        ckv_pool = ckv_pool.at[pages, offs].set(
            c_kv[:, 0].astype(ckv_pool.dtype), mode="drop")
        krope_pool = krope_pool.at[pages, offs].set(
            k_rope[:, 0].astype(krope_pool.dtype), mode="drop")
    q_abs, q_rope_f, scale = MLA.mla_absorbed_queries(
        lp, q_nope[:, 0], q_rope[:, 0], cfg)
    o = DD.mla_paged_decode_attend(q_abs, q_rope_f, ckv_pool,
                                   krope_pool, table, n_valid,
                                   scale=scale, ckv_scale=ckv_scale,
                                   krope_scale=krope_scale,
                                   backend=cfg.kernel_impl,
                                   mesh=mesh,
                                   seq_shard=(cfg.decode_shard == "seq"))
    delta = MLA.mla_decode_finish(lp, o.astype(jnp.float32), cfg)
    return delta.astype(h.dtype), ckv_pool, krope_pool, ckv_scale, \
        krope_scale


def _decode_cross_paged(cfg, lp, h, xk, xv, enc_lens, page_size,
                        mesh=None):
    """Cross-attention against the slot-dense encoder cache, VIEWED as
    an identity-paged pool (slot b's pages are rows [b*Jx, (b+1)*Jx) of
    the reshaped cache — a zero-copy reshape, no gather), so per-slot
    encoder lengths ride the same paged masking as self-attention.
    Cross KV is static per slot and attended shard-locally."""
    from repro.dist import decode as DD
    B, Tx = xk.shape[0], xk.shape[1]
    Jx = Tx // page_size
    kp = xk.reshape(B * Jx, page_size, *xk.shape[2:])
    vp = xv.reshape(B * Jx, page_size, *xv.shape[2:])
    tbl = (jnp.arange(B, dtype=jnp.int32)[:, None] * Jx
           + jnp.arange(Jx, dtype=jnp.int32)[None, :])
    q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
    o = DD.paged_decode_attend(q, kp, vp, tbl, enc_lens,
                               backend=cfg.kernel_impl, mesh=mesh,
                               seq_shard=False)
    return jnp.einsum("bhk,hkd->bd", o, lp["wo"])


def _paged_attn_delta(cfg, lens, table, h, lp, cache_slice, mesh):
    """Shared attention step of the paged layer bodies: routes MLA vs
    GQA, detects int8 pools by their scale sidecars in the cache
    slice, and returns (delta, updated cache slice)."""
    if cfg.mla is not None:
        d, ckv, ckr, cs, rs = _decode_mla_paged(
            cfg, lp["attn"], h, cache_slice["ckv"],
            cache_slice["krope"], table, lens, mesh,
            cache_slice.get("ckv_scale"), cache_slice.get("krope_scale"))
        new = {"ckv": ckv, "krope": ckr}
        if cs is not None:
            new["ckv_scale"], new["krope_scale"] = cs, rs
    else:
        d, kp, vp, ks, vs = _decode_gqa_paged(
            cfg, lp["attn"], h, cache_slice["k"], cache_slice["v"],
            table, lens, mesh, cache_slice.get("k_scale"),
            cache_slice.get("v_scale"))
        new = {"k": kp, "v": vp}
        if ks is not None:
            new["k_scale"], new["v_scale"] = ks, vs
    return d, new


def _dense_paged_body(cfg, lens, table, x, lp, cache_slice, mesh=None):
    h = _norm(cfg, lp["attn_norm"], x)
    d, new = _paged_attn_delta(cfg, lens, table, h, lp, cache_slice,
                               mesh)
    x = x + d
    x = x + L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
                  backend=cfg)
    return x, new


def _moe_paged_body(cfg, lens, table, x, lp, cache_slice, mesh=None):
    h = _norm(cfg, lp["attn_norm"], x)
    d, new = _paged_attn_delta(cfg, lens, table, h, lp, cache_slice,
                               mesh)
    x = x + d
    y, _aux = MOE.moe_ffn(lp["moe"], _norm(cfg, lp["mlp_norm"], x)[None],
                          cfg, mesh=mesh)
    return x + y[0], new


def _audio_paged_body(cfg, lens, table, enc_lens, x, lp, cs, mesh=None):
    h = _norm(cfg, lp["self_norm"], x)
    d, kp, vp, _, _ = _decode_gqa_paged(cfg, lp["self"], h,
                                        cs["self_k"], cs["self_v"],
                                        table, lens, mesh)
    x = x + d
    h = _norm(cfg, lp["cross_norm"], x)
    x = x + _decode_cross_paged(cfg, lp["cross"], h, cs["cross_k"],
                                cs["cross_v"], enc_lens,
                                cs["self_k"].shape[1], mesh)
    x = x + L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
                  backend=cfg)
    return x, {"self_k": kp, "self_v": vp}


def paged_decode_step(params, batch, cfg, mesh=None):
    """One-token serve step over a paged KV cache.

    batch: token (B,), cur_len (B,) per-slot valid positions,
    block_table (B, max_pages) int32, cache (page pools from
    ``engine.paged_cache``) [+ enc_lens (B,) for audio].  Slots with
    cur_len == 0 are inactive: their write is dropped, their attention
    masks to zero, and their logits are garbage the caller discards.
    Returns (logits (B, vocab) fp32, new_cache)."""
    fam = cfg.family
    if fam not in ("dense", "vlm", "moe", "audio"):
        raise ValueError(
            f"paged decode supports KV-cache families "
            f"('dense', 'vlm', 'moe', 'audio'); family {fam!r} carries "
            "O(1) recurrent state per slot — use the dense decode path")
    tok = batch["token"]
    lens = jnp.asarray(batch["cur_len"], jnp.int32)
    table = jnp.asarray(batch["block_table"], jnp.int32)
    cache = batch["cache"]
    x = L.embed(params["embed"], tok).astype(jnp.dtype(cfg.dtype))

    if fam in ("dense", "vlm"):
        body = functools.partial(_dense_paged_body, cfg, lens, table,
                                 mesh=mesh)
        x, new_cache = _scan_stack(cfg, body, x, params["layers"],
                                   extra_xs=cache)

    elif fam == "moe":
        m = cfg.moe
        new_cache = dict(cache)
        if m.first_k_dense:
            body = functools.partial(_dense_paged_body, cfg, lens, table,
                                     mesh=mesh)
            x, nd = _scan_stack(cfg, body, x, params["dense_layers"],
                                extra_xs=cache["dense"])
            new_cache["dense"] = nd
        body = functools.partial(_moe_paged_body, cfg, lens, table,
                                 mesh=mesh)
        x, nm = _scan_stack(cfg, body, x, params["layers"],
                            extra_xs=cache["moe"])
        new_cache["moe"] = nm

    else:                                   # audio
        enc_lens = jnp.asarray(batch["enc_lens"], jnp.int32)
        body = functools.partial(_audio_paged_body, cfg, lens, table,
                                 enc_lens, mesh=mesh)
        xs_cache = {"self_k": cache["self_k"], "self_v": cache["self_v"],
                    "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
        x, upd = _scan_stack(cfg, body, x, params["layers"],
                             extra_xs=xs_cache)
        new_cache = dict(cache)
        new_cache.update(upd)

    h = _norm(cfg, params["final_norm"], x)
    logits = _logits(params, h[:, None, :], cfg)[:, 0].astype(jnp.float32)
    return logits, new_cache


def _dense_decode_body(cfg, cur_len, x, lp, cache_slice, mesh=None):
    if cfg.mla is not None:
        h = _norm(cfg, lp["attn_norm"], x)
        d, cckv, ckr = _decode_mla(cfg, lp["attn"], h, cache_slice["ckv"],
                                   cache_slice["krope"], cur_len, mesh)
        new = {"ckv": cckv, "krope": ckr}
    else:
        h = _norm(cfg, lp["attn_norm"], x)
        d, ck, cv = _decode_gqa(cfg, lp["attn"], h, cache_slice["k"],
                                cache_slice["v"], cur_len, mesh)
        new = {"k": ck, "v": cv}
    x = x + d
    x = x + L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
                  backend=cfg)
    return x, new


def _moe_decode_body(cfg, cur_len, x, lp, cache_slice, mesh=None):
    if cfg.mla is not None:
        h = _norm(cfg, lp["attn_norm"], x)
        d, cckv, ckr = _decode_mla(cfg, lp["attn"], h, cache_slice["ckv"],
                                   cache_slice["krope"], cur_len, mesh)
        new = {"ckv": cckv, "krope": ckr}
    else:
        h = _norm(cfg, lp["attn_norm"], x)
        d, ck, cv = _decode_gqa(cfg, lp["attn"], h, cache_slice["k"],
                                cache_slice["v"], cur_len, mesh)
        new = {"k": ck, "v": cv}
    x = x + d
    # decode grouping: one group of all B tokens (see moe.py docstring)
    y, _aux = MOE.moe_ffn(lp["moe"], _norm(cfg, lp["mlp_norm"], x)[None],
                          cfg, mesh=mesh)
    return x + y[0], new


def decode_step(params, batch, cfg, mesh=None):
    """One-token serve step. batch: token (B,), cur_len (), cache pytree.

    Returns (logits (B, vocab) fp32, new_cache).  ``mesh`` is the
    explicit device mesh for the sequence-sharded decode path
    (cfg.decode_shard == 'seq'); without it that path falls back to the
    deprecated ambient-mesh lookup.  ``engine.DecodeEngine`` (or
    ``steps.build_decode(cfg, mesh)``) threads it for you.

    With a ``block_table`` operand in the batch (and per-slot (B,)
    ``cur_len``), the step runs over a paged KV cache instead —
    ``paged_decode_step`` — which is how continuous batching serves
    slots at different lengths from one shared page pool.
    """
    if "block_table" in batch:
        return paged_decode_step(params, batch, cfg, mesh=mesh)
    fam = cfg.family
    tok = batch["token"]
    cur = batch["cur_len"]
    cache = batch["cache"]
    x = L.embed(params["embed"], tok).astype(jnp.dtype(cfg.dtype))  # (B,D)

    if fam in ("dense", "vlm"):
        body = functools.partial(_dense_decode_body, cfg, cur, mesh=mesh)
        x, new_cache = _scan_stack(cfg, body, x, params["layers"],
                                   extra_xs=cache)

    elif fam == "moe":
        m = cfg.moe
        new_cache = dict(cache)
        if m.first_k_dense:
            body = functools.partial(_dense_decode_body, cfg, cur,
                                     mesh=mesh)
            x, nd = _scan_stack(cfg, body, x, params["dense_layers"],
                                extra_xs=cache["dense"])
            new_cache["dense"] = nd
        body = functools.partial(_moe_decode_body, cfg, cur, mesh=mesh)
        x, nm = _scan_stack(cfg, body, x, params["layers"],
                            extra_xs=cache["moe"])
        new_cache["moe"] = nm

    elif fam == "hybrid":
        k, n_main, tail, n_inv = _hybrid_groups(cfg)
        sp = params["shared_attn"]

        def mamba_dec(x, lp, ex):
            nrm, st = ex
            d, st1 = SSM.mamba2_step(lp, _norm(cfg, nrm, x), st, cfg)
            return x + d, st1

        def shared_dec(x, ck, cv):
            h = _norm(cfg, sp["attn_norm"], x)
            d, ck, cv = _decode_gqa(cfg, sp["attn"], h, ck, cv, cur, mesh)
            x = x + d
            x = x + L.mlp(sp["mlp"], _norm(cfg, sp["mlp_norm"], x), cfg.act,
                          backend=cfg)
            return x, ck, cv

        def group_dec(x, gp, ex):
            gn, gst, ck, cv = ex
            x, st1 = _scan_stack(cfg, mamba_dec, x, gp, extra_xs=(gn, gst))
            x, ck, cv = shared_dec(x, ck, cv)
            return x, (st1, ck, cv)

        x, (st_main, ak, av) = _scan_stack(
            cfg, group_dec, x, params["mamba_main"],
            extra_xs=(params["mamba_norms"], cache["mamba_main"],
                      cache["attn_k"][:n_main], cache["attn_v"][:n_main]))
        new_cache = {"mamba_main": st_main, "mamba_tail": None}
        if tail:
            x, st_tail = _scan_stack(
                cfg, mamba_dec, x, params["mamba_tail"],
                extra_xs=(params["tail_norms"], cache["mamba_tail"]))
            x, tk, tv = shared_dec(x, cache["attn_k"][n_main],
                                   cache["attn_v"][n_main])
            new_cache["mamba_tail"] = st_tail
            ak = jnp.concatenate([ak, tk[None]], 0)
            av = jnp.concatenate([av, tv[None]], 0)
        new_cache["attn_k"], new_cache["attn_v"] = ak, av

    elif fam == "ssm":
        def ml_dec(x, lp, ex):
            nrm, st = ex
            d, st1 = XL.mlstm_step(lp, _norm(cfg, nrm, x), st, cfg)
            return x + d, st1

        def group_dec(x, gp, ex):
            gst = ex
            x, mst = _scan_stack(cfg, ml_dec, x, gp["m"],
                                 extra_xs=(gp["n"], gst["mlstm"]))
            d, sst = XL.slstm_step(gp["s"], x, gst["slstm"], cfg)
            return x + d, {"mlstm": mst, "slstm": sst}

        stacked = {"m": params["mlstm"], "n": params["mlstm_norms"],
                   "s": params["slstm"]}
        x, new_cache = _scan_stack(
            cfg, group_dec, x, stacked,
            extra_xs={"mlstm": cache["mlstm"], "slstm": cache["slstm"]})

    elif fam == "audio":
        def dec_body(x, lp, cs):
            h = _norm(cfg, lp["self_norm"], x)
            d, ck, cv = _decode_gqa(cfg, lp["self"], h, cs["self_k"],
                                    cs["self_v"], cur, mesh)
            x = x + d
            h = _norm(cfg, lp["cross_norm"], x)
            x = x + _decode_cross(cfg, lp["cross"], h, cs["cross_k"],
                                  cs["cross_v"], mesh)
            x = x + L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
                          backend=cfg)
            return x, {"self_k": ck, "self_v": cv}

        xs_cache = {"self_k": cache["self_k"], "self_v": cache["self_v"],
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
        x, upd = _scan_stack(cfg, dec_body, x, params["layers"],
                             extra_xs=xs_cache)
        new_cache = dict(cache)
        new_cache.update(upd)

    else:
        raise ValueError(fam)

    h = _norm(cfg, params["final_norm"], x)
    logits = _logits(params, h[:, None, :], cfg)[:, 0].astype(jnp.float32)
    return logits, new_cache


def prefill(params, batch, cfg, mesh=None):
    """Full-sequence prefill: returns (last-token logits, cache material).

    The cache material is the backbone's per-layer KV stacks / final
    recurrent states at the prefill length;
    ``engine.pad_cache_from_prefill`` pads them into a fixed-size
    decode cache (``engine.DecodeEngine`` does both in one call).
    ``mesh`` is threaded to the backbone's sharding hints.
    """
    out = backbone(params, batch["tokens"], cfg,
                   frontend_emb=batch.get("frontend_emb"),
                   collect_cache=True, mesh=mesh)
    logits = _logits(params, out.h[:, -1:, :], cfg)[:, 0]
    return logits.astype(jnp.float32), out.caches


# ---------------- suffix-only prefill (prefix cache) ----------------

def _gather_prefix_kv(sub, keys, pages):
    """Gather the matched prefix pages back out of one family's pools
    into dense prefill-cache-shaped ``(L, 1, M, ...)`` arrays
    (M = n_pages * page_size), dequantizing int8 pools through their
    per-page scale sidecars.  For model-dtype pools the gathered rows
    are bit-identical to the KV the original prefill wrote."""
    out = []
    for kk in keys:
        pool = sub[kk]                       # (L, n_pages, ps, ...)
        g = pool[:, pages]                   # (L, J, ps, ...)
        if kk + "_scale" in sub:
            s = sub[kk + "_scale"][:, pages]  # (L, J[, KV])
            if g.ndim == 5:                   # GQA: per-page per-head
                s = s[:, :, None, :, None]
            else:                             # MLA latent: per-page
                s = s[:, :, None, None]
            g = g.astype(jnp.float32) * s
        L, J, ps = g.shape[:3]
        out.append(g.reshape(L, 1, J * ps, *g.shape[3:]))
    return tuple(out)


def _suffix_attn_delta(cfg, ap, h, q_pos, kv_pos, prefix, *,
                       residual=None, mesh=None):
    """Attention step of the suffix bodies: queries at global positions
    ``q_pos`` over concat(prefix KV from the pools, suffix KV computed
    here).  Runs the blockwise (xla) path directly — the streaming
    kv scan sees the same kv length and block boundaries as the
    whole-prompt prefill, so every suffix row is bit-identical to the
    corresponding row of a full prefill (the pallas prefill kernel has
    no positional-offset support; admission is batch-1 and off the
    decode hot path, so kernel parity is deliberately future work)."""
    if cfg.mla is not None:
        pckv, pkrope = prefix
        out, cache = MLA.mla_attention_suffix(
            ap, h, q_pos, kv_pos, cfg, pckv, pkrope,
            head_axis=_head_axis(cfg), mesh=mesh)
        return (out if residual is None else residual + out), cache
    pk, pv = prefix
    q, k, v = A.qkv_proj(ap, h, q_pos, cfg.rope_theta, cfg)
    k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
    v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
    if cfg.accounting:
        o = A.full_attn_ref(q, k_all, v_all, causal=True,
                            q_positions=q_pos, kv_positions=kv_pos)
    else:
        o = A.blockwise_attn(q, k_all, v_all, causal=True,
                             q_positions=q_pos, kv_positions=kv_pos,
                             block_q=cfg.attn_block_q,
                             block_kv=cfg.attn_block_kv,
                             head_axis=_head_axis(cfg), mesh=mesh)
    return A.o_proj(ap, o, cfg, residual=residual), (k, v)


def _dense_suffix_body(cfg, q_pos, kv_pos, x, lp, prefix, *, mesh=None):
    x, kv = _suffix_attn_delta(cfg, lp["attn"],
                               _norm(cfg, lp["attn_norm"], x),
                               q_pos, kv_pos, prefix, residual=x,
                               mesh=mesh)
    x = L.mlp(lp["mlp"], _norm(cfg, lp["mlp_norm"], x), cfg.act,
              backend=cfg, residual=x)
    return x, kv


def _moe_suffix_body(cfg, q_pos, kv_pos, x, lp, prefix, *, mesh=None):
    x, kv = _suffix_attn_delta(cfg, lp["attn"],
                               _norm(cfg, lp["attn_norm"], x),
                               q_pos, kv_pos, prefix, residual=x,
                               mesh=mesh)
    y, aux = MOE.moe_ffn(lp["moe"], _norm(cfg, lp["mlp_norm"], x), cfg,
                         mesh=mesh)
    return x + y, (kv, aux)


def prefill_suffix(params, batch, cfg, mesh=None):
    """Prefill only the SUFFIX of a prompt whose prefix is already
    resident in the page pools (prefix cache hit).

    batch: ``tokens`` (1, S) int32 suffix tokens, ``pages`` (J_m,)
    int32 matched physical page ids (whole pages, prefix order), and
    ``cache`` — the live page pools the prefix is read from.  The
    matched length M = J_m * page_size rides the ``pages`` operand's
    SHAPE, so under jit this compiles once per (S, M) pair — the same
    per-shape compile discipline as whole-prompt prefill.

    Returns (last-token logits (1, vocab_padded) fp32, suffix cache
    material) exactly like ``prefill`` restricted to positions
    [M, M+S): the caches scatter into the slot's pages from page index
    J_m on (the suffix starts page-aligned by construction).

    Families: dense and moe (GQA or MLA).  The frontend families
    (vlm/audio) prepend non-token positions, so a token-only prefix
    index cannot alias their pages — the scheduler gates them off.
    """
    fam = cfg.family
    if fam not in ("dense", "moe"):
        raise ValueError(
            f"prefill_suffix supports the token-only families "
            f"('dense', 'moe'); family {fam!r} prepends frontend "
            "positions that a token-keyed prefix index cannot match")
    tokens = batch["tokens"]
    pages = jnp.asarray(batch["pages"], jnp.int32)
    cache = batch["cache"]
    keys = ("ckv", "krope") if cfg.mla is not None else ("k", "v")
    sub = cache["moe"] if fam == "moe" else cache
    ps = sub[keys[0]].shape[2]
    M = pages.shape[0] * ps
    S = tokens.shape[1]
    q_pos = jnp.arange(S) + M
    kv_pos = jnp.arange(M + S)

    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    if fam == "dense":
        prefix = _gather_prefix_kv(cache, keys, pages)
        body = functools.partial(_dense_suffix_body, cfg, q_pos, kv_pos,
                                 mesh=mesh)
        x, kvs = _scan_stack(cfg, body, x, params["layers"],
                             extra_xs=prefix)
        caches = kvs
    else:                                   # moe
        m = cfg.moe
        kv_d = None
        if m.first_k_dense:
            prefix_d = _gather_prefix_kv(cache["dense"], keys, pages)
            body = functools.partial(_dense_suffix_body, cfg, q_pos,
                                     kv_pos, mesh=mesh)
            x, kv_d = _scan_stack(cfg, body, x, params["dense_layers"],
                                  extra_xs=prefix_d)
        prefix_m = _gather_prefix_kv(cache["moe"], keys, pages)
        body = functools.partial(_moe_suffix_body, cfg, q_pos, kv_pos,
                                 mesh=mesh)
        x, (kv_m, _aux) = _scan_stack(cfg, body, x, params["layers"],
                                      extra_xs=prefix_m)
        caches = (kv_d, kv_m)

    h = _norm(cfg, params["final_norm"], x)
    logits = _logits(params, h[:, -1:, :], cfg)[:, 0]
    return logits.astype(jnp.float32), caches


# ---------------- chunked prefill (one chunk of an in-flight prompt) -----

def prefill_chunk(params, batch, cfg, mesh=None):
    """One chunk of a chunked prefill: compute the chunk's KV against
    everything already resident and scatter it into its granted pages.

    batch: ``tokens`` (1, C) int32 chunk tokens at global positions
    [M, M+C) where M = len(pages) * page_size; ``pages`` (J_p,) int32 —
    ALL pages holding positions [0, M) in prefix order (prefix-cache
    matched pages followed by earlier chunks' pages — the scheduler
    keeps every non-final chunk page-aligned, so the resident prefix is
    always whole pages); ``write_pages`` (J_w,) int32 — the pages
    positions [M, M+C) land in; ``cache`` — the live page pools.

    Composes with ``prefill_suffix``: the attention math IS the
    suffix-prefill math (a chunk is a suffix whose prefix grows chunk
    by chunk), so every chunk row — and in particular the final chunk's
    last-token logits — is bit-identical to the corresponding row of a
    whole-prompt prefill when the pools store the model dtype.  On top
    of that this writes the chunk's KV into ``write_pages`` (the
    quantize-on-write scatter for int8 pools), so the NEXT chunk can
    read it back through the block table.

    Returns (last-chunk-token logits (1, vocab_padded) fp32, updated
    cache).  Intermediate chunks' logits are discarded by the caller;
    the final chunk's seed the first generated token.
    """
    from repro.engine import paged_cache as PC
    logits, caches = prefill_suffix(
        params, {"tokens": batch["tokens"], "pages": batch["pages"],
                 "cache": batch["cache"]}, cfg, mesh=mesh)
    table = jnp.asarray(batch["write_pages"], jnp.int32)[None]  # (1, J_w)
    cache = PC.write_prefill(cfg, batch["cache"], caches, table)
    return logits, cache


# ---------------- xlstm decode uses ml/sl steps with scalar inputs -------

def ssm_decode_supported(cfg) -> bool:
    return cfg.family in ("hybrid", "ssm")
