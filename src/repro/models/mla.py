"""Multi-head Latent Attention (DeepSeek-V2/V3).

Cache stores the compressed latent (c_kv, kv_lora_rank) + shared rope key
(rope_head_dim) per token — 576 values/token for V3 — the reason MLA is
the bandwidth-friendliest full-attention cache and a natural fit for the
paper's wide-streaming discipline.

Prefill/train use the expanded (non-absorbed) form (compute-bound);
decode uses the *absorbed* form: q_nope is folded through wk_b so scores
and values are taken directly against the latent cache
(O(T * kv_lora) per head instead of O(T * expand)).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.common.hints import shard_hint
from repro.common.module import ParamDef
from repro.kernels import dispatch as D
from repro.models.attention import NEG_INF, blockwise_attn
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_spec


def mla_spec(cfg):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dtype = jnp.dtype(cfg.dtype)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": ParamDef((d, m.q_lora_rank), dtype, ("embed", "q_lora")),
        "q_norm": rmsnorm_spec(m.q_lora_rank, dtype),
        "wq_b": ParamDef((m.q_lora_rank, H, qd), dtype, ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamDef(
            (d, m.kv_lora_rank + m.rope_head_dim), dtype, ("embed", "kv_lora")
        ),
        "kv_norm": rmsnorm_spec(m.kv_lora_rank, dtype),
        "wk_b": ParamDef((m.kv_lora_rank, H, m.nope_head_dim), dtype,
                         ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim), dtype,
                         ("kv_lora", "heads", "head_dim")),
        "wo": ParamDef((H, m.v_head_dim, d), dtype, ("heads", "head_dim", "embed")),
    }


def mla_latent(p, x, positions, cfg):
    """x -> (c_kv normalized, k_rope with rope applied). Cache contents."""
    m = cfg.mla
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]        # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_queries(p, x, positions, cfg):
    m = cfg.mla
    q_a = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_a, p["wq_b"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, x, positions, cfg, *, causal=True, dense=False,
                  head_axis=None, mesh=None):
    """Expanded-form attention for train/prefill. Returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    H = cfg.n_heads
    q_nope, q_rope = mla_queries(p, x, positions, cfg)
    c_kv, k_rope = mla_latent(p, x, positions, cfg)

    # H2d (latent/projection hints) was measured NEUTRAL here and is
    # reverted — see EXPERIMENTS.md §Perf; the blockwise head hints
    # (H2b) below carry the gain.
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (*k_rope.shape[:2], H, m.rope_head_dim)
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)

    if dense:
        from repro.models.attention import full_attn_ref
        o = full_attn_ref(q, k, v_pad(v, q.shape[-1]), causal=causal,
                          q_positions=positions, kv_positions=positions)
        o = o[..., : m.v_head_dim]
    else:
        o = blockwise_attn(
            q, k, v_pad(v, q.shape[-1]), causal=causal,
            q_positions=positions, kv_positions=positions,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            head_axis=head_axis, mesh=mesh,
        )[..., : m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def mla_attention_suffix(p, x, q_positions, kv_positions, cfg,
                         prefix_ckv, prefix_krope, *, head_axis=None,
                         mesh=None):
    """Expanded-form attention for suffix-only prefill (prefix cache).

    ``x`` holds only the SUFFIX tokens at global ``q_positions``
    (arange(M, M+S) for a matched prefix of M tokens);
    ``prefix_ckv`` (B, M, r) / ``prefix_krope`` (B, M, rope) are the
    prefix latents gathered back out of the page pools (already
    normalized / rope'd — exactly what ``mla_latent`` cached).  Keys
    and values are reconstructed from the concatenated latents through
    wk_b / wv_b just as the full prefill does, so each suffix row's
    output is bit-identical to the same row of a whole-prompt
    ``mla_attention`` when the pools store the model dtype.  Returns
    (out, (c_kv, k_rope)) covering the suffix only — the prefix is
    already paged."""
    m = cfg.mla
    H = cfg.n_heads
    q_nope, q_rope = mla_queries(p, x, q_positions, cfg)
    c_kv, k_rope = mla_latent(p, x, q_positions, cfg)

    ckv_all = jnp.concatenate([prefix_ckv.astype(c_kv.dtype), c_kv], 1)
    krope_all = jnp.concatenate(
        [prefix_krope.astype(k_rope.dtype), k_rope], 1)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_all, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", ckv_all, p["wv_b"])
    k_rope_h = jnp.broadcast_to(
        krope_all[:, :, None, :],
        (*krope_all.shape[:2], H, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)

    if cfg.accounting:
        from repro.models.attention import full_attn_ref
        o = full_attn_ref(q, k, v_pad(v, q.shape[-1]), causal=True,
                          q_positions=q_positions,
                          kv_positions=kv_positions)[..., : m.v_head_dim]
    else:
        o = blockwise_attn(
            q, k, v_pad(v, q.shape[-1]), causal=True,
            q_positions=q_positions, kv_positions=kv_positions,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            head_axis=head_axis, mesh=mesh,
        )[..., : m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def v_pad(v, d):
    """Pad V head dim up to QK head dim so the streaming kernel is uniform."""
    pad = d - v.shape[-1]
    if pad == 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


# ---------------- absorbed decode ----------------

def mla_absorbed_queries(p, q_nope, q_rope, cfg
                         ) -> Tuple[jax.Array, jax.Array, float]:
    """Fold q_nope through wk_b: the split-operand decode queries.

    q_nope: (B,H,nope); q_rope: (B,H,rope).  Returns (q_abs (B,H,r)
    fp32, q_rope fp32, scale) with scale the absorbed-MLA
    1/sqrt(nope+rope) — the query triple every ``decode_partial_mla``
    backend consumes.  No cache-side concat is involved: scores are
    ``(q_abs . c_kv + q_rope . k_rope) * scale`` and values come
    straight from the latent cache."""
    m = cfg.mla
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope.astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    scale = 1.0 / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
    return q_abs, q_rope.astype(jnp.float32), scale


def mla_flash_decode_partial(
    q_abs, q_rope, cache_ckv, cache_krope, kv_positions, cur_len, *,
    scale: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-operand absorbed-form partial decode (XLA reference).

    q_abs: (B,H,r) fp32 (pre-folded through wk_b — see
    ``mla_absorbed_queries``); q_rope: (B,H,rope); cache_ckv: (B,T,r);
    cache_krope: (B,T,rope).  The latent cache carries both the nope
    part of the keys and the values, so the cache is read ONCE with no
    k_cat/v_cat copies and no rope zero-pad in the value stream.
    Returns fp32 (o_tilde (B,H,r), m (B,H), l (B,H)) — the
    ``dist.decode`` pmax/psum combine contract.
    """
    s = jnp.einsum("bhr,btr->bht", q_abs, cache_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhk,btk->bht", q_rope.astype(jnp.float32),
                       cache_krope.astype(jnp.float32))
    s = s * scale
    valid = kv_positions < cur_len
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    ptab = jnp.exp(s - m[..., None])
    ptab = jnp.where((m > NEG_INF / 2)[..., None], ptab, 0.0)
    l = ptab.sum(axis=-1)
    o_t = jnp.einsum("bht,btr->bhr", ptab, cache_ckv.astype(jnp.float32))
    return o_t, m, l


def mla_paged_flash_decode_partial(
    q_abs, q_rope, ckv_pool, krope_pool, block_table, page_counts, *,
    scale: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-operand paged partial decode (XLA gather reference).

    q_abs: (B,H,r) fp32; q_rope: (B,H,rope); ckv_pool: (n_pages, ps,
    r); krope_pool: (n_pages, ps, rope); block_table / page_counts:
    (B, max_pages) int32 (count 0 masks a page completely — length
    overrun, unallocated entry, or a page owned by another shard).
    Gathers ONLY the tables' pages of the two pools — the concat-MQA
    view instead copied the whole pool into k_cat/v_cat every step.
    Returns fp32 (o_tilde (B,H,r), m (B,H), l (B,H)).
    """
    B, H, r = q_abs.shape
    n_pages, ps, _ = ckv_pool.shape
    J = block_table.shape[1]
    tbl = jnp.clip(block_table, 0, n_pages - 1)
    ckv = ckv_pool[tbl].reshape(B, J * ps, r)
    kr = krope_pool[tbl].reshape(B, J * ps, krope_pool.shape[2])
    valid = (jnp.arange(ps)[None, None, :]
             < page_counts[..., None]).reshape(B, J * ps)
    s = jnp.einsum("bhr,btr->bht", q_abs, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhk,btk->bht", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32))
    s = s * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    ptab = jnp.exp(s - m[..., None])
    ptab = jnp.where((m > NEG_INF / 2)[..., None], ptab, 0.0)
    l = ptab.sum(axis=-1)
    o_t = jnp.einsum("bht,btr->bhr", ptab, ckv.astype(jnp.float32))
    return o_t, m, l


def mla_decode_partial(
    p, q_nope, q_rope, cache_ckv, cache_krope, kv_positions, cur_len, cfg
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form partial decode vs a (possibly sharded) latent cache.

    q_nope: (B,H,nope); q_rope: (B,H,rope)
    cache_ckv: (B,T,r); cache_krope: (B,T,rope)
    Returns (o_tilde (B,H,r), m (B,H), l (B,H)) — combined via pmax/psum.
    This is the ``decode_partial_mla`` registry op's reference
    formulation with the wk_b fold applied — no longer a private path.
    """
    q_abs, q_rope, scale = mla_absorbed_queries(p, q_nope, q_rope, cfg)
    return mla_flash_decode_partial(q_abs, q_rope, cache_ckv,
                                    cache_krope, kv_positions, cur_len,
                                    scale=scale)


# Registered split-operand decode contract (dist.decode combines the
# partials across sequence shards): (q_abs (B,H,r) fp32, q_rope
# (B,H,rope), c_kv/k_rope caches, cur_len) -> fp32 (o_tilde, m, l).

@D.register("decode_partial_mla", "xla")
def _decode_partial_mla_xla(q_abs, q_rope, c_kv, k_rope, cur_len,
                            pos0=0, *, scale, tune=True):
    T = c_kv.shape[1]
    return mla_flash_decode_partial(q_abs, q_rope, c_kv, k_rope,
                                    pos0 + jnp.arange(T), cur_len,
                                    scale=scale)


@D.register("decode_partial_mla", "pallas")
def _decode_partial_mla_pallas(q_abs, q_rope, c_kv, k_rope, cur_len,
                               pos0=0, *, scale, tune=True):
    from repro.kernels import autotune, ops
    if tune:
        return ops.vwr_mla_flash_decode(q_abs, q_rope, c_kv, k_rope,
                                        cur_len, pos0=pos0, scale=scale)
    # tune=False (shard_map tracing): block size from the cost-model
    # prior only — the measuring tuner must not fire inside shard_map
    T, r = c_kv.shape[1], c_kv.shape[2]
    rope = k_rope.shape[2]
    dtype = str(c_kv.dtype)
    cands = autotune.decode_candidates(T, r + rope, dtype)
    bkv = min(cands, key=lambda c: autotune.decode_prior(
        q_abs.shape[0], T, q_abs.shape[1], 1, r + rope, dtype, c))[0]
    return ops.vwr_mla_flash_decode(q_abs, q_rope, c_kv, k_rope,
                                    cur_len, pos0=pos0, scale=scale,
                                    bkv=bkv)


@D.register("decode_partial_mla_paged", "xla")
def _decode_partial_mla_paged_xla(q_abs, q_rope, ckv_pool, krope_pool,
                                  table, counts, *, scale,
                                  page_size=None, max_pages=None,
                                  tune=True):
    return mla_paged_flash_decode_partial(q_abs, q_rope, ckv_pool,
                                          krope_pool, table, counts,
                                          scale=scale)


@D.register("decode_partial_mla_paged", "pallas")
def _decode_partial_mla_paged_pallas(q_abs, q_rope, ckv_pool,
                                     krope_pool, table, counts, *,
                                     scale, page_size=None,
                                     max_pages=None, tune=True):
    from repro.kernels import ops
    return ops.vwr_mla_paged_flash_decode(q_abs, q_rope, ckv_pool,
                                          krope_pool, table, counts,
                                          scale=scale)


# q8 split-operand decode: int8 latent caches with fp32 scale
# sidecars.  Per-sequence scales for the dense cache (ckv_scale /
# krope_scale (B,)), per-page scales for the pools ((n_pages,)).  The
# latent channel and the rope channel quantize independently — their
# dynamic ranges differ by the rope rotation — and both dots hoist
# the scale out of the int8 contraction exactly (per-block-constant
# scale commutes with the reduction), so drift vs the bf16 path is
# rounding-only.

@D.register("decode_partial_mla_q8", "xla")
def _decode_partial_mla_q8_xla(q_abs, q_rope, c_kv, k_rope, ckv_scale,
                               krope_scale, cur_len, pos0=0, *, scale,
                               tune=True):
    T = c_kv.shape[1]
    ckv = c_kv.astype(jnp.float32) * ckv_scale[:, None, None]
    kr = k_rope.astype(jnp.float32) * krope_scale[:, None, None]
    return mla_flash_decode_partial(q_abs, q_rope, ckv, kr,
                                    pos0 + jnp.arange(T), cur_len,
                                    scale=scale)


@D.register("decode_partial_mla_q8", "pallas")
def _decode_partial_mla_q8_pallas(q_abs, q_rope, c_kv, k_rope,
                                  ckv_scale, krope_scale, cur_len,
                                  pos0=0, *, scale, tune=True):
    from repro.kernels import autotune, ops
    if tune:
        return ops.vwr_mla_flash_decode_q8(q_abs, q_rope, c_kv, k_rope,
                                           ckv_scale, krope_scale,
                                           cur_len, pos0=pos0,
                                           scale=scale)
    T, r = c_kv.shape[1], c_kv.shape[2]
    rope = k_rope.shape[2]
    cands = autotune.decode_candidates(T, r + rope, "int8")
    bkv = min(cands, key=lambda c: autotune.decode_prior(
        q_abs.shape[0], T, q_abs.shape[1], 1, r + rope, "int8", c))[0]
    return ops.vwr_mla_flash_decode_q8(q_abs, q_rope, c_kv, k_rope,
                                       ckv_scale, krope_scale, cur_len,
                                       pos0=pos0, scale=scale, bkv=bkv)


@D.register("decode_partial_mla_paged_q8", "xla")
def _decode_partial_mla_paged_q8_xla(q_abs, q_rope, ckv_pool,
                                     krope_pool, ckv_scale,
                                     krope_scale, table, counts, *,
                                     scale, page_size=None,
                                     max_pages=None, tune=True):
    ckv = ckv_pool.astype(jnp.float32) * ckv_scale[:, None, None]
    kr = krope_pool.astype(jnp.float32) * krope_scale[:, None, None]
    return mla_paged_flash_decode_partial(q_abs, q_rope, ckv, kr,
                                          table, counts, scale=scale)


@D.register("decode_partial_mla_paged_q8", "pallas")
def _decode_partial_mla_paged_q8_pallas(q_abs, q_rope, ckv_pool,
                                        krope_pool, ckv_scale,
                                        krope_scale, table, counts, *,
                                        scale, page_size=None,
                                        max_pages=None, tune=True):
    from repro.kernels import ops
    return ops.vwr_mla_paged_flash_decode_q8(q_abs, q_rope, ckv_pool,
                                             krope_pool, ckv_scale,
                                             krope_scale, table,
                                             counts, scale=scale)


# ---------------- chunked prefill (absorbed chunk vs latent pools) ------------
#
# The MLA sibling of ``attention.chunk_prefix_attend_partial``: an
# absorbed (C, H, r) query chunk against the latent page pools over the
# chunk's PRIOR pages.  Returns latent-space fp32 partials
# (o_tilde (C,H,r), m (C,H), l (C,H)); the within-chunk causal block
# and ``mla_decode_finish`` live downstream.

def mla_chunk_prefix_attend_partial(q_abs, q_rope, ckv_pool,
                                    krope_pool, table, counts, *,
                                    scale):
    """XLA gather reference for the MLA chunk-prefix contract.
    table/counts: (J,) prior pages + per-page valid counts."""
    C, H, r = q_abs.shape
    n_pages, ps, _ = ckv_pool.shape
    J = table.shape[0]
    tbl = jnp.clip(table, 0, n_pages - 1)
    ckv = ckv_pool[tbl].reshape(J * ps, r)
    kr = krope_pool[tbl].reshape(J * ps, krope_pool.shape[2])
    valid = (jnp.arange(ps)[None, :] < counts[:, None]).reshape(J * ps)
    qa = q_abs.astype(jnp.float32) * scale
    qr = q_rope.astype(jnp.float32) * scale
    s = jnp.einsum("chr,tr->cht", qa, ckv.astype(jnp.float32))
    s = s + jnp.einsum("chr,tr->cht", qr, kr.astype(jnp.float32))
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = p.sum(axis=-1)
    o_t = jnp.einsum("cht,tr->chr", p, ckv.astype(jnp.float32))
    return o_t, m, l


@D.register("chunk_prefix_mla_paged", "xla")
def _chunk_prefix_mla_paged_xla(q_abs, q_rope, ckv_pool, krope_pool,
                                table, counts, *, scale,
                                page_size=None, max_pages=None,
                                tune=True):
    return mla_chunk_prefix_attend_partial(q_abs, q_rope, ckv_pool,
                                           krope_pool, table, counts,
                                           scale=scale)


@D.register("chunk_prefix_mla_paged", "pallas")
def _chunk_prefix_mla_paged_pallas(q_abs, q_rope, ckv_pool, krope_pool,
                                   table, counts, *, scale,
                                   page_size=None, max_pages=None,
                                   tune=True):
    from repro.kernels import ops
    return ops.vwr_mla_chunk_prefix_attend(q_abs, q_rope, ckv_pool,
                                           krope_pool, table, counts,
                                           scale=scale)


@D.register("chunk_prefix_mla_paged_q8", "xla")
def _chunk_prefix_mla_paged_q8_xla(q_abs, q_rope, ckv_pool, krope_pool,
                                   ckv_scale, krope_scale, table,
                                   counts, *, scale, page_size=None,
                                   max_pages=None, tune=True):
    ckv = ckv_pool.astype(jnp.float32) * ckv_scale[:, None, None]
    kr = krope_pool.astype(jnp.float32) * krope_scale[:, None, None]
    return mla_chunk_prefix_attend_partial(q_abs, q_rope, ckv, kr,
                                           table, counts, scale=scale)


@D.register("chunk_prefix_mla_paged_q8", "pallas")
def _chunk_prefix_mla_paged_q8_pallas(q_abs, q_rope, ckv_pool,
                                      krope_pool, ckv_scale,
                                      krope_scale, table, counts, *,
                                      scale, page_size=None,
                                      max_pages=None, tune=True):
    from repro.kernels import ops
    return ops.vwr_mla_chunk_prefix_attend_q8(q_abs, q_rope, ckv_pool,
                                              krope_pool, ckv_scale,
                                              krope_scale, table,
                                              counts, scale=scale)


def mla_absorbed_mqa(p, q_nope, q_rope, cache_ckv, cache_krope, cfg):
    """Absorbed MLA decode as an MQA flash-decode problem.

    Folding q_nope through wk_b makes scores a plain dot product
    against the latent cache, and concatenating the latent and rope-key
    caches along the feature dim makes it *literally* the GQA decode
    contract with KV=1:

        s   = [q_abs, q_rope] . [c_kv, k_rope]   (one shared KV head)
        o~  = p . [c_kv, 0]                       (values = latent part)

    so MLA decode *can* run the very same ``decode_partial`` registry
    op and ``dist.decode`` combine as GQA.  The price of the uniform
    surface: the value stream is zero-padded by rope_head_dim (64/576
    ≈ 11% for V3), and the two concats *materialize* k_cat/v_cat
    copies of the cache each step (the concat operands feeding
    pallas_call/shard_map are not fusion-eliminated), so per-token
    cache bytes are 2*(r+rope) features/position instead of r+rope.

    The production decode path therefore no longer uses this view: the
    split-operand ``decode_partial_mla`` / ``decode_partial_mla_paged``
    ops take the latent and rope caches as SEPARATE operands and stage
    only live bytes.  This concatenated view is kept as the equivalence
    reference — the split-vs-concat bit-exactness tests and the
    ``mla_concat`` benchmark rows are built on it.

    q_nope: (B,H,nope); q_rope: (B,H,rope); cache_ckv: (B,T,r);
    cache_krope: (B,T,rope).  Returns (q_cat (B,H,r+rope) f32 —
    pre-scaled so the kernel's 1/sqrt(Dh) equals the absorbed-MLA
    1/sqrt(nope+rope) — k_cat, v_cat (B,T,1,r+rope), r).
    """
    m = cfg.mla
    r = m.kv_lora_rank
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope.astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    Dc = r + m.rope_head_dim
    scale_fix = (Dc ** 0.5) / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
    q_cat = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)],
                            axis=-1) * scale_fix
    k_cat = jnp.concatenate([cache_ckv, cache_krope], axis=-1)[:, :, None]
    v_cat = jnp.concatenate([cache_ckv, jnp.zeros_like(cache_krope)],
                            axis=-1)[:, :, None]
    return q_cat, k_cat, v_cat, r


def mla_concat_view(q_abs, q_rope, c_kv, k_rope, scale: float):
    """Concatenated k_cat/v_cat view of the SPLIT decode operands —
    equivalence reference only (tests, ``mla_concat`` benchmark rows).

    q_abs: (B,H,r) fp32; q_rope: (B,H,rope); c_kv / k_rope: the latent
    and rope caches with trailing feature dims — dense ``(B,T,...)``
    and paged ``(n_pages, ps, ...)`` layouts both work.  Returns
    (q_cat, k_cat, v_cat, r): q_cat is pre-scaled by
    ``scale * sqrt(Dc)`` so the plain decode ops' 1/sqrt(Dc) nets to
    the absorbed-MLA ``scale``; k_cat/v_cat grow a KV=1 head axis and
    v_cat zero-pads the rope features.  Every site pinning
    split-vs-concat equivalence must build the concat side HERE so the
    baselines cannot drift apart."""
    r = c_kv.shape[-1]
    Dc = r + k_rope.shape[-1]
    q_cat = jnp.concatenate([q_abs, q_rope], -1) * (scale * Dc ** 0.5)
    k_cat = jnp.concatenate([c_kv, k_rope], -1)[..., None, :]
    v_cat = jnp.concatenate([c_kv, jnp.zeros_like(k_rope)],
                            -1)[..., None, :]
    return q_cat, k_cat, v_cat, r


def mla_decode_finish(p, o_latent, cfg):
    """(B,H,r) normalized latent attention output -> (B,d_model)."""
    o = jnp.einsum("bhr,rhk->bhk", o_latent, p["wv_b"].astype(o_latent.dtype))
    return jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(o_latent.dtype))
