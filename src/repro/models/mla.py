"""Multi-head Latent Attention (DeepSeek-V2/V3).

Cache stores the compressed latent (c_kv, kv_lora_rank) + shared rope key
(rope_head_dim) per token — 576 values/token for V3 — the reason MLA is
the bandwidth-friendliest full-attention cache and a natural fit for the
paper's wide-streaming discipline.

Prefill/train use the expanded (non-absorbed) form (compute-bound);
decode uses the *absorbed* form: q_nope is folded through wk_b so scores
and values are taken directly against the latent cache
(O(T * kv_lora) per head instead of O(T * expand)).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.common.hints import shard_hint
from repro.common.module import ParamDef
from repro.models.attention import NEG_INF, blockwise_attn
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_spec


def mla_spec(cfg):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dtype = jnp.dtype(cfg.dtype)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": ParamDef((d, m.q_lora_rank), dtype, ("embed", "q_lora")),
        "q_norm": rmsnorm_spec(m.q_lora_rank, dtype),
        "wq_b": ParamDef((m.q_lora_rank, H, qd), dtype, ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamDef(
            (d, m.kv_lora_rank + m.rope_head_dim), dtype, ("embed", "kv_lora")
        ),
        "kv_norm": rmsnorm_spec(m.kv_lora_rank, dtype),
        "wk_b": ParamDef((m.kv_lora_rank, H, m.nope_head_dim), dtype,
                         ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim), dtype,
                         ("kv_lora", "heads", "head_dim")),
        "wo": ParamDef((H, m.v_head_dim, d), dtype, ("heads", "head_dim", "embed")),
    }


def mla_latent(p, x, positions, cfg):
    """x -> (c_kv normalized, k_rope with rope applied). Cache contents."""
    m = cfg.mla
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]        # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_queries(p, x, positions, cfg):
    m = cfg.mla
    q_a = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_a, p["wq_b"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, x, positions, cfg, *, causal=True, dense=False,
                  head_axis=None, mesh=None):
    """Expanded-form attention for train/prefill. Returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    H = cfg.n_heads
    q_nope, q_rope = mla_queries(p, x, positions, cfg)
    c_kv, k_rope = mla_latent(p, x, positions, cfg)

    # H2d (latent/projection hints) was measured NEUTRAL here and is
    # reverted — see EXPERIMENTS.md §Perf; the blockwise head hints
    # (H2b) below carry the gain.
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (*k_rope.shape[:2], H, m.rope_head_dim)
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)

    if dense:
        from repro.models.attention import full_attn_ref
        o = full_attn_ref(q, k, v_pad(v, q.shape[-1]), causal=causal,
                          q_positions=positions, kv_positions=positions)
        o = o[..., : m.v_head_dim]
    else:
        o = blockwise_attn(
            q, k, v_pad(v, q.shape[-1]), causal=causal,
            q_positions=positions, kv_positions=positions,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            head_axis=head_axis, mesh=mesh,
        )[..., : m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def v_pad(v, d):
    """Pad V head dim up to QK head dim so the streaming kernel is uniform."""
    pad = d - v.shape[-1]
    if pad == 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


# ---------------- absorbed decode ----------------

def mla_decode_partial(
    p, q_nope, q_rope, cache_ckv, cache_krope, kv_positions, cur_len, cfg
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form partial decode vs a (possibly sharded) latent cache.

    q_nope: (B,H,nope); q_rope: (B,H,rope)
    cache_ckv: (B,T,r); cache_krope: (B,T,rope)
    Returns (o_tilde (B,H,r), m (B,H), l (B,H)) — combined via pmax/psum.
    """
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope.astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    scale = 1.0 / ((cfg.mla.nope_head_dim + cfg.mla.rope_head_dim) ** 0.5)
    s = jnp.einsum("bhr,btr->bht", q_abs, cache_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhk,btk->bht", q_rope.astype(jnp.float32),
                       cache_krope.astype(jnp.float32))
    s = s * scale
    valid = kv_positions < cur_len
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    ptab = jnp.exp(s - m[..., None])
    ptab = jnp.where((m > NEG_INF / 2)[..., None], ptab, 0.0)
    l = ptab.sum(axis=-1)
    o_t = jnp.einsum("bht,btr->bhr", ptab, cache_ckv.astype(jnp.float32))
    return o_t, m, l


def mla_absorbed_mqa(p, q_nope, q_rope, cache_ckv, cache_krope, cfg):
    """Absorbed MLA decode as an MQA flash-decode problem.

    Folding q_nope through wk_b makes scores a plain dot product
    against the latent cache, and concatenating the latent and rope-key
    caches along the feature dim makes it *literally* the GQA decode
    contract with KV=1:

        s   = [q_abs, q_rope] . [c_kv, k_rope]   (one shared KV head)
        o~  = p . [c_kv, 0]                       (values = latent part)

    so MLA decode runs the very same ``decode_partial`` registry op —
    XLA reference or VWR flash-decode kernel — and the very same
    ``dist.decode`` sequence-sharded combine as GQA, instead of a
    private einsum path.  The price of the uniform surface: the value
    stream is zero-padded by rope_head_dim (64/576 ≈ 11% for V3), and
    the two concats *materialize* k_cat/v_cat copies of the cache each
    step (the concat operands feeding pallas_call/shard_map are not
    fusion-eliminated), so per-token cache bytes are a small multiple
    of the in-place einsum read.  A flash-decode kernel variant taking
    the latent and rope caches as separate operands would remove both
    costs (ROADMAP).

    q_nope: (B,H,nope); q_rope: (B,H,rope); cache_ckv: (B,T,r);
    cache_krope: (B,T,rope).  Returns (q_cat (B,H,r+rope) f32 —
    pre-scaled so the kernel's 1/sqrt(Dh) equals the absorbed-MLA
    1/sqrt(nope+rope) — k_cat, v_cat (B,T,1,r+rope), r).
    """
    m = cfg.mla
    r = m.kv_lora_rank
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope.astype(jnp.float32),
                       p["wk_b"].astype(jnp.float32))
    Dc = r + m.rope_head_dim
    scale_fix = (Dc ** 0.5) / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)
    q_cat = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)],
                            axis=-1) * scale_fix
    k_cat = jnp.concatenate([cache_ckv, cache_krope], axis=-1)[:, :, None]
    v_cat = jnp.concatenate([cache_ckv, jnp.zeros_like(cache_krope)],
                            axis=-1)[:, :, None]
    return q_cat, k_cat, v_cat, r


def mla_decode_finish(p, o_latent, cfg):
    """(B,H,r) normalized latent attention output -> (B,d_model)."""
    o = jnp.einsum("bhr,rhk->bhk", o_latent, p["wv_b"].astype(o_latent.dtype))
    return jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(o_latent.dtype))
