"""Mixture-of-Experts FFN: shared + routed top-k, capacity dispatch.

Two dispatch strategies (cfg.moe.dispatch):

``'gather'`` (baseline, pure GSPMD): position-in-expert is computed with a
one-hot cumsum, tokens are *gathered* into a static (G, E, C, D) buffer
(G = dispatch groups, C = per-expert capacity), experts run as one batched
einsum, results are gathered back per (token, k) slot.  No (G,S,E,C)
combine tensor is ever materialized (the classic GShard formulation would
need T*K*E*C elements — hopeless at our sizes); peak transient is the
dispatched activations themselves, T*K*cf*D.

``'sort'`` (beyond-paper perf iteration): position-in-expert via a stable
argsort over expert ids — O(T log T) instead of the O(T*K*E) cumsum
tensor; numerically identical (tested).

Token-dropping: assignments beyond capacity are dropped (keep=False) and
their gate weight contributes nothing; with cf=1.25 drops are rare.  The
aux load-balance loss keeps the router near-uniform.

Grouping policy: group = one batch row for train/prefill (so the group
axis shards over 'data' exactly like the batch), a single group of all B
tokens for decode (S=1) so capacity slots stay dense.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.common.module import ParamDef, zeros_init
from repro.models.layers import mlp, mlp_spec


from repro.common.hints import shard_hint as _ep_constraint


def moe_spec(cfg):
    m = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    spec: Dict = {
        "router": ParamDef((d, m.n_experts), jnp.float32, ("embed", "experts")),
        "wi": ParamDef((m.n_experts, d, m.d_expert), dtype,
                       ("experts", "embed", "expert_ff")),
        "wg": ParamDef((m.n_experts, d, m.d_expert), dtype,
                       ("experts", "embed", "expert_ff")),
        "wo": ParamDef((m.n_experts, m.d_expert, d), dtype,
                       ("experts", "expert_ff", "embed")),
    }
    if m.n_shared:
        spec["shared"] = mlp_spec(d, m.n_shared * m.d_expert, "swiglu", dtype)
    if m.score_fn == "sigmoid":
        # DeepSeek-V3 e-score correction bias: used for top-k *selection*
        # only, not in the gate weights. Updated out-of-band (bias update
        # rate is a training-schedule knob; see optim/router_bias.py).
        spec["e_bias"] = ParamDef((m.n_experts,), jnp.float32, ("experts",),
                                  zeros_init)
    return spec


# ---------------- routing ----------------

def router_scores(p, x, cfg):
    """x: (..., D) -> probs (..., E) fp32 and selection scores."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    if m.score_fn == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        sel = probs + p["e_bias"]          # bias influences selection only
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        sel = probs
    return probs, sel, logits


def top_k_gates(probs, sel, cfg):
    """Returns (gates (...,K) fp32, idx (...,K) int32)."""
    m = cfg.moe
    _, idx = jax.lax.top_k(sel, m.top_k)
    gates = jnp.take_along_axis(probs, idx, axis=-1)
    if m.norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-20)
    return gates * m.routed_scale, idx


# ---------------- position-in-expert ----------------

def _positions_cumsum(idx_flat, n_experts):
    """idx_flat: (G, A) expert ids. Returns pos (G, A) int32.

    pos[a] = #{a' < a : idx[a'] == idx[a]} — via one-hot cumsum.
    """
    oh = jax.nn.one_hot(idx_flat, n_experts, dtype=jnp.int32)   # (G,A,E)
    pos = jnp.cumsum(oh, axis=1) - 1                            # inclusive -> -1
    return jnp.take_along_axis(pos, idx_flat[..., None], axis=-1)[..., 0]


def _positions_sort(idx_flat, n_experts):
    """Same contract as _positions_cumsum via stable argsort (O(A log A))."""
    G, A = idx_flat.shape

    def per_group(e):
        order = jnp.argsort(e, stable=True)              # assignments by expert
        sorted_e = e[order]
        # start offset of each expert's run = exclusive cumsum of counts
        counts = jnp.zeros(n_experts, jnp.int32).at[e].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos_sorted = jnp.arange(A, dtype=jnp.int32) - starts[sorted_e]
        return jnp.zeros(A, jnp.int32).at[order].set(pos_sorted)

    return jax.vmap(per_group)(idx_flat)


# ---------------- dispatch / combine ----------------

def moe_ffn(p, x, cfg, mesh=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (G, S, D) grouped tokens. Returns (y (G,S,D), aux dict).

    aux: 'lb_loss' (load balance), 'z_loss' (router logit magnitude),
    'drop_frac' (fraction of assignments dropped by capacity).
    ``mesh`` resolves the expert-parallel sharding constraints
    explicitly (callers without an ambient mesh context — the engine
    path — must pass it or full_ep constraints silently no-op).
    """
    m = cfg.moe
    G, S, D = x.shape
    E, K = m.n_experts, m.top_k
    A = S * K
    cap = int(max(1, -(-S * K * m.capacity_factor // E)))       # ceil

    probs, sel, logits = router_scores(p, x, cfg)               # (G,S,E)
    gates, idx = top_k_gates(probs, sel, cfg)                   # (G,S,K)

    idx_flat = idx.reshape(G, A)
    if m.dispatch == "sort":
        pos = _positions_sort(idx_flat, E)
    else:
        pos = _positions_cumsum(idx_flat, E)
    keep = pos < cap                                            # (G,A)

    # scatter token indices into (E*cap) slots; sentinel S = zero-pad row
    slot = jnp.where(keep, idx_flat * cap + pos, E * cap)       # (G,A)
    token_of_assign = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[:, None], (S, K)
    ).reshape(A)
    g_ix = jnp.arange(G, dtype=jnp.int32)[:, None]
    token_for_slot = jnp.full((G, E * cap + 1), S, jnp.int32)
    token_for_slot = token_for_slot.at[g_ix, slot].set(token_of_assign[None, :])
    token_for_slot = token_for_slot[:, : E * cap]               # (G, E*cap)

    x_pad = jnp.concatenate([x, jnp.zeros((G, 1, D), x.dtype)], axis=1)
    xd = jnp.take_along_axis(
        x_pad, token_for_slot[..., None], axis=1
    ).reshape(G, E, cap, D)                                     # dispatched

    ep_spec = PS(None, ("data", "model"), None, None)
    if m.ep == "full_ep":
        # tokens move to the expert owners (all-to-all-sized traffic);
        # expert weights, sharded E -> (data, model), never move.
        # (measured WORSE when combined with gather-based combine at
        # decode — §Perf H7a — so not applied by default)
        xd = _ep_constraint(xd, ep_spec, mesh=mesh)

    # expert FFN (swiglu) as batched einsum over the expert dim
    h = jnp.einsum("gecd,edf->gecf", xd, p["wi"])
    gte = jnp.einsum("gecd,edf->gecf", xd, p["wg"])
    h = jax.nn.silu(gte.astype(jnp.float32)).astype(h.dtype) * h
    y_e = jnp.einsum("gecf,efd->gecd", h, p["wo"])              # (G,E,cap,D)
    if m.ep == "full_ep":
        y_e = _ep_constraint(y_e, ep_spec, mesh=mesh)

    # combine: gather each assignment's slot output, weight by gate
    y_flat = y_e.reshape(G, E * cap, D)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((G, 1, D), y_flat.dtype)],
                             axis=1)
    src = jnp.where(keep, idx_flat * cap + pos, E * cap)        # (G,A)
    y_a = jnp.take_along_axis(y_flat, src[..., None], axis=1)   # (G,A,D)
    w_a = (gates.reshape(G, A) * keep).astype(jnp.float32)
    y = (y_a.astype(jnp.float32) * w_a[..., None]).reshape(G, S, K, D).sum(2)
    y = y.astype(x.dtype)

    if m.n_shared:
        y = y + mlp(p["shared"], x, "swiglu", backend=cfg)

    # aux metrics / losses (fp32)
    me = probs.mean(axis=(0, 1))                                # (E,) mean prob
    ce = (jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(2).mean(axis=(0, 1)))
    lb_loss = E * jnp.sum(me * ce) / K
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    drop_frac = 1.0 - keep.mean()
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": drop_frac}
    return y, aux
