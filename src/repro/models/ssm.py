"""Mamba2 (SSD) block — chunked formulation, two variants.

``accounting=False`` (real program): sequential ``lax.scan`` over chunks,
peak memory O(B*H*Q^2) — what a real cluster runs.

``accounting=True``: the inter-chunk recurrence is evaluated in *closed
form* as a (n_chunks x n_chunks) decay matmul (per-head decays are
scalars), so the whole layer is scan-free and XLA ``cost_analysis``
FLOP/byte accounting is exact (XLA counts while-loop bodies once; see
DESIGN.md §8).  Accounting programs are lowered, never executed, so the
large transients are irrelevant.

Both variants share the per-chunk math and agree numerically (tested).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.module import ParamDef, const_init, ones_init, zeros_init
from repro.models.layers import rmsnorm, rmsnorm_spec


def _a_log_init(key, shape, dtype):
    # A in [1, 16] as in the reference implementation.
    # shape may carry leading layer-stack dims: fill along the last axis.
    row = jnp.log(jnp.linspace(1.0, 16.0, shape[-1]))
    return jnp.broadcast_to(row, shape).astype(dtype)


def mamba2_spec(cfg):
    mc = cfg.mamba2
    D = cfg.d_model
    d_inner = mc.expand * D
    H = d_inner // mc.head_dim
    G, N, K = mc.n_groups, mc.d_state, mc.d_conv
    d_xbc = d_inner + 2 * G * N
    dtype = jnp.dtype(cfg.dtype)
    return {
        "in_proj": ParamDef((D, d_inner + d_xbc + H), dtype, ("embed", "inner_all")),
        "conv_w": ParamDef((K, d_xbc), dtype, ("conv_k", "inner")),
        "conv_b": ParamDef((d_xbc,), dtype, ("inner",), zeros_init),
        "dt_bias": ParamDef((H,), jnp.float32, ("heads",), const_init(0.5)),
        "a_log": ParamDef((H,), jnp.float32, ("heads",), _a_log_init),
        "d_skip": ParamDef((H,), jnp.float32, ("heads",), ones_init),
        "norm": rmsnorm_spec(d_inner, dtype),
        "out_proj": ParamDef((d_inner, D), dtype, ("inner", "embed")),
    }


class Mamba2State(NamedTuple):
    ssm: jax.Array     # (B, H, N, P) fp32
    conv: jax.Array    # (B, K-1, d_xbc)


def init_state(cfg, batch: int) -> Mamba2State:
    mc = cfg.mamba2
    d_inner = mc.expand * cfg.d_model
    H = d_inner // mc.head_dim
    d_xbc = d_inner + 2 * mc.n_groups * mc.d_state
    return Mamba2State(
        ssm=jnp.zeros((batch, H, mc.d_state, mc.head_dim), jnp.float32),
        conv=jnp.zeros((batch, mc.d_conv - 1, d_xbc), jnp.dtype(cfg.dtype)),
    )


def _split_proj(p, x, cfg):
    mc = cfg.mamba2
    d_inner = mc.expand * cfg.d_model
    G, N = mc.n_groups, mc.d_state
    H = d_inner // mc.head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: d_inner * 2 + 2 * G * N]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def _causal_conv(p, xbc, cfg, left_ctx=None):
    """Depthwise causal conv1d along seq (kernel K), then silu.

    left_ctx: (B, K-1, d_xbc) carried context (decode continuation); zeros
    at sequence start.
    """
    K = cfg.mamba2.d_conv
    if left_ctx is None:
        pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left_ctx.astype(xbc.dtype), xbc], axis=1)
    out = sum(
        pad[:, i: i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(K)
    ) + p["conv_b"]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def _chunk_math(xq, Bq, Cq, dtq, aq, G, hpg):
    """Per-chunk quantities shared by both variants.

    xq (B,Q,H,P) fp32; Bq/Cq (B,Q,G,N) fp32; dtq/aq (B,Q,H) fp32.
    Returns y_intra (B,Q,G,hpg,P), S_chunk (B,G,hpg,N,P),
            cum (B,Q,H), g_tot (B,H).
    """
    Bsz, Q = xq.shape[:2]
    cum = jnp.cumsum(aq, axis=1)                                  # (B,Q,H)
    cb = jnp.einsum("blgn,bsgn->bgls", Cq, Bq)                    # (B,G,l,s)
    # mask BEFORE exp: the upper triangle holds positive log-decays whose
    # exp overflows; where-after-exp poisons the backward pass with NaNs.
    diff = cum[:, :, None, :] - cum[:, None, :, :]                # (B,l,s,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
    xdt = xq * dtq[..., None]                                     # (B,Q,H,P)
    dec = decay.transpose(0, 3, 1, 2).reshape(Bsz, G, hpg, Q, Q)
    att = cb[:, :, None] * dec                                    # (B,G,hpg,l,s)
    xdt_g = xdt.reshape(Bsz, Q, G, hpg, -1)
    y_intra = jnp.einsum("bghls,bsghp->blghp", att, xdt_g)
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)                  # (B,Q,H)
    xw_g = (xdt * decay_to_end[..., None]).reshape(Bsz, Q, G, hpg, -1)
    S_chunk = jnp.einsum("bsgn,bsghp->bghnp", Bq, xw_g)
    return y_intra, S_chunk, cum, cum[:, -1, :]


def mamba2_forward(
    p, x, cfg, initial_state: Mamba2State | None = None
) -> Tuple[jax.Array, Mamba2State]:
    """Training/prefill forward. x: (B, S, D). Returns (y, final_state)."""
    mc = cfg.mamba2
    Bsz, S, D = x.shape
    d_inner = mc.expand * D
    P, G, N = mc.head_dim, mc.n_groups, mc.d_state
    H = d_inner // P
    Q = min(mc.chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    hpg = H // G

    z, xbc_raw, dt = _split_proj(p, x, cfg)
    tail = mc.d_conv - 1
    conv_tail = (
        xbc_raw[:, -tail:, :]
        if S >= tail
        else jnp.pad(xbc_raw, ((0, 0), (tail - S, 0), (0, 0)))
    )
    left = initial_state.conv if initial_state is not None else None
    xbc = _causal_conv(p, xbc_raw, cfg, left_ctx=left)
    xs = xbc[..., :d_inner].reshape(Bsz, S, H, P)
    Bmat = xbc[..., d_inner: d_inner + G * N].reshape(Bsz, S, G, N)
    Cmat = xbc[..., d_inner + G * N:].reshape(Bsz, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    a = -jnp.exp(p["a_log"]) * dt                                    # (B,S,H) <= 0

    xf = xs.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    Bf = Bmat.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    Cf = Cmat.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    dtf = dt.reshape(Bsz, nc, Q, H)
    af = a.reshape(Bsz, nc, Q, H)

    S0 = (
        initial_state.ssm
        if initial_state is not None
        else jnp.zeros((Bsz, H, N, P), jnp.float32)
    ).reshape(Bsz, G, hpg, N, P)

    if cfg.accounting:
        y, S_fin = _ssd_closed(xf, Bf, Cf, dtf, af, S0, G, hpg)
    else:
        y, S_fin = _ssd_scan(xf, Bf, Cf, dtf, af, S0, G, hpg)

    y = y.reshape(Bsz, S, H, P)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]

    # gate + norm + out
    y = y.reshape(Bsz, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, Mamba2State(ssm=S_fin.reshape(Bsz, H, N, P), conv=conv_tail)


def _apply_state(Cq, cum, S_in, G, hpg):
    """y_inter[l] = C[l] · exp(cum[l]) · S_in."""
    Bsz, Q = Cq.shape[:2]
    return jnp.einsum(
        "blgn,bghnp,blgh->blghp",
        Cq, S_in, jnp.exp(cum).reshape(Bsz, Q, G, hpg),
    )


def _ssd_scan(xf, Bf, Cf, dtf, af, S0, G, hpg):
    """Sequential chunk scan (real program): bounded memory."""
    def body(S_prev, args):
        xq, Bq, Cq, dtq, aq = args
        y_intra, S_chunk, cum, g_tot = _chunk_math(xq, Bq, Cq, dtq, aq, G, hpg)
        y = y_intra + _apply_state(Cq, cum, S_prev, G, hpg)
        Bsz = xq.shape[0]
        S_next = S_prev * jnp.exp(g_tot).reshape(Bsz, G, hpg)[..., None, None] \
            + S_chunk
        return S_next, y

    xsw = [t.swapaxes(0, 1) for t in (xf, Bf, Cf, dtf, af)]
    S_fin, ys = jax.lax.scan(body, S0, tuple(xsw))
    return ys.swapaxes(0, 1), S_fin  # (B,nc,Q,G,hpg,P)


def _ssd_closed(xf, Bf, Cf, dtf, af, S0, G, hpg):
    """Closed-form inter-chunk combination (accounting program)."""
    Bsz, nc = xf.shape[:2]

    def per_chunk(xq, Bq, Cq, dtq, aq):
        return _chunk_math(xq, Bq, Cq, dtq, aq, G, hpg)

    y_intra, S_chunk, cum, g_tot = jax.vmap(
        per_chunk, in_axes=(1, 1, 1, 1, 1), out_axes=(1, 1, 1, 1)
    )(xf, Bf, Cf, dtf, af)
    # g_tot: (B,nc,H); cum: (B,nc,Q,H)
    Gcum = jnp.cumsum(g_tot, axis=1)
    # M[c, c'] = exp(G[c-1] - G[c']) for c' < c (strictly lower triangular)
    diff = Gcum[:, :, None, :] - g_tot[:, :, None, :] - Gcum[:, None, :, :]
    cmask = jnp.tril(jnp.ones((nc, nc), bool), k=-1)
    M = jnp.exp(jnp.where(cmask[None, :, :, None], diff, -1e30))  # (B,c,c',H)
    M_g = M.reshape(Bsz, nc, nc, G, hpg)
    S_in = jnp.einsum("bczgh,bzghnp->bcghnp", M_g, S_chunk)
    # contribution of the initial state: decay G[c-1] from sequence start
    init_dec = jnp.exp(Gcum - g_tot).reshape(Bsz, nc, G, hpg)     # (B,c,G,hpg)
    S_in = S_in + S0[:, None] * init_dec[..., None, None]

    y_inter = jax.vmap(
        lambda Cq, cumq, Sq: _apply_state(Cq, cumq, Sq, G, hpg),
        in_axes=(1, 1, 1), out_axes=1,
    )(Cf, cum, S_in)

    last_decay = jnp.exp(g_tot[:, -1, :]).reshape(Bsz, G, hpg)
    S_fin = S_in[:, -1] * last_decay[..., None, None] + S_chunk[:, -1]
    return y_intra + y_inter, S_fin


def mamba2_step(p, x, state: Mamba2State, cfg) -> Tuple[jax.Array, Mamba2State]:
    """Single-token decode. x: (B, D). O(1) in sequence length."""
    mc = cfg.mamba2
    Bsz, D = x.shape
    d_inner = mc.expand * D
    P, G, N, K = mc.head_dim, mc.n_groups, mc.d_state, mc.d_conv
    H = d_inner // P

    z, xbc, dt = _split_proj(p, x[:, None, :], cfg)
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]

    conv_in = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # (B,K,dxbc)
    xbc_c = jnp.einsum("bke,ke->be", conv_in, p["conv_w"]) + p["conv_b"]
    xbc_c = jax.nn.silu(xbc_c.astype(jnp.float32)).astype(x.dtype)
    new_conv = conv_in[:, 1:, :]

    xs = xbc_c[..., :d_inner].reshape(Bsz, H, P).astype(jnp.float32)
    Bv = xbc_c[..., d_inner: d_inner + G * N].reshape(Bsz, G, N).astype(jnp.float32)
    Cv = xbc_c[..., d_inner + G * N:].reshape(Bsz, G, N).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,H)
    decay = jnp.exp(-jnp.exp(p["a_log"]) * dt)                        # (B,H)

    Bh = jnp.repeat(Bv, H // G, axis=1)                               # (B,H,N)
    Ch = jnp.repeat(Cv, H // G, axis=1)
    h = state.ssm * decay[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh * dt[..., None], xs
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + xs * p["d_skip"][None, :, None]

    y = y.reshape(Bsz, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])
    return out, Mamba2State(ssm=h, conv=new_conv)
