"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory, per head of dim P):
    C_t = f_t C_{t-1} + i_t k_t v_t^T      n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t))
with exponential input gate i = exp(i~), sigmoid forget gate, and the
log-domain stabilizer m_t.  The chunkwise-parallel form below evaluates
within-chunk contributions as a masked attention-like matmul (the VWR
streaming case: one wide chunk staged, many MXU steps) and carries the
(C, n, m) state across chunks with a lax.scan — mirroring the Mamba2 SSD
structure in ssm.py.  A naive per-timestep scan in ``mlstm_ref`` is the
oracle; tests assert chunkwise == naive.

sLSTM (scalar memory, block-diagonal recurrence R per head) is truly
sequential — h_{t-1} feeds the gates — so it is a lax.scan over time by
construction (the paper's own CUDA kernels do the same; no parallel form
exists).  1-in-N layers are sLSTM per the xLSTM[m:s] notation.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.module import ParamDef, const_init, zeros_init
from repro.models.layers import rmsnorm, rmsnorm_spec

# ======================================================================
# mLSTM
# ======================================================================


def mlstm_spec(cfg):
    xc = cfg.xlstm
    D, H = cfg.d_model, cfg.n_heads
    d_inner = int(xc.proj_factor * D)
    P = d_inner // H
    K = xc.conv1d_kernel
    dtype = jnp.dtype(cfg.dtype)
    return {
        "in_proj": ParamDef((D, 2 * d_inner), dtype, ("embed", "inner_all")),
        "conv_w": ParamDef((K, d_inner), dtype, ("conv_k", "inner")),
        "conv_b": ParamDef((d_inner,), dtype, ("inner",), zeros_init),
        "wq": ParamDef((d_inner, H, P), dtype, ("inner", "heads", "head_dim")),
        "wk": ParamDef((d_inner, H, P), dtype, ("inner", "heads", "head_dim")),
        "wv": ParamDef((d_inner, H, P), dtype, ("inner", "heads", "head_dim")),
        "w_i": ParamDef((d_inner, H), jnp.float32, ("inner", "heads"), zeros_init),
        "b_i": ParamDef((H,), jnp.float32, ("heads",), zeros_init),
        "w_f": ParamDef((d_inner, H), jnp.float32, ("inner", "heads"), zeros_init),
        "b_f": ParamDef((H,), jnp.float32, ("heads",), const_init(3.0)),
        "norm": rmsnorm_spec(d_inner, dtype),
        "out_proj": ParamDef((d_inner, D), dtype, ("inner", "embed")),
    }


class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, P, P) fp32 — stabilized matrix memory
    n: jax.Array      # (B, H, P) fp32
    m: jax.Array      # (B, H) fp32 — log stabilizer
    conv: jax.Array   # (B, K-1, d_inner)


def mlstm_init_state(cfg, batch: int) -> MLSTMState:
    xc = cfg.xlstm
    d_inner = int(xc.proj_factor * cfg.d_model)
    H = cfg.n_heads
    P = d_inner // H
    return MLSTMState(
        C=jnp.zeros((batch, H, P, P), jnp.float32),
        n=jnp.zeros((batch, H, P), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
        conv=jnp.zeros((batch, xc.conv1d_kernel - 1, d_inner),
                       jnp.dtype(cfg.dtype)),
    )


def _mlstm_conv(p, x, K, left_ctx=None):
    if left_ctx is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left_ctx.astype(x.dtype), x], axis=1)
    out = sum(pad[:, i: i + x.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(K)) + p["conv_b"]
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def _mlstm_qkvif(p, xc_act):
    """xc_act: (B,S,d_inner) conv-activated branch -> q,k,v,(li,lf) fp32."""
    q = jnp.einsum("bse,ehp->bshp", xc_act, p["wq"])
    k = jnp.einsum("bse,ehp->bshp", xc_act, p["wk"])
    v = jnp.einsum("bse,ehp->bshp", xc_act, p["wv"])
    xf = xc_act.astype(jnp.float32)
    li = jnp.einsum("bse,eh->bsh", xf, p["w_i"]) + p["b_i"]      # log i-gate
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xf, p["w_f"]) + p["b_f"]
    )                                                            # log f-gate
    return q, k, v, li, lf


def mlstm_chunkwise(q, k, v, li, lf, state: Tuple, chunk: int,
                    unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM sequence evaluation.

    q,k,v: (B,S,H,P); li,lf: (B,S,H) fp32.
    state: (C (B,H,P,P), n (B,H,P), m (B,H)) fp32.
    Returns (h (B,S,H,P) fp32, new_state).
    """
    B, S, H, P = q.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    scale = 1.0 / (P ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, nc, Q, H, P)
    kf = (k.astype(jnp.float32) * scale).reshape(B, nc, Q, H, P)
    vf = v.astype(jnp.float32).reshape(B, nc, Q, H, P)
    lif = li.reshape(B, nc, Q, H)
    lff = lf.reshape(B, nc, Q, H)

    def body(carry, xs):
        C0, n0, m0 = carry                      # C0/n0 stabilized by exp(-m0)
        qq, kk, vv, ii, ff = xs                 # (B,Q,H,P)/(B,Q,H)
        b = jnp.cumsum(ff, axis=1)              # (B,Q,H) log-decay to chunk start
        a = ii - b                              # log i_s discounted to start
        g = jnp.maximum(m0[:, None, :], jax.lax.cummax(a, axis=1))  # (B,Q,H)
        m_t = b + g                             # per-position stabilizer

        # intra-chunk: Dmat[t,s] = exp(a_s - g_t) for s<=t.
        # Mask before exp: upper-triangle log-weights can be positive
        # and overflow, which would NaN the backward pass.
        ldm = a[:, None, :, :] - g[:, :, None, :]                # (B,t,s,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        dmat = jnp.exp(jnp.where(tri[None, :, :, None], ldm, -1e30))
        s_qk = jnp.einsum("bthp,bshp->btsh", qq, kk)             # (B,t,s,H)
        w = s_qk * dmat
        num = jnp.einsum("btsh,bshp->bthp", w, vv)
        den = w.sum(axis=2)                                      # (B,t,H)

        # inter-chunk: carry contribution with weight exp(m0 - g_t)
        wc = jnp.exp(m0[:, None, :] - g)                         # (B,t,H)
        num = num + wc[..., None] * jnp.einsum("bthp,bhpj->bthj", qq, C0)
        den = den + wc * jnp.einsum("bthp,bhp->bth", qq, n0)

        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # end-of-chunk state
        bQ = b[:, -1, :]                                         # (B,H)
        gQ = g[:, -1, :]
        m1 = bQ + gQ
        wS = jnp.exp(a - gQ[:, None, :])                         # (B,s,H)
        kv = jnp.einsum("bshp,bsh,bshj->bhpj", kk, wS,
                        vv)                                      # (B,H,P,P)
        kn = jnp.einsum("bshp,bsh->bhp", kk, wS)
        decay = jnp.exp(m0 - gQ)                                 # (B,H)
        C1 = C0 * decay[..., None, None] + kv
        n1 = n0 * decay[..., None] + kn
        return (C1, n1, m1), h

    xs = tuple(t.swapaxes(0, 1) for t in (qf, kf, vf, lif, lff))
    if unroll:
        # accounting variant: python loop so XLA cost_analysis counts
        # every chunk (while bodies are counted once; DESIGN.md §8)
        carry, hs_l = state, []
        for c_ in range(nc):
            carry, h_ = body(carry, tuple(t[c_] for t in xs))
            hs_l.append(h_)
        (C, n, m), hs = carry, jnp.stack(hs_l)
    else:
        (C, n, m), hs = jax.lax.scan(body, state, xs)
    return hs.swapaxes(0, 1).reshape(B, S, H, P), (C, n, m)


def mlstm_ref(q, k, v, li, lf, state):
    """Naive per-timestep oracle (tests)."""
    B, S, H, P = q.shape
    scale = 1.0 / (P ** 0.5)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    kf = kf * scale

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        m1 = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m1)
        ip = jnp.exp(it - m1)
        C = C * fp[..., None, None] + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhp,bhpj->bhj", qt, C)
        den = jnp.einsum("bhp,bhp->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m1))[..., None]
        return (C, n, m1), h

    xs = tuple(t.swapaxes(0, 1) for t in (qf, kf, vf, li, lf))
    (C, n, m), hs = jax.lax.scan(step, state, xs)
    return hs.swapaxes(0, 1), (C, n, m)


def mlstm_forward(p, x, cfg, state: MLSTMState | None = None):
    """Full mLSTM block. x: (B,S,D) -> (y, new_state)."""
    xc = cfg.xlstm
    B, S, D = x.shape
    d_inner = int(xc.proj_factor * D)
    H = cfg.n_heads
    K = xc.conv1d_kernel

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xa, z = proj[..., :d_inner], proj[..., d_inner:]
    tail = K - 1
    conv_tail = (xa[:, -tail:, :] if S >= tail
                 else jnp.pad(xa, ((0, 0), (tail - S, 0), (0, 0))))
    left = state.conv if state is not None else None
    xc_act = _mlstm_conv(p, xa, K, left_ctx=left)
    q, k, v, li, lf = _mlstm_qkvif(p, xc_act)

    if state is not None:
        st = (state.C, state.n, state.m)
    else:
        st = (jnp.zeros((B, H, d_inner // H, d_inner // H), jnp.float32),
              jnp.zeros((B, H, d_inner // H), jnp.float32),
              jnp.full((B, H), -1e30, jnp.float32))
    h, (C, n, m) = mlstm_chunkwise(q, k, v, li, lf, st, xc.chunk,
                                   unroll=cfg.accounting)

    h = h.reshape(B, S, d_inner)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    h = rmsnorm(p["norm"], h.astype(x.dtype), cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", h, p["out_proj"])
    return y, MLSTMState(C=C, n=n, m=m, conv=conv_tail)


def mlstm_step(p, x, state: MLSTMState, cfg):
    """Single-token decode. x: (B,D). O(P^2) per head, O(1) in seq."""
    xc = cfg.xlstm
    B, D = x.shape
    d_inner = int(xc.proj_factor * D)
    H = cfg.n_heads
    P = d_inner // H

    proj = jnp.einsum("bd,de->be", x, p["in_proj"])
    xa, z = proj[..., :d_inner], proj[..., d_inner:]
    conv_in = jnp.concatenate([state.conv, xa[:, None, :]], axis=1)
    xc_act = jnp.einsum("bke,ke->be", conv_in, p["conv_w"]) + p["conv_b"]
    xc_act = jax.nn.silu(xc_act.astype(jnp.float32)).astype(x.dtype)

    q, k, v, li, lf = _mlstm_qkvif(p, xc_act[:, None, :])
    h, (C, n, m) = mlstm_ref(q, k, v, li, lf, (state.C, state.n, state.m))

    h = h[:, 0].reshape(B, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    h = rmsnorm(p["norm"], h.astype(x.dtype), cfg.norm_eps)
    y = jnp.einsum("be,ed->bd", h, p["out_proj"])
    return y, MLSTMState(C=C, n=n, m=m, conv=conv_in[:, 1:, :])


# ======================================================================
# sLSTM
# ======================================================================


def slstm_spec(cfg):
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    dtype = jnp.dtype(cfg.dtype)
    # proj factor 4/3 rounded up to a multiple of 64 (as the released
    # xLSTM does) — also keeps the dim TP-shardable
    ff = -(-int(D * 4 / 3) // 64) * 64
    return {
        # input weights for the 4 gates (z, i, f, o)
        "w_in": ParamDef((D, 4 * D), dtype, ("embed", "inner_all")),
        # block-diagonal recurrent weights, per head: (4, H, P, P)
        "r": ParamDef((4, H, P, P), dtype, ("gates", "heads", "head_dim",
                                            "head_dim2")),
        "b": ParamDef((4, D), jnp.float32, ("gates", "embed"), zeros_init),
        "norm": rmsnorm_spec(D, dtype),
        # post-block gated FFN (proj factor 4/3 per xLSTM paper)
        "ff_norm": rmsnorm_spec(D, dtype),
        "ff_wi": ParamDef((D, ff), dtype, ("embed", "ffn")),
        "ff_wg": ParamDef((D, ff), dtype, ("embed", "ffn")),
        "ff_wo": ParamDef((ff, D), dtype, ("ffn", "embed")),
    }


class SLSTMState(NamedTuple):
    c: jax.Array    # (B, D) fp32
    n: jax.Array    # (B, D) fp32
    h: jax.Array    # (B, D) fp32
    m: jax.Array    # (B, D) fp32


def slstm_init_state(cfg, batch: int) -> SLSTMState:
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, D), -1e30, jnp.float32))


def _slstm_cell(p, wx, st: SLSTMState, H, P):
    """wx: (B, 4D) precomputed input contribution; one recurrent step."""
    B = wx.shape[0]
    D = H * P
    h_heads = st.h.reshape(B, H, P)
    rh = jnp.einsum("bhp,ghpj->gbhj", h_heads.astype(jnp.float32),
                    p["r"].astype(jnp.float32)).reshape(4, B, D)
    pre = wx.astype(jnp.float32).reshape(B, 4, D).transpose(1, 0, 2) \
        + rh + p["b"][:, None, :]
    zt = jnp.tanh(pre[0])
    it = pre[1]                                  # log-domain input gate
    ft = jax.nn.log_sigmoid(pre[2])              # log-domain forget gate
    ot = jax.nn.sigmoid(pre[3])
    m1 = jnp.maximum(ft + st.m, it)
    fp = jnp.exp(ft + st.m - m1)
    ip = jnp.exp(it - m1)
    c1 = fp * st.c + ip * zt
    n1 = fp * st.n + ip
    h1 = ot * c1 / jnp.maximum(n1, jnp.exp(-m1))
    return SLSTMState(c=c1, n=n1, h=h1, m=m1)


def slstm_forward(p, x, cfg, state: SLSTMState | None = None):
    """Full sLSTM block (recurrent scan over time). x: (B,S,D)."""
    B, S, D = x.shape
    H = cfg.n_heads
    P = D // H
    if state is None:
        state = slstm_init_state(cfg, B)

    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("bsd,de->bse", xn, p["w_in"])     # (B,S,4D) hoisted

    if cfg.accounting:
        # ACCOUNTING ONLY (lowered, never executed): replace the true
        # recurrence with a flop-equivalent parallel program so XLA
        # cost_analysis counts the S recurrent R-matmuls exactly once
        # each (a scan body would be counted once total).
        xh = xn.reshape(B, S, H, P).astype(jnp.float32)
        rh = jnp.einsum("bshp,ghpj->bsghj", xh,
                        p["r"].astype(jnp.float32)).reshape(B, S, 4 * D)
        pre = wx.astype(jnp.float32) + rh
        y = jnp.tanh(pre[..., :D]).astype(x.dtype)
        state = slstm_init_state(cfg, B)
    else:
        def step(st, wxt):
            st1 = _slstm_cell(p, wxt, st, H, P)
            return st1, st1.h

        state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
        y = hs.swapaxes(0, 1).astype(x.dtype)         # (B,S,D)

    # post-block gated FFN
    yn = rmsnorm(p["ff_norm"], x + y, cfg.norm_eps)
    f = jnp.einsum("bsd,df->bsf", yn, p["ff_wi"])
    g = jnp.einsum("bsd,df->bsf", yn, p["ff_wg"])
    f = jax.nn.silu(g.astype(jnp.float32)).astype(f.dtype) * f
    out = y + jnp.einsum("bsf,fd->bsd", f, p["ff_wo"])
    return out, state


def slstm_step(p, x, state: SLSTMState, cfg):
    """Single-token decode. x: (B,D)."""
    H, P = cfg.n_heads, cfg.d_model // cfg.n_heads
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("bd,de->be", xn, p["w_in"])
    st = _slstm_cell(p, wx, state, H, P)
    y = st.h.astype(x.dtype)
    yn = rmsnorm(p["ff_norm"], x + y, cfg.norm_eps)
    f = jnp.einsum("bd,df->bf", yn, p["ff_wi"])
    g = jnp.einsum("bd,df->bf", yn, p["ff_wg"])
    f = jax.nn.silu(g.astype(jnp.float32)).astype(f.dtype) * f
    out = y + jnp.einsum("bf,fd->bd", f, p["ff_wo"])
    return out, st
