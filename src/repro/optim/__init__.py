from repro.optim.adamw import OptConfig, OptState, init, update, schedule, opt_state_pspecs, global_norm  # noqa: F401
