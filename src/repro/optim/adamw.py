"""AdamW with mixed-precision state, schedules, clipping, ZeRO-1.

No optax in the container — this is a complete implementation:
  * fp32 master weights (optional; required when params are bf16),
  * m/v moments in a configurable dtype (bf16 halves optimizer HBM —
    what lets deepseek-v3-671b fit the 512-chip mesh; see
    EXPERIMENTS.md §Dry-run),
  * global-norm clipping,
  * warmup + cosine decay schedule,
  * ZeRO-1: `zero1_pspecs` shards every optimizer-state dim that the
    param left replicated over the data axes (GSPMD then reduces
    gradients with reduce-scatter + all-gathers updated params).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # bfloat16 halves optimizer HBM
    master_dtype: str = "float32"      # fp32 master copies of bf16 params


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any        # fp32 params (or None-tree when params are fp32)


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: OptConfig, params) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    needs_master = any(p.dtype != jnp.float32
                       for p in jax.tree.leaves(params))
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if needs_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v, master=master)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.master if state.master is not None else params

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        upd_ = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (upd_ + cfg.weight_decay * p32)
        return m32.astype(m.dtype), v32.astype(v.dtype), p32

    out = jax.tree.map(upd, grads, state.m, state.v, ref)
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    p32 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))

    if state.master is not None:
        new_master = p32
        new_params = jax.tree.map(
            lambda p32_, p: p32_.astype(p.dtype), p32, params)
    else:
        new_master = None
        new_params = p32

    st = OptState(step=step, m=m, v=v, master=new_master)
    return new_params, st, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------- ZeRO-1

def zero1_pspecs(param_pspec_tree, params_abstract, mesh,
                 dp_axis="data"):
    """Shard optimizer-state copies of replicated dims over `dp_axis`.

    For each param pspec, find the largest dim whose spec is None and
    whose size divides the data-axis size; assign it to dp_axis.  The
    result is applied to m / v / master (ZeRO-1): gradients reduce with
    reduce-scatter into the state shards, updated params all-gather.
    """
    n_dp = mesh.shape[dp_axis]

    def one(ps: PS, aval):
        entries = list(ps) + [None] * (len(aval.shape) - len(ps))
        if dp_axis in jax.tree.leaves(list(entries)):
            return PS(*entries)
        best, best_size = -1, 0
        for i, (e, s) in enumerate(zip(entries, aval.shape)):
            if e is None and s % n_dp == 0 and s > best_size:
                best, best_size = i, s
        if best >= 0:
            entries[best] = dp_axis
        return PS(*entries)

    return jax.tree.map(one, param_pspec_tree, params_abstract,
                        is_leaf=lambda x: isinstance(x, PS))


def opt_state_pspecs(cfg: OptConfig, param_pspec_tree, params_abstract,
                     mesh, zero1=True):
    base = (zero1_pspecs(param_pspec_tree, params_abstract, mesh)
            if (zero1 and "data" in mesh.axis_names) else param_pspec_tree)
    needs_master = any(a.dtype != jnp.float32
                       for a in jax.tree.leaves(params_abstract))
    return OptState(step=PS(), m=base, v=base,
                    master=(base if needs_master else None))
