from repro.runtime.resilience import StragglerMonitor, Heartbeat, RestartPolicy, run_with_restarts  # noqa: F401
