"""Fault-tolerance runtime: straggler monitor, heartbeat, restart loop.

On a 1000+-node cluster the failure modes this layer covers:
  * slow host / degraded chip  -> StragglerMonitor flags steps beyond
    k x trailing-median; the launcher's policy decides (log, exclude
    host on next restart, or checkpoint-now),
  * hang                       -> Heartbeat file ages out; the external
    supervisor (launch/run_elastic.sh) kills and restarts the job,
  * crash                      -> run_with_restarts resumes from the
    latest complete checkpoint (data pipeline is stateless-resumable,
    see data/pipeline.py).

The serving scheduler (``engine.scheduler``) reuses the same pieces at
request granularity: StragglerMonitor + Heartbeat ride the decode loop,
``RetryPolicy``/``call_with_retries`` bound the transient-step retry,
and ``percentiles`` summarizes per-request latency.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 warmup: int = 5):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.warmup = warmup
        self.flagged: List[dict] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self) -> Optional[dict]:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._step += 1
        flag = None
        if len(self.window) >= self.warmup:
            med = sorted(self.window)[len(self.window) // 2]
            if dt > self.threshold * med:
                flag = {"step": self._step, "dt": dt, "median": med}
                self.flagged.append(flag)
        self.window.append(dt)
        return flag

    @property
    def median(self) -> float:
        if not self.window:
            return 0.0
        return sorted(self.window)[len(self.window) // 2]


class Heartbeat:
    """Touches a file each step; an external supervisor treats a stale
    heartbeat as a hang and restarts the worker."""

    def __init__(self, path: str, interval_s: float = 15.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0

    def beat(self, step: int, extra: Optional[dict] = None):
        now = time.time()
        if now - self._last < self.interval:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now, **(extra or {})}, f)
        os.replace(tmp, self.path)


@dataclass
class RetryPolicy:
    """Bounded retry with linear backoff for one *call* (a decode or
    prefill step), as opposed to ``RestartPolicy`` which governs whole
    process restarts.  ``max_retries=0`` disables retrying.

    ``fatal`` exception types re-raise immediately without burning the
    retry budget: a simulated process death (``engine.faults.
    CrashError``) is not a transient blip a retry could heal — the
    restart loop, not the step retry, is the layer that answers it."""
    max_retries: int = 2
    backoff_s: float = 0.05
    fatal: tuple = ()


def call_with_retries(fn: Callable, *args,
                      policy: Optional[RetryPolicy] = None,
                      on_retry: Optional[Callable[[int, Exception],
                                                  None]] = None):
    """Call ``fn(*args)``; on exception retry up to
    ``policy.max_retries`` times, sleeping ``backoff_s * attempt``
    between attempts (``on_retry(attempt, exc)`` fires before each
    retry).  Re-raises the last exception once the budget is spent —
    persistent faults are not request-level and must surface.
    Exceptions matching ``policy.fatal`` re-raise immediately."""
    policy = policy or RetryPolicy()
    last: Optional[Exception] = None
    for attempt in range(policy.max_retries + 1):
        if attempt:
            if on_retry is not None:
                on_retry(attempt, last)
            time.sleep(policy.backoff_s * attempt)
        try:
            return fn(*args)
        except KeyboardInterrupt:
            raise
        except Exception as e:                      # noqa: BLE001
            if policy.fatal and isinstance(e, policy.fatal):
                raise
            last = e
    raise last


def percentiles(samples: Sequence[float],
                qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
    """{'p50': ..., 'p90': ..., 'p99': ...} by linear interpolation
    over sorted ``samples`` (empty input -> {})."""
    xs = sorted(samples)
    if not xs:
        return {}
    out = {}
    for q in qs:
        pos = (len(xs) - 1) * (q / 100.0)
        lo, hi = int(pos), min(int(pos) + 1, len(xs) - 1)
        out[f"p{q:g}"] = xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
    return out


@dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 1.0


def run_with_restarts(make_state: Callable[[Optional[int]], object],
                      run: Callable[[object], None],
                      store,
                      policy: RestartPolicy = RestartPolicy()):
    """make_state(resume_step|None) -> state;  run(state) raises on
    failure.  Resumes from store.latest_step() after each failure."""
    attempts = 0
    while True:
        resume = store.latest_step()
        state = make_state(resume)
        try:
            run(state)
            return
        except KeyboardInterrupt:
            raise
        except Exception as e:                      # noqa: BLE001
            attempts += 1
            if attempts > policy.max_restarts:
                raise
            time.sleep(policy.backoff_s * attempts)


def serve_with_recovery(engine, snapshot_dir: str, submit: Callable,
                        *, snapshot_every: int = 0, keep: int = 3,
                        policy: RestartPolicy = RestartPolicy(),
                        on_start: Optional[Callable] = None,
                        sched_kwargs: Optional[dict] = None):
    """Durable serving supervisor: ``run_with_restarts`` wrapped around
    a snapshot-cadenced, journaled scheduler drain.

    The first attempt builds a FRESH scheduler and calls
    ``submit(sched)`` to enqueue the workload (every submit lands in
    the write-ahead journal under ``snapshot_dir``); the scheduler then
    snapshots its full serving state every ``snapshot_every`` steps off
    the step path (0 = journal-only durability).  When the drain raises
    — e.g. an ``engine.faults.CrashFault`` simulating process death —
    the restart loop rebuilds the scheduler from the latest complete
    snapshot (or from scratch when the crash beat the first cadence)
    and replays the journal suffix: finished results are recovered
    verbatim, post-snapshot submits re-queued, in-flight slots resume
    from their snapshotted pages and RNG state.  ``submit`` is NOT
    called again on recovery attempts — the journal is the workload's
    durable record.

    ``on_start(sched, fresh)`` runs after each (re)build — the hook
    fault-injection tests use to crash only the fresh run.  Returns the
    scheduler that completed the drain; async snapshot failures surface
    here (teardown waits on the background writer).
    """
    # engine modules import this one — keep the import lazy
    from repro.engine.journal import RequestJournal, read_events, replay
    from repro.engine.scheduler import Scheduler
    from repro.engine.snapshot import EngineSnapshotter, restore

    snapshotter = EngineSnapshotter(snapshot_dir, every=snapshot_every,
                                    keep=keep)
    journal = RequestJournal(os.path.join(snapshot_dir, "journal.jsonl"))
    kw = dict(sched_kwargs or {})
    done: dict = {}

    def make_state(resume):
        events = read_events(journal.path)
        if resume is None and not events:
            sched = Scheduler(engine, journal=journal,
                              snapshotter=snapshotter, **kw)
            submit(sched)
            fresh = True
        else:
            sched = restore(snapshotter, engine, step=resume,
                            journal=journal, snapshotter=snapshotter,
                            **kw)
            replay(sched, events)
            fresh = False
        if on_start is not None:
            on_start(sched, fresh)
        done["sched"] = sched
        return sched

    try:
        run_with_restarts(make_state, lambda s: s.run(), snapshotter,
                          policy)
    finally:
        journal.close()
        snapshotter.close()     # re-raises a failed async snapshot
    return done["sched"]
