"""Per-architecture smoke: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step + one decode step on
CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import lm

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        nf = cfg.frontend_tokens
        batch = {"tokens": tokens[:, : S - nf],
                 "labels": tokens[:, : S - nf],
                 "loss_mask": jnp.ones((B, S - nf), jnp.float32),
                 "frontend_emb": jax.random.normal(
                     KEY, (B, nf, cfg.frontend_dim))}
    if cfg.family == "audio":
        batch["frontend_emb"] = jax.random.normal(
            KEY, (B, S, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: lm.train_loss(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    g = jax.grad(lambda p: lm.train_loss(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init(cfg, KEY)
    cache = lm.init_cache(cfg, B, 16, enc_len=16)
    tok = jax.random.randint(KEY, (B,), 0, cfg.vocab)
    logits, new_cache = jax.jit(lambda p, b: lm.decode_step(p, b, cfg))(
        params, {"token": tok, "cur_len": jnp.int32(3), "cache": cache})
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # padded logits masked to -inf never win an argmax
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_prefill(arch):
    cfg = reduced(get_config(arch))
    params = lm.init(cfg, KEY)
    batch = _batch(cfg)
    logits, caches = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg))(params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
