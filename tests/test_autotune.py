"""Autotuner: cost-model prior sanity, cache round-trip
(miss -> measure -> persist -> hit), and the models-layer pallas path
(fused epilogues + zero-copy GQA + autotuned blocks) against the XLA
reference formulation."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def tuner(tmp_path, monkeypatch):
    """Redirect the JSON cache to a tmp file and reset in-memory state."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.reset()
    yield path
    autotune.reset()


def test_cache_round_trip_matmul(tuner):
    x = jax.random.normal(KEY, (96, 64))
    w = jax.random.normal(KEY, (64, 48))
    out = ops.vwr_matmul(x, w)                  # miss: measure + persist
    assert autotune.stats["misses"] == 1
    assert autotune.stats["measured"] >= 1
    measured = autotune.stats["measured"]
    assert os.path.exists(tuner)
    entry, = json.load(open(tuner)).values()
    assert len(entry["blocks"]) == 3 and entry["us"] > 0

    ops.vwr_matmul(x, w)                        # identical key: pure hit
    assert autotune.stats["hits"] == 1
    assert autotune.stats["measured"] == measured

    autotune.reset()                            # simulate process restart
    ops.vwr_matmul(x, w)                        # re-read from disk, no
    assert autotune.stats["hits"] == 1          # re-measure
    assert autotune.stats["measured"] == 0

    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=2e-4, atol=2e-4)


def test_cache_keys_distinguish_shape_and_dtype(tuner):
    x = jax.random.normal(KEY, (64, 64))
    ops.vwr_matmul(x, x)
    ops.vwr_matmul(x.astype(jnp.bfloat16), x.astype(jnp.bfloat16))
    ops.vwr_matmul(jax.random.normal(KEY, (32, 64)), x)
    assert autotune.stats["misses"] == 3
    assert len(json.load(open(tuner))) == 3


def test_attention_autotune_round_trip(tuner):
    q = jax.random.normal(KEY, (1, 64, 4, 16))
    k = jax.random.normal(KEY, (1, 64, 2, 16))
    out = ops.vwr_attention(q, k, k, causal=True)
    assert autotune.stats["misses"] == 1
    ops.vwr_attention(q, k, k, causal=True)
    assert autotune.stats["hits"] == 1
    from repro.models.attention import full_attn_ref
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full_attn_ref(q, k, k,
                                                        causal=True)),
                               rtol=2e-4, atol=2e-4)


def test_disabled_autotune_uses_prior_without_cache(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    x = jax.random.normal(KEY, (64, 64))
    out = ops.vwr_matmul(x, x)
    assert autotune.stats["misses"] == 0
    assert autotune.stats["measured"] == 0
    assert not os.path.exists(tuner)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ x),
                               rtol=2e-4, atol=2e-4)


def test_candidates_respect_constraints():
    for cand in autotune.matmul_candidates(100, 130, 50, "float32"):
        vmem = sum((cand[0] * cand[1], cand[1] * cand[2],
                    cand[0] * cand[2])) * 4 + cand[0] * cand[2] * 4
        assert vmem <= autotune.VMEM_BUDGET
        # pure powers of two: Mosaic tile alignment on real TPUs
        for b in cand:
            assert b & (b - 1) == 0, cand
    for bq, bkv in autotune.attention_candidates(96, 32, "float32",
                                                 causal=True):
        assert max(bq, bkv) % min(bq, bkv) == 0
    for bq, bkv in autotune.attention_candidates(256, 32, "float32",
                                                 causal=False):
        assert 256 % max(bq, bkv) == 0


def test_non_causal_ragged_seq_falls_back_to_clamped_blocks(tuner):
    """S=100 has no divisible power-of-two block: the candidate set
    must fall back to the clamped (S, S) pair instead of raising
    (regression: the pure-pow2 candidate change dropped it)."""
    from repro.models.attention import full_attn_ref
    q = jax.random.normal(KEY, (1, 100, 4, 16))
    k = jax.random.normal(jax.random.split(KEY)[0], (1, 100, 2, 16))
    out = ops.vwr_attention(q, k, k, causal=False)
    want = full_attn_ref(q, k, k, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_partial_pin_is_honored(tuner):
    """Pinning a subset of block sizes must keep the pins (fills the
    rest from defaults) and must NOT consult the tuner."""
    x = jax.random.normal(KEY, (64, 64))
    out = ops.vwr_matmul(x, x, bm=32)
    assert autotune.stats["misses"] == 0 and autotune.stats["hits"] == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ x),
                               rtol=2e-4, atol=2e-4)
    # ragged S with a pinned bq: the fill must mirror the pin so the
    # nesting assert can't trip (S=96 clamps a default bkv to 96,
    # which does not nest with bq=64)
    q = jax.random.normal(KEY, (1, 96, 4, 16))
    out = ops.vwr_attention(q, q, q, causal=True, bq=64)
    assert autotune.stats["misses"] == 0
    assert out.shape == (1, 96, 4, 16)


def test_train_loss_rejects_forward_only_pallas():
    from repro.common.config import ModelConfig
    from repro.models import lm
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_head=16, d_ff=64,
                      vocab=64, dtype="float32", remat="none",
                      kernel_impl="pallas")
    with pytest.raises(ValueError, match="forward-only"):
        lm.train_loss({}, {"tokens": None}, cfg)


def test_prior_prefers_wide_blocks_on_big_shapes():
    """The width-ratio cost model must rank the widest VMEM-legal block
    first on a large square matmul (the paper's access-ratio monotone)."""
    cands = autotune.matmul_candidates(2048, 2048, 2048, "bfloat16")
    best = min(cands, key=lambda c: autotune.matmul_prior(
        2048, 2048, 2048, "bfloat16", c))
    assert best[0] * best[1] * best[2] == max(
        bm * bk * bn for bm, bk, bn in cands)


# ---------------------------------------------------------------- models

def test_backbone_pallas_matches_xla(tuner):
    """cfg.kernel_impl='pallas' (fused qkv-bias/activation/residual
    epilogues + zero-copy GQA flash kernel, autotuned blocks) is
    semantics-preserving vs the einsum/blockwise reference."""
    from repro.common.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                      vocab=256, dtype="float32", remat="none",
                      attn_block_q=32, attn_block_kv=32, qkv_bias=True)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 96), 0, 256)
    want = lm.backbone(params, toks, cfg)
    got = lm.backbone(params, toks, cfg.replace(kernel_impl="pallas"))
    np.testing.assert_allclose(np.asarray(got.h), np.asarray(want.h),
                               rtol=2e-4, atol=2e-4)
    # second run hits the tuning cache for every op in the stack
    hits0 = autotune.stats["hits"]
    misses0 = autotune.stats["misses"]
    lm.backbone(params, toks, cfg.replace(kernel_impl="pallas"))
    assert autotune.stats["misses"] == misses0
    assert autotune.stats["hits"] > hits0


def test_measure_discards_compile_and_reports_median(monkeypatch):
    """The first (compile) call never enters the statistic; the result
    is the median of the timed reps."""
    monkeypatch.setenv("REPRO_AUTOTUNE_REPS", "5")
    calls = {"n": 0}

    def run():
        calls["n"] += 1
    us = autotune._measure(run)
    assert calls["n"] == 6            # 1 discarded compile + 5 timed
    assert us >= 0.0


def test_conv_autotune_round_trip(tuner):
    """vwr_conv2d with unpinned blocks consults the shared-prior
    tuner: miss -> measure -> persist -> hit."""
    x = jax.random.normal(KEY, (1, 12, 12, 8))
    w = jax.random.normal(KEY, (3, 3, 8, 16))
    out = ops.vwr_conv2d(x, w)
    assert autotune.stats["misses"] == 1
    ops.vwr_conv2d(x, w)
    assert autotune.stats["hits"] == 1
    from repro.kernels import ref
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w)),
                               rtol=2e-4, atol=2e-4)


def test_conv_prior_shares_staging_energy_tiebreak():
    """The conv prior returns the same (time, energy-per-bit) tuple
    shape as matmul/attention, with the eq.-2 energy falling as the
    staged transaction widens (the shared Fig. 2b monotone)."""
    narrow = autotune.conv_prior(1, 64, 64, 32, 3, 3, 64, "float32",
                                 (2, 32))
    wide = autotune.conv_prior(1, 64, 64, 32, 3, 3, 64, "float32",
                               (8, 32))
    assert len(narrow) == 2 and len(wide) == 2
    assert wide[1] <= narrow[1]       # wider row block, cheaper per bit


def test_decode_autotune_round_trip(tuner):
    q = jax.random.normal(KEY, (1, 4, 16))
    k = jax.random.normal(KEY, (1, 64, 2, 16))
    o_t, m, l = ops.vwr_flash_decode(q, k, k, jnp.int32(64))
    assert autotune.stats["misses"] == 1
    ops.vwr_flash_decode(q, k, k, jnp.int32(64))
    assert autotune.stats["hits"] == 1
    from repro.models.attention import decode_attend_local
    got = o_t / np.maximum(np.asarray(l), 1e-30)[..., None]
    want = decode_attend_local(q, k, k, jnp.arange(64), jnp.int32(64))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
