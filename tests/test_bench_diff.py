"""scripts/bench_diff.py: row matching + regression flagging."""
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
import bench_diff  # noqa: E402


def _row(op, shape, us, note="n 42"):
    return {"op": op, "shape": shape, "us": us, "note": note}


def test_diff_flags_only_over_threshold_regressions():
    old = [_row("matmul", "256x256x256", 100.0),
           _row("decode", "4x2048", 50.0),
           _row("mla_decode", "4x2048", 30.0, note="mla_split 99 B"),
           _row("mla_decode", "4x2048", 80.0, note="mla_concat 11 B"),
           _row("gone", "1x1", 5.0)]
    new = [_row("matmul", "256x256x256", 115.0),      # +15%: flagged
           _row("decode", "4x2048", 54.0),            # +8%: fine
           # same (op, shape), disambiguated by digit-stripped note
           _row("mla_decode", "4x2048", 31.0, note="mla_split 77 B"),
           _row("mla_decode", "4x2048", 60.0, note="mla_concat 22 B"),
           _row("added", "2x2", 7.0)]
    res = bench_diff.diff(old, new, threshold=0.10)
    assert [(e["op"], e["ratio"]) for e in res["regressions"]] == \
        [("matmul", 1.15)]
    assert [e["op"] for e in res["improvements"]] == ["mla_decode"]
    assert res["only_old"] == [("gone", "1x1")]
    assert res["only_new"] == [("added", "2x2")]


def test_diff_pairs_colliding_keys_by_order():
    """Rows whose digit-stripped notes collide (block-size sweeps) are
    paired by emission order — a regression in the SECOND such row
    must still be flagged, not silently dropped."""
    old = [_row("vwr_matmul", "256x256x256", 100.0, note="b64x64x64"),
           _row("vwr_matmul", "256x256x256", 100.0, note="b128x128x64"),
           _row("vwr_matmul", "256x256x256", 100.0, note="b256x64x64")]
    new = [_row("vwr_matmul", "256x256x256", 100.0, note="b64x64x64"),
           _row("vwr_matmul", "256x256x256", 310.0, note="b128x128x64"),
           _row("vwr_matmul", "256x256x256", 100.0, note="b256x64x64")]
    res = bench_diff.diff(old, new, threshold=0.10)
    assert [(e["note"], e["ratio"]) for e in res["regressions"]] == \
        [("b128x128x64", 3.1)]
    assert not res["only_old"] and not res["only_new"]


def test_diff_flags_staged_bytes_regressions():
    """A matched row whose ``staged_bytes`` column grew past the
    threshold is flagged even when its latency held still — the
    quantized-KV benchmarks' headline is bytes, not us."""
    old = [_row("paged_decode_q8", "4x2048", 50.0) | {
               "staged_bytes": 1_000_000},
           _row("decode", "4x2048", 50.0) | {"staged_bytes": 500_000}]
    new = [_row("paged_decode_q8", "4x2048", 50.0) | {
               "staged_bytes": 1_200_000},        # +20% bytes: flagged
           _row("decode", "4x2048", 50.0) | {"staged_bytes": 520_000}]
    res = bench_diff.diff(old, new, threshold=0.10)
    assert not res["regressions"]
    assert [(e["op"], e["ratio"]) for e in res["byte_regressions"]] == \
        [("paged_decode_q8", 1.2)]
    assert res["byte_regressions"][0]["staged_bytes_old"] == 1_000_000
    assert res["byte_regressions"][0]["staged_bytes_new"] == 1_200_000


def test_diff_flags_speedup_regressions():
    """A row carrying a within-run baseline (``us_ref`` — the
    prefix_cache_decode TTFT row's cold reference) is flagged when the
    SPEEDUP us_ref/us shrinks past the threshold, even if both
    absolute latencies moved together (machine-load jitter)."""
    old = [_row("prefix_cache_decode", "m:104p", 1000.0) | {
               "us_ref": 5000.0}]                  # 5.0x warm-vs-cold
    # everything 2x slower (load), but the ratio collapsed to 2.2x
    new = [_row("prefix_cache_decode", "m:104p", 4500.0) | {
               "us_ref": 10000.0}]
    res = bench_diff.diff(old, new, threshold=0.10)
    assert [(e["op"], e["speedup_old"], e["speedup_new"])
            for e in res["speedup_regressions"]] == \
        [("prefix_cache_decode", 5.0, 2.222)]
    # a proportional slowdown keeps the ratio: no speedup flag
    prop = [_row("prefix_cache_decode", "m:104p", 2000.0) | {
                "us_ref": 10000.0}]
    assert not bench_diff.diff(old, prop,
                               threshold=0.10)["speedup_regressions"]


def test_cli_fail_flag_counts_speedup_regressions(tmp_path):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(
        [_row("prefix_cache_decode", "s", 100.0) | {"us_ref": 500.0}]))
    new.write_text(json.dumps(
        [_row("prefix_cache_decode", "s", 105.0) | {"us_ref": 210.0}]))
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "bench_diff.py")
    r = subprocess.run([sys.executable, script, str(old), str(new),
                        "--fail"], capture_output=True, text=True)
    assert r.returncode == 1
    assert "SPEEDUP-REGRESSION" in r.stdout
    assert "1 speedup" in r.stdout


def test_diff_ignores_missing_staged_bytes():
    """Rows without the column (most latency benches) never produce
    byte flags."""
    old = [_row("matmul", "s", 100.0),
           _row("engine", "a", 10.0) | {"staged_bytes": None}]
    new = [_row("matmul", "s", 100.0),
           _row("engine", "a", 10.0) | {"staged_bytes": 999}]
    res = bench_diff.diff(old, new)
    assert not res["byte_regressions"]


def test_cli_fail_flag_counts_byte_regressions(tmp_path):
    """--fail exits nonzero on a staged-bytes-only regression."""
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(
        [_row("paged_decode_q8", "s", 100.0) | {"staged_bytes": 100}]))
    new.write_text(json.dumps(
        [_row("paged_decode_q8", "s", 100.0) | {"staged_bytes": 150}]))
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "bench_diff.py")
    r = subprocess.run([sys.executable, script, str(old), str(new),
                        "--fail"], capture_output=True, text=True)
    assert r.returncode == 1
    assert "BYTES-REGRESSION" in r.stdout
    assert "1 staged-bytes" in r.stdout


def test_diff_ignores_untimed_rows():
    old = [_row("engine", "a", None), _row("x", "s", 0)]
    new = [_row("engine", "a", 99.0), _row("x", "s", 99.0)]
    res = bench_diff.diff(old, new)
    assert not res["regressions"] and not res["improvements"]


def test_cli_self_diff_is_clean(tmp_path):
    """A file diffed against itself reports nothing and exits 0 even
    with --fail — the CI invariant."""
    rows = [_row("matmul", "256x256x256", 100.0)]
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(rows))
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "bench_diff.py")
    r = subprocess.run([sys.executable, script, str(p), str(p),
                        "--fail"], capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 regression(s)" in r.stdout


def test_cli_fail_flag_exits_nonzero(tmp_path):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps([_row("matmul", "s", 100.0)]))
    new.write_text(json.dumps([_row("matmul", "s", 200.0)]))
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "bench_diff.py")
    r = subprocess.run([sys.executable, script, str(old), str(new),
                        "--fail"], capture_output=True, text=True)
    assert r.returncode == 1
    assert "REGRESSION" in r.stdout
