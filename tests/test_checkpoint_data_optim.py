"""Checkpoint store, data pipeline, optimizer, runtime resilience."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import (DataConfig, MemmapTokens, Prefetcher, SyntheticLM,
                        pack_documents)
from repro.optim import OptConfig, adamw
from repro.runtime import (Heartbeat, RestartPolicy, StragglerMonitor,
                           run_with_restarts)


# ---------------------------------------------------------------- ckpt

def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    store.save(1, tree)
    spec = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        tree)
    out = store.restore(1, spec)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_keep_k_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    assert store.steps() == [3, 4]
    assert store.latest_step() == 4


def test_checkpoint_atomicity(tmp_path):
    """Incomplete directories (no _COMPLETE) are invisible."""
    store = CheckpointStore(str(tmp_path))
    tree = {"x": jnp.ones((2,))}
    store.save(5, tree)
    os.makedirs(tmp_path / "step_9")          # crashed write, no marker
    assert store.latest_step() == 5


def test_checkpoint_async(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"x": jnp.full((128, 128), 3.0)}
    store.save(1, tree, async_=True)
    store.wait()
    out = store.restore(1, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    assert float(out["x"][0, 0]) == 3.0


def test_checkpoint_async_failure_surfaces_on_wait(tmp_path,
                                                   monkeypatch):
    """A failed background write re-raises at wait() — exactly once —
    instead of being dropped (or surfacing only on the NEXT save);
    after the raise the store is usable again."""
    store = CheckpointStore(str(tmp_path))
    tree = {"x": jnp.zeros((2,))}
    real_write = store._write

    def boom(step, host):
        raise OSError("disk died")

    monkeypatch.setattr(store, "_write", boom)
    store.save(1, tree, async_=True)
    with pytest.raises(OSError, match="disk died"):
        store.wait()
    store.wait()                        # idempotent: no second raise
    monkeypatch.setattr(store, "_write", real_write)
    store.save(2, tree, async_=True)    # save() joins via wait() too
    store.wait()
    assert store.latest_step() == 2


def test_checkpoint_steps_skips_stray_dirs(tmp_path):
    """Non-numeric step_* entries (step_backup, a stray file) must not
    kill restore discovery."""
    store = CheckpointStore(str(tmp_path))
    store.save(3, {"x": jnp.zeros((2,))})
    os.makedirs(tmp_path / "step_backup")
    (tmp_path / "step_backup" / "_COMPLETE").write_text("ok")
    (tmp_path / "step_7b").mkdir()
    assert store.steps() == [3]
    assert store.latest_step() == 3


# ---------------------------------------------------------------- data

def test_synthetic_determinism():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, n_shards=2)
    d = SyntheticLM(cfg)
    b1 = d.batch(7, shard=1)
    b2 = d.batch(7, shard=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(8, shard=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels shifted by one
    full = SyntheticLM(DataConfig(vocab=1000, seq_len=64, global_batch=8))
    b = full.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pack_and_memmap(tmp_path):
    docs = [[5, 6, 7], [9] * 10, [3, 4]]
    rows = pack_documents(docs, seq_len=8, eos_id=1)
    assert rows.shape[1] == 8
    flat = np.concatenate([rows.reshape(-1), np.zeros(1, np.int32)])
    path = str(tmp_path / "toks.bin")
    flat.astype(np.int32).tofile(path)
    mm = MemmapTokens(path, DataConfig(vocab=16, seq_len=4,
                                       global_batch=2))
    b = mm.batch(0)
    assert b["tokens"].shape == (2, 4)
    # EOS boundary masks the cross-document label
    assert (b["loss_mask"][b["tokens"] == 1] == 0).all()


def test_prefetcher():
    it = iter([{"x": i} for i in range(5)])
    pf = Prefetcher(it, depth=2)
    got = [b["x"] for b in pf]
    assert got == list(range(5))


# ---------------------------------------------------------------- optim

def test_adamw_converges_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    st = adamw.init(cfg, params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, st, _ = adamw.update(cfg, g, st, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_bf16_moments_master():
    cfg = OptConfig(lr=0.01, warmup_steps=0, total_steps=10,
                    moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw.init(cfg, params)
    assert st.m["w"].dtype == jnp.bfloat16
    assert st.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    params, st, m = adamw.update(cfg, g, st, params)
    assert params["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(m["grad_norm"]))


def test_grad_clip():
    cfg = OptConfig(lr=0.0, warmup_steps=0, total_steps=10,
                    grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    st = adamw.init(cfg, params)
    g = {"w": jnp.full((3,), 100.0)}
    _, _, m = adamw.update(cfg, g, st, params)
    assert float(m["grad_norm"]) > 100


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    s0 = float(adamw.schedule(cfg, jnp.int32(0)))
    s10 = float(adamw.schedule(cfg, jnp.int32(10)))
    s100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert s0 < 0.2 and abs(s10 - 1.0) < 0.01 and abs(s100 - 0.1) < 0.01


# ---------------------------------------------------------------- runtime

def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=2.0, warmup=3)
    for _ in range(5):
        mon.start_step()
        time.sleep(0.01)
        assert mon.end_step() is None
    mon.start_step()
    time.sleep(0.08)
    flag = mon.end_step()
    assert flag is not None and flag["dt"] > 2 * flag["median"]


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), interval_s=0.0)
    hb.beat(3)
    assert os.path.exists(tmp_path / "hb.json")


def test_run_with_restarts(tmp_path):
    store = CheckpointStore(str(tmp_path))
    calls = []

    def make_state(resume):
        calls.append(resume)
        return {"resume": resume}

    def run(state):
        if state["resume"] is None:
            store.save(10, {"x": jnp.ones(2)})
            raise RuntimeError("simulated node failure")
        assert state["resume"] == 10

    run_with_restarts(make_state, run, store,
                      RestartPolicy(max_restarts=3, backoff_s=0.0))
    assert calls == [None, 10]
