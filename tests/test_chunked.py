"""Chunked prefill + unified mixed-step tests: the ``pack_chunk``
token-budget rule's boundary cases, the chunk-prefix kernel partials
(pallas interpret vs the XLA gather reference, incl. int8 pools and
the fully-masked-prefix identity), greedy bit-identity of the chunked
scheduler against the non-chunked one for every paged family x kv
dtype x prefix-cache setting, the page-boundary / 1-token-final-chunk
/ prefix-hit-all-but-one-token admission edges, mid-prefill preemption
keeping exactly the completed whole pages, and the mixed-step
transient-fault retry redoing only the in-flight chunk (the hypothesis
mirror of the packer invariants lives in tests/test_resilience_prop.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig, MoEConfig
from repro.engine import (DecodeEngine, EngineConfig, Request,
                          RequestStatus, Scheduler, faults)
from repro.engine.scheduler import pack_chunk
from repro.models import attention as A

PS = 4          # page_size used throughout
CT = 8          # chunk_tokens (2 pages) used throughout


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


_MLA = MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                 nope_head_dim=16, v_head_dim=16)


def _mla_cfg():
    return _cfg(mla=_MLA)


def _moe_mla_cfg():
    return _cfg(family="moe",
                moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                              first_k_dense=1, d_ff_dense=128,
                              capacity_factor=4.0),
                mla=_MLA)


def _engine(cfg, B=2, max_len=32, n_pages=24, **kw):
    return DecodeEngine(cfg, EngineConfig(
        batch=B, max_len=max_len, paged=True, page_size=PS,
        n_pages=n_pages, chunked_prefill=True, chunk_tokens=CT, **kw))


def _run(eng, reqs, **sched_kw):
    sched = Scheduler(eng, **sched_kw)
    for r in reqs:
        sched.submit(r)
    return sched, sched.run()


def _reqs(rng, vocab, specs):
    return [Request(rid=i, tokens=rng.integers(2, vocab, (p,))
                    .astype(np.int32), gen=g, seed=i)
            for i, (p, g) in enumerate(specs)]


# ------------------------------------------------- pack_chunk boundaries


@pytest.mark.parametrize("remaining,n_decode,budget,want", [
    (40, 2, 2 + CT, CT),       # full chunk fits beside the decodes
    (40, 10, 10, 0),           # decode fills the budget: no chunk
    (40, 7, 10, 0),            # room 3 < page: floored away
    (40, 6, 10, PS),           # room 4: one whole page
    (40, 2, 2 + CT - 1, PS),   # room 7 floors to one page, not two
    (5, 2, 2 + CT, 5),         # final chunk: exact, unaligned
    (1, 2, 2 + CT, 1),         # 1-token final chunk
    (CT, 2, 2 + CT, CT),       # final chunk landing ON the boundary
    (40, 0, 1, 0),             # budget 1, room < page
    (3, 0, 1, 0),              # would be final but room 1 < remaining 3
    (1, 0, 1, 1),              # empty batch still prefills
], ids=["full", "starved", "floored-0", "floored-1page", "floored-7",
        "final-unaligned", "final-1tok", "final-aligned", "tiny-budget",
        "tiny-budget-nonfinal", "empty-batch"])
def test_pack_chunk_boundaries(remaining, n_decode, budget, want):
    got = pack_chunk(remaining, n_decode, budget, CT, PS)
    assert got == want
    # the invariants the hypothesis property pins, spot-checked here:
    assert got <= remaining and got <= CT
    if got:
        assert n_decode + got <= budget
    if 0 < got < remaining:
        assert got % PS == 0   # non-final chunks end page-aligned


# ------------------------------------------------- kernel partials


def _pool(rng, n_pages=6, KV=2, Dh=16):
    k = rng.standard_normal((n_pages, PS, KV, Dh)).astype(np.float32)
    v = rng.standard_normal((n_pages, PS, KV, Dh)).astype(np.float32)
    return jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("counts", [(PS, PS, PS), (PS, 3, 0), (PS, 1, 1)],
                         ids=["full-pages", "partial-tail", "sparse"])
def test_chunk_prefix_pallas_matches_xla(counts, rng):
    """The pallas chunk-prefix kernel (interpret mode on CPU) returns
    the same (o_tilde, m, l) partial as the XLA gather reference."""
    from repro.models.attention import D
    C, H, Dh = 8, 4, 16
    kp, vp = _pool(rng)
    q = jnp.asarray(rng.standard_normal((C, H, Dh)), jnp.float32)
    table = jnp.asarray([4, 1, 3], jnp.int32)
    cnt = jnp.asarray(counts, jnp.int32)
    want = D.dispatch("chunk_prefix_paged", "xla", q, kp, vp, table,
                      cnt, page_size=PS, max_pages=3)
    got = D.dispatch("chunk_prefix_paged", "pallas", q, kp, vp, table,
                     cnt, page_size=PS, max_pages=3)
    for w, g, name in zip(want, got, ("o_tilde", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_chunk_prefix_q8_pallas_matches_xla(rng):
    from repro.models.attention import D
    C, H, KV, Dh = 8, 4, 2, 16
    kq = jnp.asarray(rng.integers(-127, 128, (6, PS, KV, Dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (6, PS, KV, Dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.05, (6, KV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.05, (6, KV)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((C, H, Dh)), jnp.float32)
    table = jnp.asarray([0, 5, 2], jnp.int32)
    cnt = jnp.asarray([PS, PS, 2], jnp.int32)
    want = D.dispatch("chunk_prefix_paged_q8", "xla", q, kq, vq, ks, vs,
                      table, cnt, page_size=PS, max_pages=3)
    got = D.dispatch("chunk_prefix_paged_q8", "pallas", q, kq, vq, ks,
                     vs, table, cnt, page_size=PS, max_pages=3)
    for w, g, name in zip(want, got, ("o_tilde", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_masked_prefix_partial_is_identity(rng):
    """A fully masked prefix partial (counts all zero — the FIRST chunk
    of a prompt) merges into the self partial as an exact no-op: the
    chunk's output equals plain causal self-attention."""
    C, H, KV, Dh = 8, 4, 2, 16
    kp, vp = _pool(rng)
    q = jnp.asarray(rng.standard_normal((C, H, Dh)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((C, KV, Dh)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((C, KV, Dh)), jnp.float32)
    table = jnp.zeros((3,), jnp.int32)
    cnt = jnp.zeros((3,), jnp.int32)
    got = A.chunk_prefill_attend(q, ck, cv, kp, vp, table, cnt)
    o_t, _, l = A.chunk_self_attn_partial(q, ck, cv)
    want = A.normalize_partial(o_t, l, q.dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chunk_rows_match_whole_prefill_rows(rng):
    """Blockwise exactness, one chunk at a time: prefix partial over
    the earlier chunks' pooled KV merged with the chunk's self partial
    reproduces the corresponding rows of one dense causal pass over
    the whole prompt."""
    S, C, H, KV, Dh = 16, CT, 4, 2, 16
    k = rng.standard_normal((S, KV, Dh)).astype(np.float32)
    v = rng.standard_normal((S, KV, Dh)).astype(np.float32)
    q = rng.standard_normal((S, H, Dh)).astype(np.float32)
    o_t, _, l = A.chunk_self_attn_partial(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    whole = np.asarray(A.normalize_partial(o_t, l, jnp.float32))
    n_pages = S // PS
    kp = jnp.asarray(k.reshape(n_pages, PS, KV, Dh))
    vp = jnp.asarray(v.reshape(n_pages, PS, KV, Dh))
    # chunks after the first (chunk 0 has no prior pages; its identity
    # with plain causal self-attention is pinned above)
    for c0 in range(C, S, C):
        jp = c0 // PS
        table = jnp.arange(jp, dtype=jnp.int32)
        cnt = jnp.full((jp,), PS, jnp.int32)
        got = A.chunk_prefill_attend(
            jnp.asarray(q[c0:c0 + C]), jnp.asarray(k[c0:c0 + C]),
            jnp.asarray(v[c0:c0 + C]), kp, vp, table, cnt)
        np.testing.assert_allclose(np.asarray(got), whole[c0:c0 + C],
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"chunk at {c0}")


# ------------------------------------------------- scheduler bit-identity


# this seed pins greedy identity for the int8 cells too: chunks after
# the first read the earlier chunks' KV through the quantized pages
# where the whole prefill saw full precision, so a near-tie argmax
# could flip — identity is pinned empirically at this scale/seed,
# exactly like the prefix-cache int8 tests
_SEED = 0
_SPECS = [(19, 6), (5, 4), (11, 5)]


@pytest.mark.parametrize("make_cfg", [_cfg, _mla_cfg, _moe_mla_cfg],
                         ids=["gqa", "mla", "moe-mla"])
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("prefix", [False, True],
                         ids=["no-prefix", "prefix"])
def test_chunked_scheduler_matches_non_chunked(make_cfg, kv_dtype,
                                               prefix):
    cfg = make_cfg()
    eng = _engine(cfg, kv_dtype=kv_dtype, prefix_cache=prefix)
    rng = np.random.default_rng(_SEED)
    prompts = [rng.integers(2, cfg.vocab, (p,)).astype(np.int32)
               for p, _ in _SPECS]

    def reqs():
        return [Request(rid=i, tokens=prompts[i], gen=g, seed=i)
                for i, (_, g) in enumerate(_SPECS)]

    off, want = _run(eng, reqs(), chunked_prefill=False)
    on, got = _run(eng, reqs())
    for i in range(len(_SPECS)):
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(want[i]),
                                      err_msg=f"req {i}")
    assert on.stats["chunks"] >= 3 and on.stats["mixed_steps"] >= 3
    assert on.stats["chunked_tokens"] == sum(p for p, _ in _SPECS)
    assert off.stats["chunks"] == 0 and off.stats["mixed_steps"] == 0
    assert on.allocator.free_pages == eng.n_pages - (
        on.prefix.cached_pages if on.prefix is not None else 0)
    on.allocator.check()


# ------------------------------------------------- admission edges


def _identity_case(prompt_len, gen, want_chunks, rng):
    cfg = _cfg()
    eng = _engine(cfg, max_len=40, n_pages=32)
    toks = rng.integers(2, cfg.vocab, (prompt_len,)).astype(np.int32)
    _, want = _run(eng, [Request(rid=0, tokens=toks, gen=gen, seed=0)],
                   chunked_prefill=False)
    on, got = _run(eng, [Request(rid=0, tokens=toks, gen=gen, seed=0)])
    np.testing.assert_array_equal(np.asarray(got[0]),
                                  np.asarray(want[0]))
    assert on.stats["chunks"] == want_chunks
    assert on.allocator.free_pages == eng.n_pages


def test_chunk_ends_exactly_on_page_boundary(rng):
    """Prompt = 2 full chunks: the final chunk lands exactly on a page
    boundary (remaining == room, aligned)."""
    _identity_case(2 * CT, 5, 2, rng)


def test_one_token_final_chunk(rng):
    """Prompt = 2 chunks + 1: the final chunk carries a single token
    (the promotion logits come from a C=1 chunk)."""
    _identity_case(2 * CT + 1, 5, 3, rng)


def test_prefix_hit_consuming_all_but_one_token(rng):
    """A cached prefix covering every whole page of the prompt leaves a
    1-token suffix: chunked admission must enqueue exactly one 1-token
    final chunk over the aliased resident pages."""
    cfg = _cfg()
    eng = _engine(cfg, max_len=32, n_pages=24, prefix_cache=True)
    toks = rng.integers(2, cfg.vocab, (2 * PS + 1,)).astype(np.int32)

    _, want = _run(eng, [Request(rid=0, tokens=toks, gen=5, seed=0)],
                   chunked_prefill=False, prefix_cache=False)
    sched = Scheduler(eng)
    sched.submit(Request(rid=0, tokens=toks, gen=5, seed=0))
    out0 = sched.run()                      # cold: inserts 2 pages
    np.testing.assert_array_equal(np.asarray(out0[0]),
                                  np.asarray(want[0]))
    chunks_cold = sched.stats["chunks"]
    sched.submit(Request(rid=1, tokens=toks, gen=5, seed=1))
    out = sched.run()                       # hit: 8 of 9 tokens cached
    assert sched.stats["prefix_hits"] == 1
    assert sched.stats["prefix_hit_tokens"] == 2 * PS
    assert sched.stats["chunks"] == chunks_cold + 1   # one 1-token chunk
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  np.asarray(want[0]))
    sched.prefix.check()
    sched.allocator.check()


# ------------------------------------------------- preemption mid-prefill


def test_preempt_mid_prefill_keeps_completed_pages(rng):
    """Preempting a PREFILLING slot drops only the in-flight chunk: the
    whole pages its completed chunks wrote travel WITH the queued slot,
    re-admission grants just the missing tail, chunking resumes where
    it left off, and the stream is bit-identical to the non-chunked
    scheduler."""
    cfg = _cfg()
    eng = _engine(cfg, B=1, max_len=32, n_pages=16)
    toks = rng.integers(2, cfg.vocab, (19,)).astype(np.int32)
    _, want = _run(eng, [Request(rid=0, tokens=toks, gen=6, seed=0)],
                   chunked_prefill=False)

    sched = Scheduler(eng)
    sched.submit(Request(rid=0, tokens=toks, gen=6, seed=0))
    assert sched.admit() == 1
    slot = sched.slots[0]
    assert slot.req.status is RequestStatus.PREFILLING
    granted = len(slot.pages)
    sched.step()                            # chunk 1: prefilled 8
    sched.step()                            # chunk 2: prefilled 16
    assert slot.prefilled == 2 * CT
    sched._preempt(0)
    # exactly the completed whole pages stayed with the queued slot;
    # the unwritten tail pages went back to the pool
    item = sched.pending[0]
    assert len(item.pages) == 2 * CT // PS
    assert item.prefilled == 2 * CT
    assert sched.allocator.free_pages == eng.n_pages - len(item.pages)
    assert granted > len(item.pages)
    sched.allocator.check()

    out = sched.run()                       # re-admit: 1 chunk remains
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(want[0]))
    assert sched.stats["preempted"] == 1
    # 2 chunks before the preemption + the resumed 3-token final chunk
    assert sched.stats["chunks"] == 3
    assert sched.stats["chunked_tokens"] == 19
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()


# ------------------------------------------------- mixed-step faults


def test_transient_fault_mid_chunk_retries_that_chunk_only(rng):
    """A transient fault landing on a mixed step redoes the in-flight
    chunk and nothing else: one step retry, the successful-chunk count
    matches the clean run, and the stream is bit-identical."""
    cfg = _cfg()
    eng = _engine(cfg, B=1, max_len=32, n_pages=16)
    toks = rng.integers(2, cfg.vocab, (19,)).astype(np.int32)

    def run(with_fault):
        sched = Scheduler(eng)
        proxy = None
        if with_fault:
            proxy = faults.inject(sched, decode_faults=[
                faults.TransientError(step=1)])   # the 2nd chunk
        sched.submit(Request(rid=0, tokens=toks, gen=6, seed=0))
        return sched, proxy, sched.run()

    _, _, clean = run(False)
    sched, proxy, out = run(True)
    assert sched.stats["step_retries"] == 1
    assert proxy.mixed_fn.injected == 1     # it hit a MIXED step
    assert sched.stats["chunks"] == 3       # no completed chunk redone
    assert out[0].ok
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray(clean[0]))
    assert sched.allocator.free_pages == eng.n_pages


def test_nonfinite_final_chunk_quarantines_alone(rng):
    """NaN chunk logits surfacing at promotion fail that request alone
    (the isfinite guard in ``_promote``); the slot and its pages free,
    and a later request on the same scheduler runs clean."""
    cfg = _cfg()
    eng = _engine(cfg, B=1, max_len=32, n_pages=16)
    toks = rng.integers(2, cfg.vocab, (19,)).astype(np.int32)
    sched = Scheduler(eng)
    sched.submit(Request(rid=0, tokens=toks, gen=6, seed=0))
    assert sched.admit() == 1
    assert sched.slots[0].req.status is RequestStatus.PREFILLING
    # promotion with poisoned final-chunk logits (the injectors can
    # only corrupt the decode logits, which a PREFILLING slot
    # discards — drive the guard directly)
    sched._prefilling.popleft()
    sched.slots[0].prefilled = len(toks)
    sched._promote(0, jnp.full((1, cfg.vocab), jnp.nan, jnp.float32))
    out0 = sched.finished[0]
    assert out0.status is RequestStatus.FAILED
    assert "chunked prefill" in out0.error
    assert sched.slots[0] is None
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()
    sched.submit(Request(rid=1, tokens=toks[:9], gen=4, seed=1))
    out = sched.run()
    assert out[1].ok and len(out[1]) == 4
    assert sched.allocator.free_pages == eng.n_pages
    sched.allocator.check()


# ------------------------------------------------- config validation


def test_chunk_tokens_must_be_page_multiple():
    eng = _engine(_cfg())
    with pytest.raises(ValueError, match="multiple of"):
        Scheduler(eng, chunk_tokens=PS + 1)


def test_itl_percentiles_populated(rng):
    cfg = _cfg()
    eng = _engine(cfg)
    sched, out = _run(eng, _reqs(rng, cfg.vocab, _SPECS))
    assert all(v.ok for v in out.values())
    itl = sched.itl_percentiles()
    assert set(itl) == {"p50", "p90", "p99"}
    assert all(v >= 0 for v in itl.values())
    for i, (_, g) in enumerate(_SPECS):
        assert out[i].token_times is not None
        assert len(out[i].token_times) == g
