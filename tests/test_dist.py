"""Multi-device distribution tests.  Each test body runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 so
the rest of the suite keeps seeing one device."""
import subprocess
import sys
import textwrap


def _run(body: str):
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_flash_decode_matches_local():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.decode import sharded_flash_decode
    from repro.models.attention import decode_attend_local

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, T, KV, Dh, H = 2, 64, 2, 16, 4
    q = jax.random.normal(key, (B, H, Dh))
    ck = jax.random.normal(key, (B, T, KV, Dh))
    cv = jax.random.normal(key, (B, T, KV, Dh))
    # flatten kv heads into q-heads for the shard_map path (MHA view)
    qm = q
    want = decode_attend_local(q, ck, cv, jnp.arange(T), jnp.int32(50))
    got = sharded_flash_decode(mesh, q, ck, cv, jnp.int32(50))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("ok")
    """)


def test_paged_pool_seq_sharded_matches_dense_engine():
    """Paged DecodeEngine with the page POOL sequence-sharded over the
    'model' axis (block tables replicated, ownership masked by page
    counts, psum/pmax combine) decodes token-for-token like the dense
    local engine — GQA and absorbed-MLA configs, plus the shard-local
    paged-attend cross-check."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common.config import ModelConfig, MLAConfig
    from repro.engine import DecodeEngine, EngineConfig
    from repro.dist.decode import (local_paged_decode_attend,
                                   sharded_paged_flash_decode)

    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    mla = dict(base, mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   rope_head_dim=8, nope_head_dim=16,
                                   v_head_dim=16))
    B, P, G = 2, 8, 6
    key = jax.random.PRNGKey(0)
    for tag, kw in (("gqa", base), ("mla", mla)):
        cfg = ModelConfig(**kw)
        dense = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                               mesh_shape=(2, 4)))
        toks = jax.random.randint(key, (B, P), 0, cfg.vocab)
        want, _ = dense.generate({"tokens": toks}, gen=G)
        paged = DecodeEngine(cfg, EngineConfig(
            batch=B, max_len=P + G, mesh_shape=(2, 4), paged=True,
            page_size=4, decode_shard="seq"), params=dense.params)
        got, _ = paged.generate({"tokens": toks}, gen=G)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=tag)

    # op level: arbitrary page->shard placement, both backends
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(key, 3)
    Bq, KV, D, H, ps, J, n_pages = 2, 2, 16, 4, 4, 6, 16
    q = jax.random.normal(ks[0], (Bq, H, D))
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, D))
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, D))
    table = jnp.asarray(np.random.default_rng(0).permutation(n_pages)
                        [:Bq * J].reshape(Bq, J), jnp.int32)
    lens = jnp.array([13, 21], jnp.int32)
    want = local_paged_decode_attend(q, kp, vp, table, lens)
    for backend in ("xla", "pallas"):
        got = sharded_paged_flash_decode(mesh, q, kp, vp, table, lens,
                                         backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=backend)
    print("ok")
    """)


def test_seq_sharded_q8_paged_decode_matches_local():
    """int8 page pools with their fp32 scale sidecars sharded along the
    page dim over the 'model' axis (mirroring the pools): the pmax/psum
    combine reproduces the local q8 attend — GQA and split-operand MLA,
    both backends — and the seq-sharded q8 paged engine decodes
    token-for-token like the single-device q8 paged engine."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.decode import (local_mla_paged_decode_attend,
                                   local_paged_decode_attend,
                                   sharded_mla_paged_flash_decode,
                                   sharded_paged_flash_decode)
    from repro.kernels.quant import quantize_int8

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, KV, D, H, ps, J, n_pages = 2, 2, 16, 4, 4, 6, 16
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, D))
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, D))
    kq, ksc = quantize_int8(kp, axis=(1, 3))
    vq, vsc = quantize_int8(vp, axis=(1, 3))
    ksc, vsc = ksc.reshape(n_pages, KV), vsc.reshape(n_pages, KV)
    table = jnp.asarray(np.random.default_rng(0).permutation(n_pages)
                        [:B * J].reshape(B, J), jnp.int32)
    lens = jnp.array([13, 21], jnp.int32)
    want = local_paged_decode_attend(q, kq, vq, table, lens,
                                     k_scale=ksc, v_scale=vsc)
    for backend in ("xla", "pallas"):
        got = sharded_paged_flash_decode(mesh, q, kq, vq, table, lens,
                                         k_scale=ksc, v_scale=vsc,
                                         backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=backend)

    # split-operand MLA: per-page scalar scales, latent + rope pools
    r, rope = 16, 8
    scale = 1.0 / (24 ** 0.5)
    ms = jax.random.split(jax.random.PRNGKey(1), 4)
    q_abs = jax.random.normal(ms[0], (B, H, r))
    q_rope = jax.random.normal(ms[1], (B, H, rope))
    cq, cs = quantize_int8(
        jax.random.normal(ms[2], (n_pages, ps, r)), axis=(1, 2))
    rq, rs = quantize_int8(
        jax.random.normal(ms[3], (n_pages, ps, rope)), axis=(1, 2))
    cs, rs = cs.reshape(n_pages), rs.reshape(n_pages)
    want = local_mla_paged_decode_attend(q_abs, q_rope, cq, rq, table,
                                         lens, scale=scale,
                                         ckv_scale=cs, krope_scale=rs)
    for backend in ("xla", "pallas"):
        got = sharded_mla_paged_flash_decode(
            mesh, q_abs, q_rope, cq, rq, table, lens, scale=scale,
            ckv_scale=cs, krope_scale=rs, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg="mla-" + backend)

    # engine level: on the SAME (2,4) seq-sharded mesh, greedy decode
    # over int8 pools matches the bf16-pool engine token-for-token
    # (the established same-mesh pin — local-vs-mesh comparisons mix in
    # unrelated layout effects)
    from repro.common.config import ModelConfig, MLAConfig
    from repro.engine import DecodeEngine, EngineConfig

    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    mla = dict(base, mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   rope_head_dim=8, nope_head_dim=16,
                                   v_head_dim=16))
    B, P, G = 2, 8, 6
    for tag, kw in (("gqa", base), ("mla", mla)):
        cfg = ModelConfig(**kw)
        bf16 = DecodeEngine(cfg, EngineConfig(
            batch=B, max_len=P + G, mesh_shape=(2, 4), paged=True,
            page_size=4, decode_shard="seq"))
        # prompt seed chosen with no greedy near-ties under the random
        # params (the suite's usual convention for exact-stream pins)
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0,
                                  cfg.vocab)
        want, _ = bf16.generate({"tokens": toks}, gen=G)
        q8 = DecodeEngine(cfg, EngineConfig(
            batch=B, max_len=P + G, mesh_shape=(2, 4), paged=True,
            page_size=4, decode_shard="seq", kv_dtype="int8"),
            params=bf16.params)
        got, _ = q8.generate({"tokens": toks}, gen=G)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=tag)
    print("ok")
    """)


def test_pipeline_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    S, n_micro, mb, D = 4, 8, 4, 16
    key = jax.random.PRNGKey(1)
    stage_w = jax.random.normal(key, (S, D, D)) / (D ** 0.5)
    x = jax.random.normal(key, (n_micro * mb, D))

    def stage_fn(w, xb):
        return jnp.tanh(xb @ w)

    got = pipeline_apply(mesh, stage_fn, stage_w, x, n_micro=n_micro)
    want = x
    for s in range(S):
        want = stage_fn(stage_w[s], want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("ok")
    """)


def test_compressed_psum_close_and_error_feedback():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    from repro.dist.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    err0 = jnp.zeros((8, 256))

    def local(g, e):
        out, e2 = compressed_psum(g, e, "data", 8)
        return out, e2

    fn = shard_map(local, mesh=mesh,
                   in_specs=(PS("data"), PS("data")),
                   out_specs=(PS("data"), PS("data")))
    got, err = fn(g, err0)
    want = jnp.mean(g, axis=0, keepdims=True)      # psum/8 per shard
    # int8 quantization: close but not exact; error feedback captures
    # the residual
    assert float(jnp.abs(np.asarray(got) - want).max()) < 0.05
    assert float(jnp.abs(err).max()) > 0            # nonzero residual
    # two-step: applying feedback shrinks accumulated bias
    got2, _ = fn(g, err)
    two_step = (np.asarray(got) + np.asarray(got2)) / 2
    assert float(abs(two_step - np.asarray(want)).max()) <= \
        float(abs(np.asarray(got) - np.asarray(want)).max()) + 1e-6
    print("ok")
    """)


def test_sharded_train_step_runs_and_matches_single():
    """A reduced arch trains one step on a (2,4) mesh; loss equals the
    single-device loss (GSPMD semantics preserved)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.dist import sharding as SH
    from repro.launch.steps import build_train_step
    from repro.models import lm
    from repro.optim import adamw

    cfg = reduced(get_config("olmoe-1b-7b"))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, S = 4, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": jnp.ones((B, S), jnp.float32)}

    params = lm.init(cfg, key)
    loss_single, _ = lm.train_loss(params, batch, cfg)

    opt_cfg = adamw.OptConfig()
    step = build_train_step(cfg, opt_cfg)
    shardings = SH.to_shardings(mesh, SH.param_pspecs(cfg, mesh))
    with mesh:
        p_sh = jax.device_put(params, shardings)
        opt = adamw.init(opt_cfg, p_sh)
        p2, opt2, metrics = jax.jit(step)(p_sh, opt, batch)
    np.testing.assert_allclose(float(metrics["loss"]),
                               float(loss_single), rtol=1e-4)
    print("ok", float(metrics["loss"]))
    """)


def test_zero1_pspecs_shard_replicated_dims():
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS
    from repro.optim import adamw

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pspecs = {"w": PS(None, "model"), "b": PS(None)}
    avals = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
             "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
    z = adamw.zero1_pspecs(pspecs, avals, mesh)
    assert z["w"] == PS("data", "model"), z["w"]
    assert z["b"] == PS("data"), z["b"]
    print("ok")
    """)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save on a (2,4) mesh, restore onto (8,1) and 1-device — the
    elastic re-shard path."""
    _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.checkpoint import CheckpointStore

    store = CheckpointStore({str(tmp_path)!r})
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(64.0 * 32).reshape(64, 32)
    xs = jax.device_put(x, NamedSharding(mesh1, PS("data", "model")))
    store.save(1, {{"x": xs}})

    mesh2 = jax.make_mesh((8, 1), ("data", "model"))
    tgt = {{"x": jax.ShapeDtypeStruct((64, 32), jnp.float32)}}
    sh2 = {{"x": NamedSharding(mesh2, PS("model", "data"))}}
    out = store.restore(1, tgt, sh2)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
    out1 = store.restore(1, tgt)
    np.testing.assert_array_equal(np.asarray(out1["x"]), np.asarray(x))
    print("ok")
    """)


def test_hlo_collective_parser_counts_scan_trips():
    """all-gather inside a scan body is multiplied by the trip count."""
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.launch import hlo_analysis

    mesh = jax.make_mesh((8,), ("model",))
    W = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    with mesh:
        comp = jax.jit(
            f, in_shardings=(NamedSharding(mesh, PS()),
                             NamedSharding(mesh, PS(None, None, "model")))
        ).lower(x, W).compile()
    total, kinds = hlo_analysis.collective_bytes(comp.as_text())
    # GSPMD chooses to all-gather the small (4,64) carry activation
    # inside the loop body (cheaper than gathering weights): the parser
    # must multiply it by the 10 while trips
    per_trip = 4 * 64 * 4
    assert total >= 10 * per_trip, (total, kinds)
    assert total < 10 * per_trip * 4, (total, kinds)
    print("ok", total, kinds)
    """)


def test_distributed_flash_decode_pallas_kernel_path():
    """kernel_impl='pallas' dispatches the VWR flash-decode kernel per
    shard; the psum combine must still match the local reference."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.decode import sharded_flash_decode
    from repro.models.attention import decode_attend_local

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, T, KV, Dh, H = 2, 64, 2, 16, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Dh))
    ck = jax.random.normal(ks[1], (B, T, KV, Dh))
    cv = jax.random.normal(ks[2], (B, T, KV, Dh))
    for cur in (1, 37, 64):
        want = decode_attend_local(q, ck, cv, jnp.arange(T),
                                   jnp.int32(cur))
        got = sharded_flash_decode(mesh, q, ck, cv, jnp.int32(cur),
                                   kernel_impl="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    print("ok")
    """)


def test_serve_sharded_decode_matches_local():
    """End-to-end decode_step on a (2,4) mesh with the cache sequence-
    sharded (cfg.decode_shard='seq' + dist.sharding layouts) produces
    the same logits as single-device decode — the launch.serve path."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.dist import sharding as SH
    from repro.launch import steps
    from repro.models import lm

    cfg = reduced(get_config("tinyllama-1.1b"))
    key = jax.random.PRNGKey(0)
    B, T = 2, 32
    params = lm.init(cfg, key)
    cache = lm.init_cache(cfg, B, T)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    batch = {"token": tok, "cur_len": jnp.int32(5), "cache": cache}
    want, _ = lm.decode_step(params, batch, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    scfg = cfg.replace(decode_shard="seq")
    p_sh = jax.device_put(params, SH.to_shardings(
        mesh, SH.param_pspecs(scfg, mesh, "serve")))
    c_sh = jax.device_put(cache, SH.to_shardings(
        mesh, SH.cache_pspecs(scfg, mesh, B, seq_shard=True)))
    with mesh:
        got, new_cache = jax.jit(steps.build_decode(scfg, mesh))(
            p_sh, {"token": tok, "cur_len": jnp.int32(5),
                   "cache": c_sh})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("ok")
    """)


def test_serve_sharded_mla_decode_matches_local():
    """MLA decode through the absorbed-MQA view + dist.decode: a
    sequence-sharded deepseek-style decode_step on a (2,4) mesh (mesh
    passed EXPLICITLY through steps.build_decode — no ambient `with
    mesh:` context) matches single-device decode."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.dist import sharding as SH
    from repro.launch import steps
    from repro.models import lm

    cfg = reduced(get_config("deepseek-v3-671b"))
    key = jax.random.PRNGKey(0)
    B, T = 2, 32
    params = lm.init(cfg, key)
    cache = lm.init_cache(cfg, B, T)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab)
    batch = {"token": tok, "cur_len": jnp.int32(5), "cache": cache}
    want, _ = lm.decode_step(params, batch, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    scfg = cfg.replace(decode_shard="seq")
    p_sh = jax.device_put(params, SH.to_shardings(
        mesh, SH.param_pspecs(scfg, mesh, "serve")))
    c_sh = jax.device_put(cache, SH.to_shardings(
        mesh, SH.cache_pspecs(scfg, mesh, B, seq_shard=True)))
    # NOTE: no `with mesh:` — the mesh rides steps.build_decode
    got, _ = jax.jit(steps.build_decode(scfg, mesh))(
        p_sh, {"token": tok, "cur_len": jnp.int32(5), "cache": c_sh})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("ok")
    """)


def test_sharded_mla_split_matches_concat_view():
    """Sequence-sharded split-operand MLA decode
    (``sharded_mla_flash_decode``: latent + rope caches sharded as
    separate operands, pmax/psum combine) matches the concatenated
    k_cat/v_cat route through ``sharded_flash_decode`` numerically,
    and a seq-sharded deepseek-style engine decodes token-for-token
    like the same engine driven through the concat view — the
    decode_shard='seq' leg of the split-vs-concat bit-exactness pins."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.decode import (sharded_flash_decode,
                                   sharded_mla_flash_decode)
    from repro.kernels import dispatch as D
    from repro.models import mla as MLA

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, H, r, rope, T = 2, 4, 16, 8, 64
    ks = jax.random.split(key, 4)
    q_abs = jax.random.normal(ks[0], (B, H, r))
    q_rope = jax.random.normal(ks[1], (B, H, rope))
    ckv = jax.random.normal(ks[2], (B, T, r))
    krope = jax.random.normal(ks[3], (B, T, rope))
    scale = 1.0 / (24 ** 0.5)
    cur = jnp.int32(50)
    for backend in ("xla", "pallas"):
        got = sharded_mla_flash_decode(mesh, q_abs, q_rope, ckv, krope,
                                       cur, scale=scale,
                                       backend=backend)
        q_cat, k_cat, v_cat, _ = MLA.mla_concat_view(q_abs, q_rope,
                                                     ckv, krope, scale)
        want = sharded_flash_decode(mesh, q_cat, k_cat, v_cat, cur,
                                    backend=backend)[..., :r]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=backend)

    # engine level: seq-sharded deepseek-style generation, split path
    # vs the concat view re-registered over the split op
    from repro.configs import get_config, reduced
    from repro.engine import DecodeEngine, EngineConfig

    cfg = reduced(get_config("deepseek-v3-671b"))
    B, P, G = 2, 16, 8
    eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                         mesh_shape=(2, 4),
                                         decode_shard="seq"))
    toks = jax.random.randint(key, (B, P), 0, cfg.vocab)
    got, _ = eng.generate({"tokens": toks}, gen=G)

    def concat_partial(q_abs, q_rope, c_kv, k_rope, cur_len, pos0=0, *,
                       scale, tune=True):
        q_cat, k_cat, v_cat, r = MLA.mla_concat_view(q_abs, q_rope,
                                                     c_kv, k_rope,
                                                     scale)
        o_t, m, l = D.dispatch("decode_partial", "xla", q_cat, k_cat,
                               v_cat, cur_len, pos0)
        return o_t[..., :r], m, l

    saved = dict(D._REGISTRY["decode_partial_mla"])
    D.register("decode_partial_mla", "xla")(concat_partial)
    try:
        eng_c = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                               mesh_shape=(2, 4),
                                               decode_shard="seq"),
                             params=eng.params)
        want, _ = eng_c.generate({"tokens": toks}, gen=G)
    finally:
        D._REGISTRY["decode_partial_mla"] = saved
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("ok")
    """)


def test_engine_sharded_decode_no_ambient_mesh():
    """DecodeEngine on a (2,4) mesh with a sequence-sharded cache:
    generation runs end to end with the mesh passed explicitly, and the
    deprecated ambient-mesh fallback is never consulted."""
    _run("""
    import warnings
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.engine import DecodeEngine, EngineConfig

    cfg = reduced(get_config("tinyllama-1.1b"))
    B, P, G = 2, 16, 8
    eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G,
                                         mesh_shape=(2, 4),
                                         decode_shard="seq"))
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, P), 0,
                              cfg.vocab)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        tokens, stats = eng.generate({"tokens": toks}, gen=G)
    assert tokens.shape == (B, G)
    amb = [x for x in w if "ambient" in str(x.message)]
    assert not amb, [str(x.message) for x in amb]

    # single-device greedy reference: same generations
    ref = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G))
    want, _ = ref.generate({"tokens": toks}, gen=G)
    np.testing.assert_array_equal(np.asarray(tokens), np.asarray(want))
    print("ok")
    """)


def test_shard_hint_explicit_mesh_applies_constraint():
    """shard_hint with an explicit mesh (no `with mesh:` context) must
    actually constrain — regression: a bare PartitionSpec raises
    'requires a non-empty mesh' outside the context and the no-op
    guard swallowed it, leaving the whole explicit-mesh hint plumbing
    inert."""
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.common.hints import shard_batch, shard_hint

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.zeros((8, 4, 16))

    def f(x):
        return shard_hint(x, PS(None, "model", None), mesh=mesh)

    out = jax.jit(f)(x)
    s = out.sharding
    assert isinstance(s, NamedSharding) and s.spec[1] == "model", s

    out2 = jax.jit(lambda x: shard_batch(x, mesh=mesh))(x)
    assert out2.sharding.spec[0] == "data", out2.sharding
    print("ok")
    """)


def test_pipeline_handles_multi_microbatch_drain():
    """n_micro != a multiple of the stage count still drains cleanly
    (bubble ticks feed zeros that are never collected)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    S, n_micro, mb, D = 4, 5, 3, 8
    key = jax.random.PRNGKey(2)
    stage_w = jax.random.normal(key, (S, D, D)) / (D ** 0.5)
    x = jax.random.normal(key, (n_micro * mb, D))

    def stage_fn(w, xb):
        return jnp.tanh(xb @ w) + xb

    got = pipeline_apply(mesh, stage_fn, stage_w, x, n_micro=n_micro)
    want = x
    for s in range(S):
        want = stage_fn(stage_w[s], want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("ok")
    """)
