"""DecodeEngine + kernel-dispatch registry tests: CLI -> EngineConfig
mapping, engine decode vs the raw lm loop, the moe+mla cache-padding
branch, registry routing/'auto', and the kernel_impl deprecation shim."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import MLAConfig, ModelConfig, MoEConfig
from repro.engine import DecodeEngine, EngineConfig, pad_cache_from_prefill
from repro.kernels import dispatch as D
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                dtype="float32", remat="none", attn_block_q=32,
                attn_block_kv=32)
    base.update(kw)
    return ModelConfig(**base)


def _mla_moe_cfg():
    # capacity_factor 4.0: prefill groups B*S tokens, decode groups B —
    # a tight capacity drops different tokens in the two groupings, so
    # the consistency check needs the no-drop regime (same choice as
    # test_models.test_moe_matches_dense_reference)
    return _cfg(family="moe",
                moe=MoEConfig(n_experts=4, top_k=2, d_expert=32,
                              first_k_dense=1, d_ff_dense=128,
                              capacity_factor=4.0),
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              rope_head_dim=8, nope_head_dim=16,
                              v_head_dim=16))


# ---------------------------------------------------------------- CLI


def test_serve_cli_maps_to_engine_config():
    from repro.launch import serve

    args = serve.build_parser().parse_args(
        ["--arch", "qwen1.5-0.5b", "--batch", "3", "--prompt-len", "16",
         "--gen", "8", "--data-model", "2", "4", "--shard", "seq",
         "--kernel-impl", "pallas"])
    ecfg = serve.engine_config_from_args(args)
    assert ecfg == EngineConfig(batch=3, max_len=24, mesh_shape=(2, 4),
                                decode_shard="seq", kernel_impl="pallas")


def test_serve_cli_defaults_and_vlm_budget():
    from repro.launch import serve

    args = serve.build_parser().parse_args(
        ["--arch", "internvl2-2b", "--prompt-len", "16", "--gen", "8"])
    ecfg = serve.engine_config_from_args(args)
    assert ecfg.mesh_shape == (jax.device_count(), 1)
    assert ecfg.decode_shard == "none" and ecfg.kernel_impl == "xla"
    assert ecfg.max_len == 24
    # the vlm frontend prefix counts against the cache budget
    vlm = _cfg(family="vlm", frontend="vision", frontend_tokens=8,
               frontend_dim=32)
    assert serve.engine_config_from_args(args, vlm).max_len == 32


# ---------------------------------------------------------------- engine


def test_engine_generate_matches_raw_decode_loop():
    """Engine prefill + decode == lm.prefill + pad + lm.decode_step."""
    cfg = _cfg()
    B, P, G = 2, 8, 5
    eng = DecodeEngine(cfg, EngineConfig(batch=B, max_len=P + G))
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab)
    got, stats = eng.generate({"tokens": toks}, gen=G)
    assert got.shape == (B, G)
    assert stats["t_decode_s"] >= 0

    logits, caches = lm.prefill(eng.params, {"tokens": toks}, cfg)
    cache = pad_cache_from_prefill(cfg, caches, B, P + G, enc_len=P)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want = [tok]
    for i in range(G - 1):
        lg, cache = lm.decode_step(
            eng.params, {"token": tok, "cur_len": jnp.int32(P + i),
                         "cache": cache}, cfg)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        want.append(tok)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.stack(want, 1)))


def test_engine_rejects_overlong_generation_and_bad_batch():
    cfg = _cfg()
    eng = DecodeEngine(cfg, EngineConfig(batch=2, max_len=12))
    toks = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate({"tokens": toks}, gen=6)
    with pytest.raises(ValueError, match="batch"):
        eng.prefill({"tokens": jnp.zeros((4, 8), jnp.int32)})


def test_engine_inherits_cfg_pinned_knobs():
    """EngineConfig defaults (None) inherit a cfg pinned to
    pallas/seq instead of silently resetting it; an explicit
    EngineConfig value still wins."""
    cfg = _cfg(kernel_impl="pallas")
    eng = DecodeEngine(cfg, EngineConfig(batch=1, max_len=8))
    assert eng.cfg.kernel_impl == "pallas"
    assert eng.ecfg.kernel_impl == "pallas"
    assert eng.cfg.decode_shard == "none"
    eng2 = DecodeEngine(cfg, EngineConfig(batch=1, max_len=8,
                                          kernel_impl="xla"))
    assert eng2.cfg.kernel_impl == "xla"


def test_engine_seq_shard_divisibility_checked():
    """(A stub mesh stands in for a 2-chip model axis: the check fires
    before the engine touches devices, and make_local_mesh would clamp
    (1, 2) to the single CPU device anyway.)"""
    class _Mesh:
        shape = {"data": 1, "model": 2}

    with pytest.raises(ValueError, match="divisible"):
        DecodeEngine(_cfg(), EngineConfig(batch=2, max_len=13,
                                          decode_shard="seq"),
                     mesh=_Mesh())


# ---------------------------------------------------------------- cache


def test_pad_cache_from_prefill_mla_moe_branch():
    """The moe+mla branch places BOTH the dense-layer and moe-layer
    latent stacks (regression: the pre-PR-2 code sliced layer 0 and
    lacked the mla+moe case entirely)."""
    cfg = _mla_moe_cfg()
    B, P, T = 2, 8, 12
    params = lm.init(cfg, KEY)
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab)
    _, caches = lm.prefill(params, {"tokens": toks}, cfg)
    kv_d, kv_m = caches

    cache = pad_cache_from_prefill(cfg, caches, B, T)
    n_moe = cfg.n_layers - cfg.moe.first_k_dense
    r, rope = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim
    assert cache["dense"]["ckv"].shape == (1, B, T, r)
    assert cache["moe"]["ckv"].shape == (n_moe, B, T, r)
    assert cache["moe"]["krope"].shape == (n_moe, B, T, rope)
    # prefill latents land in the first P positions of every layer...
    np.testing.assert_allclose(np.asarray(cache["dense"]["ckv"][:, :, :P]),
                               np.asarray(kv_d[0]), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cache["moe"]["ckv"][:, :, :P]),
                               np.asarray(kv_m[0]), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cache["moe"]["krope"][:, :, :P]),
                               np.asarray(kv_m[1]), rtol=1e-6, atol=1e-6)
    # ...and the tail stays zero
    assert float(jnp.abs(cache["moe"]["ckv"][:, :, P:]).max()) == 0.0


def test_mla_moe_prefill_decode_consistency():
    """Teacher-forced decode from a padded mla+moe cache continues the
    prefill: decode logits == full-forward logits at those positions."""
    cfg = _mla_moe_cfg()
    B, S, P = 2, 12, 8
    params = lm.init(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    out = lm.backbone(params, tokens, cfg)
    want = lm._logits(params, out.h, cfg).astype(jnp.float32)

    _, caches = lm.prefill(params, {"tokens": tokens[:, :P]}, cfg)
    cache = pad_cache_from_prefill(cfg, caches, B, S)
    for t in range(P, S):
        lg, cache = lm.decode_step(
            params, {"token": tokens[:, t], "cur_len": jnp.int32(t),
                     "cache": cache}, cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want[:, t]),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- registry


def test_dispatch_registry_routes_and_errors():
    assert "mlp" in D.ops() and "decode_partial" in D.ops()
    assert set(D.backends("qkv_proj")) == {"xla", "pallas"}
    with pytest.raises(KeyError, match="no implementations"):
        D.dispatch("nonexistent_op", "xla")
    with pytest.raises(KeyError, match="no 'mosaic' backend"):
        D.dispatch("mlp", "mosaic", {}, None, "relu")
    # a ModelConfig selects via kernel_impl
    assert D.resolve("mlp", _cfg()) is D.resolve("mlp", "xla")


def test_dispatch_auto_measures_and_persists(tmp_path, monkeypatch):
    """backend='auto' measures both impls once, persists the winner
    under dispatch:<op>, and hits the cache on the next call."""
    import json
    import os
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "tune.json"))
    autotune.reset()
    x = jax.random.normal(KEY, (2, 16, 64))
    p = {"wi": jax.random.normal(KEY, (64, 128)),
         "wg": jax.random.normal(KEY, (64, 128)),
         "wo": jax.random.normal(KEY, (128, 64))}
    from repro.models.layers import mlp
    out = mlp(p, x, "swiglu", backend="auto")
    want = mlp(p, x, "swiglu", backend="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    table = json.load(open(os.environ["REPRO_AUTOTUNE_CACHE"]))
    assert any(k.startswith("dispatch:mlp|") for k in table)
    hits0 = autotune.stats["hits"]
    mlp(p, x, "swiglu", backend="auto")
    assert autotune.stats["hits"] > hits0
    autotune.reset()


def test_dispatch_auto_disabled_trusts_prior(tmp_path, monkeypatch):
    """REPRO_AUTOTUNE=0: 'auto' resolves from the preference order
    (pallas first) without measuring or touching the cache."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "tune.json"))
    autotune.reset()
    table = D._REGISTRY["mlp"]
    assert D._resolve_auto("mlp", table,
                           ({}, jnp.zeros((4, 8)), "relu"), {}) == "pallas"
    assert autotune.stats["measured"] == 0
    autotune.reset()


def test_cached_backend_replays_measured_winner(tmp_path, monkeypatch):
    """The lookup-only resolver (used when building shard_map programs,
    where measuring is unsafe) replays a persisted dispatch winner and
    falls back to the prior order on a miss."""
    import json
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset()
    q = jnp.zeros((2, 4, 16))
    ck = jnp.zeros((2, 64, 2, 16))
    args = (q, ck, ck, jnp.int32(64))
    # miss -> prior order (pallas first)
    assert D.cached_backend("decode_partial", "auto", args) == "pallas"
    # persist a winner pointing at index 1 (= 'xla') and replay it
    shape, dtype = D._arg_signature(args, {})
    tag = kops._backend_tag(kops._auto_interpret(None))
    key = autotune.cache_key("dispatch:decode_partial", shape, dtype, tag)
    with open(path, "w") as f:
        json.dump({key: {"blocks": [1], "us": 1.0}}, f)
    autotune.reset()
    assert D.cached_backend("decode_partial", "auto", args) == "xla"
    # a concrete backend passes through untouched
    assert D.cached_backend("decode_partial", "pallas", args) == "pallas"
    autotune.reset()


def test_cached_backend_replay_survives_registry_growth(tmp_path,
                                                        monkeypatch):
    """Regression: persisted dispatch winners used to be positional
    indices into the CURRENT candidate list, so registering one more
    backend (e.g. this PR's paged variant) silently shifted every
    replay.  Winners are now stored by NAME; legacy integer entries
    are tolerated while still in range."""
    import json
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset()
    q = jnp.zeros((2, 4, 16))
    ck = jnp.zeros((2, 64, 2, 16))
    args = (q, ck, ck, jnp.int32(64))
    shape, dtype = D._arg_signature(args, {})
    tag = kops._backend_tag(kops._auto_interpret(None))
    key = autotune.cache_key("dispatch:decode_partial", shape, dtype, tag)
    with open(path, "w") as f:
        json.dump({key: {"blocks": ["xla"], "us": 1.0}}, f)
    assert D.cached_backend("decode_partial", "auto", args) == "xla"

    # registering an extra backend reorders/extends the candidate list;
    # a name entry must replay unchanged
    try:
        D.register("decode_partial", "aaa_stub")(lambda *a, **k: None)
        autotune.reset()
        assert D.cached_backend("decode_partial", "auto", args) == "xla"
        # legacy int entry: decoded positionally while in range (the
        # old format), against the candidate list including the stub
        with open(path, "w") as f:
            json.dump({key: {"blocks": [1], "us": 1.0}}, f)
        autotune.reset()
        cands = ["pallas", "xla", "aaa_stub"]
        assert D.cached_backend("decode_partial", "auto", args) == \
            cands[1]
        # out-of-range legacy index: prior order, not a crash
        with open(path, "w") as f:
            json.dump({key: {"blocks": [7], "us": 1.0}}, f)
        autotune.reset()
        assert D.cached_backend("decode_partial", "auto", args) == \
            "pallas"
    finally:
        D._REGISTRY["decode_partial"].pop("aaa_stub", None)
        autotune.reset()


def test_resolve_auto_migrates_legacy_index_entries(tmp_path,
                                                    monkeypatch):
    """The measuring resolver rewrites a legacy positional entry to the
    backend name in place, so old cache files heal on first use."""
    import json
    from repro.kernels import autotune
    from repro.kernels import ops as kops

    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset()
    x = jax.random.normal(KEY, (2, 8, 64))
    p = {"wi": jax.random.normal(KEY, (64, 128)),
         "wo": jax.random.normal(KEY, (128, 64))}
    args = (p, x, "relu")
    shape, dtype = D._arg_signature(args, {})
    tag = kops._backend_tag(kops._auto_interpret(None))
    key = autotune.cache_key("dispatch:mlp", shape, dtype, tag)
    with open(path, "w") as f:
        json.dump({key: {"blocks": [1], "us": 1.0}}, f)
    assert D._resolve_auto("mlp", D._REGISTRY["mlp"], args, {}) == "xla"
    table = json.load(open(path))
    assert table[key]["blocks"] == ["xla"]
    autotune.reset()


def test_train_loss_pins_auto_to_xla():
    """kernel_impl='auto' must not break the backward pass: train_loss
    runs it on the xla backend (pallas stays rejected)."""
    cfg = _cfg(kernel_impl="auto")
    params = lm.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((2, 8), jnp.float32)}
    g = jax.grad(lambda p: lm.train_loss(p, batch, cfg)[0])(params)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(g))
    with pytest.raises(ValueError, match="forward-only"):
        lm.train_loss(params, batch, cfg.replace(kernel_impl="pallas"))


# ---------------------------------------------------------------- shim


def test_kernel_impl_kwarg_warns_once(monkeypatch):
    from repro.models import attention as A
    from repro.models.layers import mlp

    monkeypatch.setattr(D, "_KERNEL_IMPL_WARNED", False)
    p = {"wi": jax.random.normal(KEY, (64, 128)),
         "wo": jax.random.normal(KEY, (128, 64))}
    x = jax.random.normal(KEY, (2, 4, 64))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = mlp(p, x, "relu", kernel_impl="xla")
        # the second legacy call (different site!) stays silent
        ap = {"wq": jax.random.normal(KEY, (64, 4, 16)),
              "wk": jax.random.normal(KEY, (64, 2, 16)),
              "wv": jax.random.normal(KEY, (64, 2, 16)),
              "wo": jax.random.normal(KEY, (4, 16, 64))}
        q, k, v = A.qkv_proj(ap, x, jnp.arange(4), 1e4, kernel_impl="xla")
        o = A.o_proj(ap, q, kernel_impl="xla")
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "kernel_impl" in str(x.message)]
    assert len(dep) == 1, [str(x.message) for x in w]
    assert "dispatch" in str(dep[0].message)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(mlp(p, x, "relu")),
                               rtol=1e-6, atol=1e-6)
    assert o.shape == x.shape
