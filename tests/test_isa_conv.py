"""§6.1/§6.2 reproduction: ISA template programs are bit-exact vs NumPy
oracles, and the closed-form cost model matches the interpreter."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import analysis as A
from repro.core import ref_ops as R
from repro.core import templates as T
from repro.core.machine import PAPER_EXAMPLE, ProvetConfig


def test_conv_paper_example_6_1():
    """The paper's exact example: 5x5 kernel, 16x16 image, 16-lane VFU,
    64-operand SRAM."""
    rng = np.random.default_rng(0)
    img = rng.standard_normal((1, 16, 16)).astype(np.float32)
    w = rng.standard_normal((1, 1, 5, 5)).astype(np.float32)
    mp = T.conv2d(PAPER_EXAMPLE, img, w)
    out, m = mp.run()
    np.testing.assert_allclose(out, R.conv2d_ref(img, w), rtol=1e-5,
                               atol=1e-5)
    # paper: 25 tap-iterations per output row; ours adds loads/staging
    assert m.c.instr_mix["VFUX"] == 12 * 25          # H_out x K^2 macs
    assert m.cmr() > 4.0                             # VWR ratio pays off
    assert m.utilization(mp.meta["total_macs"]) > 0.2


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(6, 14), w=st.integers(6, 16), k=st.integers(1, 5),
    cin=st.integers(1, 3), cout=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_conv_template_property(h, w, k, cin, cout, seed):
    if k > min(h, w):
        return
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((cin, h, w)).astype(np.float32)
    wts = rng.standard_normal((cout, cin, k, k)).astype(np.float32)
    cfg = ProvetConfig()
    need = (-(-cin * h // 4) + -(-cout * cin * k * k // 64)
            + -(-cout * (h - k + 1) // 4))
    if need > cfg.sram_depth:
        return
    out, m = T.conv2d(cfg, img, wts).run()
    np.testing.assert_allclose(out, R.conv2d_ref(img, wts), rtol=1e-4,
                               atol=1e-4)


def test_depthwise_and_cmr_drop():
    """Depthwise (the low-reuse case): correct, and its CMR is lower
    than the dense conv's — the reuse the paper says it lacks."""
    rng = np.random.default_rng(1)
    img = rng.standard_normal((4, 12, 14)).astype(np.float32)
    wd = rng.standard_normal((4, 3, 3)).astype(np.float32)
    out, m_dw = T.depthwise_conv2d(ProvetConfig(), img, wd).run()
    np.testing.assert_allclose(out, R.depthwise_ref(img, wd), rtol=1e-4,
                               atol=1e-4)
    wf = rng.standard_normal((4, 4, 3, 3)).astype(np.float32)
    _, m_full = T.conv2d(ProvetConfig(), img, wf).run()
    assert m_full.cmr() > m_dw.cmr()


def test_fc_exact():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(48).astype(np.float32)
    w = rng.standard_normal((16, 48)).astype(np.float32)
    out, m = T.fc(ProvetConfig(), x, w).run()
    np.testing.assert_allclose(out, w @ x, rtol=1e-5, atol=1e-5)
    # streaming GEMV: zero weight reuse, CMR ~= slices-per-row ratio
    assert m.cmr() > 2.0


def test_maxpool_exact():
    rng = np.random.default_rng(3)
    img = rng.standard_normal((8, 16)).astype(np.float32)
    out, _ = T.maxpool(ProvetConfig(), img, 2).run()
    np.testing.assert_allclose(out, R.maxpool_ref(img, 2))


def test_packing_6_2_2():
    """Two narrow images packed into the lanes — same results."""
    rng = np.random.default_rng(4)
    imgs = [rng.standard_normal((1, 8, 6)).astype(np.float32)
            for _ in range(2)]
    packed, spans = T.pack_width(imgs, 16, 3)
    w = rng.standard_normal((1, 1, 3, 3)).astype(np.float32)
    out, _ = T.conv2d(ProvetConfig(), packed, w).run()
    for (o, wd), im in zip(spans, imgs):
        np.testing.assert_allclose(out[:, :, o: o + wd - 2],
                                   R.conv2d_ref(im, w), rtol=1e-4,
                                   atol=1e-4)


def test_partition_6_2_1():
    """Wide image split into halo'd strips — stitched output exact."""
    rng = np.random.default_rng(5)
    img = rng.standard_normal((1, 8, 40)).astype(np.float32)
    w = rng.standard_normal((1, 1, 3, 3)).astype(np.float32)
    parts = []
    for strip, off in T.partition_image(img, 16, 3):
        o, _ = T.conv2d(ProvetConfig(), strip, w).run()
        parts.append((o, off))
    st_ = T.stitch_strips(parts, 38)
    np.testing.assert_allclose(st_, R.conv2d_ref(img, w), rtol=1e-4,
                               atol=1e-4)
    # duplication overhead is bounded by (K-1)/strip_width (§6.2.1)
    n_strips = len(parts)
    dup = (n_strips * 16 - 40) / 40
    assert dup < 0.5


@settings(max_examples=8, deadline=None)
@given(h=st.integers(8, 14), cout=st.integers(1, 4),
       cin=st.integers(1, 4), k=st.sampled_from([1, 3, 5]))
def test_closed_form_counts_match_interpreter(h, cout, cin, k):
    """core/analysis.template_conv_counts == machine counters (the
    cross-validation that legitimizes evaluating the closed form at
    real CNN sizes)."""
    if k > h:
        return
    layer = A.ConvLayer("t", h, 14, cin, cout, k)
    cfg = ProvetConfig()
    need = (-(-cin * h // 4) + -(-cout * cin * k * k // 64)
            + -(-cout * (h - k + 1) // 4))
    if need > cfg.sram_depth:
        return
    rng = np.random.default_rng(0)
    img = rng.standard_normal((cin, h, 14)).astype(np.float32)
    wts = rng.standard_normal((cout, cin, k, k)).astype(np.float32)
    _, m = T.conv2d(cfg, img, wts).run()
    pred = A.template_conv_counts(cfg, layer)
    assert pred["cycles"] == m.c.cycles, (pred, m.c.as_dict())
    assert pred["sram_reads"] == m.c.sram_reads
    assert pred["sram_writes"] == m.c.sram_writes
    assert pred["compute_instrs"] == m.c.compute_instrs
